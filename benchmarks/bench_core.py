"""Benchmarks for the paper's architectural claims (one per claim).

The paper has no task-accuracy tables; its claims are arithmetic-
architectural.  Each bench below quantifies one claim; wall times are CPU
proxies (the TPU numbers are structural: op counts / slice counts).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core import fractional as fr
from repro.core import mrc, rns
from repro.core.moduli import PROFILES, get_profile, required_digits
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_matmul_res


def _t(f, *args, n=5):
    f(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_pac_ops(report):
    """Claim 2+6: PAC ops cost O(K) (linear in precision); binary multiply
    partial products are quadratic."""
    rng = np.random.default_rng(0)
    x = rng.integers(-2**20, 2**20, 4096).astype(np.int32)
    for name in ("rns5", "rns9", "rns12", "rns18"):
        p = get_profile(name)
        rx = rns.encode_int32(p, x)
        mul = jax.jit(lambda a, b: rns.rns_mul(p, a, b))
        us = _t(mul, rx, rx)
        q = int(p.range_bits)
        binary_pp = (q // 8 + 1) ** 2
        report(f"pac_mul_{name}", us,
               f"digits={p.n_digits} bits={p.range_bits:.0f} "
               f"binary_8x8_partial_products={binary_pp}")


def bench_deferred_norm(report):
    """Claim 4: one slow normalization per product summation, not per MAC."""
    p = get_profile("rns9")
    n = 256
    rng = np.random.default_rng(1)
    xs = jnp.stack([fr.fr_encode(p, rng.uniform(-1, 1, 64).astype(np.float32))
                    for _ in range(n)])

    def deferred(xs):
        return fr.fr_dot_deferred(p, xs, xs)

    def per_mac(xs):
        acc = None
        for i in range(n):
            prod = fr.fr_mul(p, xs[i], xs[i])
            acc = prod if acc is None else fr.fr_add(p, acc, prod)
        return acc

    t_def = _t(jax.jit(deferred), xs, n=3)
    t_mac = _t(jax.jit(per_mac), xs, n=3)
    report("deferred_norm_dot256", t_def,
           f"per_mac_normalize={t_mac:.0f}us speedup={t_mac/t_def:.1f}x "
           f"slow_ops: 1 vs {n}")


def bench_exactness(report):
    """Claim 1: wide product summations are bit-exact in RNS; float accum
    drifts."""
    p = get_profile("rns9")
    rng = np.random.default_rng(2)
    for D in (4096, 65536):
        a = rng.integers(-32767, 32768, (1, D)).astype(np.int64)
        b = rng.integers(-32767, 32768, (D, 1)).astype(np.int64)
        want = int((a.astype(object) @ b.astype(object))[0, 0])
        rc = rns_matmul_res(
            "rns9", rns.encode_int32(p, a.astype(np.int32)),
            rns.encode_int32(p, b.astype(np.int32)))
        got = int(rns.decode_exact(p, np.asarray(rc))[0, 0])
        f32 = int(float((a.astype(np.float32) @ b.astype(np.float32))[0, 0]))
        bf16 = int(float(
            (a.astype(jnp.bfloat16) @ b.astype(jnp.bfloat16)).astype(
                jnp.float32)[0, 0]))
        report(f"exact_dot_n{D}", 0.0,
               f"rns_err={abs(got-want)} f32_err={abs(f32-want)} "
               f"bf16_err={abs(bf16-want)}")


def bench_conversion_overhead(report):
    """Claim 5: conversion pipelines amortize to negligible vs the matmul."""
    p = get_profile("rns9")
    rng = np.random.default_rng(3)
    for MKN in (64, 256, 1024):
        x = jnp.asarray(rng.standard_normal((MKN, MKN)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((MKN, MKN)), jnp.float32)
        cfg = RnsDotConfig(profile="rns9", qx=14, qw=14)
        t_full = _t(jax.jit(lambda x, w: rns_dot(x, w, cfg)), x, w, n=3)
        # matmul-only on pre-converted residues
        rx = rns.encode_int32(p, jnp.zeros((MKN, MKN), jnp.int32))
        t_mm = _t(jax.jit(lambda a, b: rns_matmul_res("rns9", a, b)), rx, rx,
                  n=3)
        report(f"conversion_share_{MKN}", t_full,
               f"matmul_only={t_mm:.0f}us conv+norm_share="
               f"{max(0.0, 1 - t_mm / t_full):.2f}")


def bench_precision_scaling(report):
    """Claim 6: slices grow linearly with operand bits; binary partial
    products quadratically (structural counts, hardware-independent)."""
    rows = []
    for q in (8, 16, 24, 32, 48):
        k = required_digits(4096, q, q)
        pp = max(1, (2 * q) // 8) ** 2 // 4  # 8x8 mults for a qxq multiply
        rows.append(f"{q}b:rns={k},binary={max(1,(q//8))**2}")
    report("precision_scaling", 0.0, " ".join(rows))


def bench_chain_amortization(report):
    """Tentpole claim: residue-domain chaining amortizes the slow MRC —
    normalize-ops-per-matmul is 1.0 per-op but 1/len(chain) deferred
    (RnsTensor, core/tensor.py).  Counts are structural (trace-time);
    wall times are the CPU proxy."""
    from repro.models.layers import rns_linear_chain

    rng = np.random.default_rng(6)
    cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    ws = tuple(jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
               for _ in range(3))

    def per_op(x):
        y = x
        for w in ws:
            y = rns_dot(y, w, cfg)
        return y

    def deferred(x):
        return rns_linear_chain(x, ws, cfg)

    c_per = dispatch.trace_op_counts(per_op, x)
    c_def = dispatch.trace_op_counts(deferred, x)
    t_per = _t(jax.jit(per_op), x, n=3)
    t_def = _t(jax.jit(deferred), x, n=3)
    report("chain3_norm_per_matmul_deferred", t_def,
           f"norm_per_matmul={c_def.normalizes_per_matmul:.3f} "
           f"normalizes={c_def.normalizes} matmuls={c_def.matmuls} "
           f"converts={c_def.converts}")
    report("chain3_norm_per_matmul_per_op", t_per,
           f"norm_per_matmul={c_per.normalizes_per_matmul:.3f} "
           f"normalizes={c_per.normalizes} matmuls={c_per.matmuls} "
           f"converts={c_per.converts} speedup_deferred={t_per/t_def:.2f}x")


def bench_mlp_block_normalizes(report):
    """Per-residual-block slow-op budget: the deferred MLP datapath runs
    2 normalizations (gate nonlinearity + main path) vs 3 per-op."""
    import dataclasses

    from repro.models.layers import init_mlp, mlp

    rng = np.random.default_rng(7)
    p, _ = init_mlp(jax.random.PRNGKey(0), 64, 128, gated=True)
    x = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    per_op = RnsDotConfig(profile="rns9", qx=8, qw=8)
    deferred = dataclasses.replace(per_op, defer=True)
    for tag, cfg in (("per_op", per_op), ("deferred", deferred)):
        c = dispatch.trace_op_counts(
            lambda x, cfg=cfg: mlp(p, x, gated=True, act="silu", rns=cfg), x)
        us = _t(jax.jit(
            lambda x, cfg=cfg: mlp(p, x, gated=True, act="silu", rns=cfg)),
            x, n=3)
        report(f"mlp_block_{tag}", us,
               f"norm_per_matmul={c.normalizes_per_matmul:.3f} "
               f"normalizes={c.normalizes} matmuls={c.matmuls} "
               f"converts={c.converts}")


def bench_resident_weights(report):
    """Tentpole claim (PR 6): resident residue-domain weights delete the
    per-matmul weight conversion.  Structural: weight_converts drops to
    zero.  HLO-costed on the 128x512x128 acceptance shape: fewer HBM
    bytes (no re-materialized [K, 512, 128] weight residues) at identical
    dot FLOPs.  Wall time is the CPU proxy."""
    from repro.launch.hlo_cost import analyze_hlo
    from repro.core.rns_matmul import rns_resident_dot
    from repro.models.resident import _encode_one

    rng = np.random.default_rng(8)
    cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
    x = jnp.asarray(rng.standard_normal((128, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 128)) / 24, jnp.float32)
    w_res = _encode_one(w, "rns9", 8, 7.0)

    re_fn = jax.jit(lambda x, w: rns_dot(x, w, cfg))
    res_fn = jax.jit(lambda x, r: rns_resident_dot(x, r, cfg))
    c_re = analyze_hlo(re_fn.lower(x, w).compile().as_text())
    c_res = analyze_hlo(res_fn.lower(x, w_res).compile().as_text())
    o_re = dispatch.trace_op_counts(lambda x: rns_dot(x, w, cfg), x)
    o_res = dispatch.trace_op_counts(
        lambda x: rns_resident_dot(x, w_res, cfg), x)
    t_re = _t(re_fn, x, w, n=5)
    t_res = _t(res_fn, x, w_res, n=5)
    report("resident_dot_128x512x128_reencode", t_re,
           f"weight_converts={o_re.weight_converts} "
           f"converts={o_re.converts} hbm_bytes={c_re['hbm_bytes']:.0f} "
           f"flops={c_re['flops']:.0f}")
    report("resident_dot_128x512x128_resident", t_res,
           f"weight_converts={o_res.weight_converts} "
           f"converts={o_res.converts} hbm_bytes={c_res['hbm_bytes']:.0f} "
           f"flops={c_res['flops']:.0f} "
           f"hbm_saved={c_re['hbm_bytes'] - c_res['hbm_bytes']:.0f}B "
           f"speedup={t_re / t_res:.2f}x")


def bench_resident_mlp_block(report):
    """Block-level structural budget: a gated MLP forward schedules 5
    conversions (2 activation + 3 weight) on the re-encode path and 2 on
    the resident path; per-layer narrow profiles additionally shrink the
    digit count the narrow layers move."""
    from repro.models.layers import init_mlp, mlp
    from repro.models.resident import encode_resident, resident_profiles

    class _Cfg:
        rns_targets = "mlp"
        rns = RnsDotConfig(profile="rns9", qx=8, qw=8)

    rng = np.random.default_rng(9)
    p, _ = init_mlp(jax.random.PRNGKey(1), 64, 128, gated=True)
    x = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    variants = [("reencode", p)]
    for tag, kw in (("resident", {}),
                    ("resident_narrow", {"per_layer_profiles": True})):
        variants.append(
            (tag, encode_resident({"mlp": p}, _Cfg(), **kw)["mlp"]))
    for tag, pp in variants:
        c = dispatch.trace_op_counts(
            lambda x, pp=pp: mlp(pp, x, gated=True, act="silu",
                                 rns=_Cfg.rns), x)
        us = _t(jax.jit(
            lambda x, pp=pp: mlp(pp, x, gated=True, act="silu",
                                 rns=_Cfg.rns)), x, n=3)
        profs = sorted(set(resident_profiles({"mlp": pp}).values())) or ["-"]
        report(f"resident_mlp_block_{tag}", us,
               f"converts={c.converts} weight_converts={c.weight_converts} "
               f"activation_converts={c.activation_converts} "
               f"matmuls={c.matmuls} normalizes={c.normalizes} "
               f"profiles={','.join(profs)}")


def bench_paged_gather(report):
    """Serving-path overhead: the paged cache's block-table gather vs a
    dense cache read (the price of decoupling cache memory from batch).
    """
    from repro.serve.kv_cache import gather_pages

    rng = np.random.default_rng(5)
    R, nb, bs, Hk, D = 8, 16, 16, 4, 64
    P = 1 + R * nb
    pages = jnp.asarray(rng.standard_normal((P, bs, Hk, D)), jnp.float32)
    bt = jnp.asarray(
        rng.permutation(np.arange(1, P))[: R * nb].reshape(R, nb), jnp.int32)
    dense = jnp.asarray(rng.standard_normal((R, nb * bs, Hk, D)), jnp.float32)
    t_gather = _t(jax.jit(lambda p, b: gather_pages(p, b) * 1.0), pages, bt,
                  n=5)
    t_dense = _t(jax.jit(lambda d: d * 1.0), dense, n=5)
    report("paged_gather_8x256", t_gather,
           f"dense_read={t_dense:.0f}us pages={P} page_size={bs} "
           f"(gather cost amortizes into the decode attention read)")


def bench_rns_matmul_wall(report):
    """CPU-proxy wall time: digit-sliced matmul (jnp + pallas-interpret)."""
    rng = np.random.default_rng(4)
    p = get_profile("rns9")
    M = K = N = 256
    A = rng.integers(-2000, 2000, (M, K)).astype(np.int32)
    B = rng.integers(-2000, 2000, (K, N)).astype(np.int32)
    ra, rb = rns.encode_int32(p, A), rns.encode_int32(p, B)
    t_jnp = _t(jax.jit(lambda a, b: rns_matmul_res("rns9", a, b)), ra, rb, n=3)
    from repro.kernels.rns_matmul.ops import rns_matmul

    t_pal = _t(lambda a, b: rns_matmul("rns9", a.astype(jnp.int8),
                                       b.astype(jnp.int8)), ra, rb, n=3)
    xf = jnp.asarray(A, jnp.float32)
    wf = jnp.asarray(B, jnp.float32)
    t_f32 = _t(jax.jit(lambda a, b: a @ b), xf, wf, n=3)
    report("rns_matmul_256", t_jnp,
           f"pallas_interpret={t_pal:.0f}us f32_dense={t_f32:.0f}us "
           f"slices={p.n_digits} (TPU target: int8 MXU @2x bf16 rate)")


def run_all(report):
    bench_pac_ops(report)
    bench_deferred_norm(report)
    bench_exactness(report)
    bench_conversion_overhead(report)
    bench_precision_scaling(report)
    bench_chain_amortization(report)
    bench_mlp_block_normalizes(report)
    bench_paged_gather(report)
    bench_rns_matmul_wall(report)
    bench_resident_weights(report)
    bench_resident_mlp_block(report)
