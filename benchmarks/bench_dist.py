"""Digit-sharded execution benchmark: 1 vs 8 virtual devices.

Measures the PR-3 tentpole end to end: the residue-channel datapath
(convert -> digit-sliced matmuls -> one MRC normalize) and the continuous
serving engine, each run on 1 and on 8 virtual CPU devices.  Device
counts need their own XLA_FLAGS before jax initializes, so each
measurement runs in a fresh subprocess of this module (``--worker``);
the parent merges the rows into ``BENCH_dist.json`` via
``benchmarks/run.py --dist-json``.

Read the numbers for PLUMBING, not speedups: the 8 "devices" are slices
of one host CPU, so sharding adds partition bookkeeping without adding
FLOP/s — virtual-device rows are expected at parity or below the
single-device row.  What the bench pins is the *structure* the paper
promises: the residue segment compiles to zero cross-device collectives
(also asserted in tests/test_distributed_rns.py), so on a real mesh the
digit axis scales like the independent channels it is, and the one
normalize-time gather is the only communication.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time

DEVICE_COUNTS = (1, 8)
PROFILE = "rns16"              # 16 digits: 2 per device on the 8-wide axis


def _bench_chain(report, n_dev: int):
    """Digit-sharded 3-linear residue chain, time per jitted call."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.tensor import rt_decode, rt_encode, rt_matmul
    from repro.distributed.sharding import use_digit_sharding
    from repro.launch.mesh import make_digit_mesh

    mesh = make_digit_mesh()            # every device on the "model" axis
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 512)), jnp.float32)
    ws = [jnp.asarray(rng.standard_normal((512, 512)) / 16, jnp.float32)
          for _ in range(3)]

    def chain(x, ws):
        ht = rt_encode(x, PROFILE, bits=8)
        for w in ws:
            ht = rt_matmul(ht, rt_encode(w, PROFILE, bits=8))
        return rt_decode(ht)

    with use_digit_sharding(mesh):
        jf = jax.jit(chain)
        jf(x, ws).block_until_ready()   # compile + warm
        n = 20
        t0 = time.perf_counter()
        for _ in range(n):
            y = jf(x, ws)
        y.block_until_ready()
        us = (time.perf_counter() - t0) / n * 1e6
    report(f"dist_chain_{n_dev}dev", us,
           f"3-linear {PROFILE} chain [8,512]x[512,512], digit axis over "
           f"{n_dev} device(s)")


def _bench_serve(report, n_dev: int):
    """Continuous engine, digit-sharded decode: warm tokens/sec."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs.base import get_config
    from repro.core.rns_matmul import RnsDotConfig
    from repro.models import model as M
    from repro.launch.mesh import make_digit_mesh
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg = dataclasses.replace(
        get_config("smollm-135m", smoke=True),
        rns=RnsDotConfig(profile=PROFILE, qx=8, qw=8), rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    lens = (7, 33, 120)
    prompts = [rng.integers(1, cfg.vocab, (lens[i % 3],)).astype(np.int32)
               for i in range(6)]
    engine = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=160, max_new_tokens=16, page_size=16, max_seqs=6,
        mesh=make_digit_mesh()))
    engine.run(prompts)                 # compile + warm round
    _, stats = engine.run(prompts)
    # us_per_call = microseconds PER TOKEN, so the row is comparable to
    # every other per-call latency in the merged BENCH artifacts
    us_per_tok = stats["wall_s"] / max(stats["total_new_tokens"], 1) * 1e6
    report(f"dist_serve_{n_dev}dev", us_per_tok,
           f"tok_s={stats['tokens_per_s']:.1f} "
           f"page_util={stats['mean_page_utilization']:.2f} "
           f"digit_axis={n_dev}")


def worker(n_dev: int) -> None:
    rows = []

    def report(name, us, derived=""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})

    _bench_chain(report, n_dev)
    _bench_serve(report, n_dev)
    print("RESULT:" + json.dumps(rows), flush=True)


def run_all(report) -> None:
    """Spawn one worker per device count; forward their rows."""
    from repro.launch.mesh import virtual_cpu_env

    for n in DEVICE_COUNTS:
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_dist", "--worker",
             "--devices", str(n)],
            env=virtual_cpu_env(n), capture_output=True, text=True,
            timeout=1200)
        if res.returncode != 0:
            raise RuntimeError(
                f"bench_dist worker ({n} devices) failed:\n"
                + res.stderr[-2000:])
        line = [l for l in res.stdout.splitlines()
                if l.startswith("RESULT:")][0]
        for row in json.loads(line[len("RESULT:"):]):
            report(row["name"], row["us_per_call"], row["derived"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()
    if args.worker:
        worker(args.devices)
        return
    rows = []

    def report(name, us, derived=""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    run_all(report)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
