"""Mixed-length synthetic-traffic benchmark: continuous vs bucketed.

This is the system-level benchmark behind the PR-2 tentpole: the paper's
RNS cost model (cheap residue ops, one slow normalize per summation) only
pays off if the engine keeps the datapath saturated — which bucketed
batching cannot do the moment request lengths mix.  Each engine serves
the SAME workload cold (fresh engine, compile included — the
recompilation cliff IS the production cost being measured) and warm.

Rows land in ``BENCH_serve.json`` via ``benchmarks/run.py --serve-json``:
tokens/sec, p50/p99 request latency, and cache-page utilization.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


PROMPT_LENS = (7, 33, 120)


def _traffic(vocab, n_req, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, (PROMPT_LENS[i % len(PROMPT_LENS)],))
            .astype(np.int32) for i in range(n_req)]


def _serve_bucketed(params, cfg, prompts, max_new, max_cache):
    """Exact-length buckets, each run to completion (the legacy engine)."""
    t0 = time.perf_counter()
    engine = Engine(params, cfg, ServeConfig(max_cache=max_cache,
                                             max_new_tokens=max_new))
    buckets: dict[int, list[int]] = {}
    for i, p in enumerate(prompts):
        buckets.setdefault(len(p), []).append(i)
    done_at = np.zeros((len(prompts),), np.float64)
    total = 0
    for L, idxs in sorted(buckets.items()):
        batch = np.stack([prompts[i] for i in idxs])
        out = engine.generate(batch)
        t = time.perf_counter() - t0
        for i in idxs:
            done_at[i] = t
        total += out.size
    wall = time.perf_counter() - t0
    return {
        "tokens_per_s": total / wall,
        "wall_s": wall,
        "latency_p50_s": float(np.percentile(done_at, 50)),
        "latency_p99_s": float(np.percentile(done_at, 99)),
        "n_buckets": len(buckets),
    }


def _serve_continuous(params, cfg, prompts, max_new, max_cache, **knobs):
    engine = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=max_cache, max_new_tokens=max_new, **knobs))
    _, stats = engine.run(prompts)
    stats["decode_compiles"] = engine._decode._cache_size()
    return stats


def bench_traffic(report, arch="smollm-135m", n_req=9, max_new=16):
    """Cold-start mixed-length traffic: the bucketed engine recompiles per
    (length, bucket-size) cell; the continuous engine compiles once."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    prompts = _traffic(cfg.vocab, n_req)
    max_cache = max(PROMPT_LENS) + max_new + 8

    b = _serve_bucketed(params, cfg, prompts, max_new, max_cache)
    c = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                          page_size=16, max_seqs=n_req)
    report("serve_bucketed_cold", b["wall_s"] * 1e6,
           f"tok_s={b['tokens_per_s']:.1f} p50={b['latency_p50_s']:.3f}s "
           f"p99={b['latency_p99_s']:.3f}s buckets={b['n_buckets']}")
    report("serve_continuous_cold", c["wall_s"] * 1e6,
           f"tok_s={c['tokens_per_s']:.1f} p50={c['latency_p50_s']:.3f}s "
           f"p99={c['latency_p99_s']:.3f}s "
           f"page_util={c['mean_page_utilization']:.2f} "
           f"decode_compiles={c['decode_compiles']} "
           f"speedup_vs_bucketed={b['wall_s']/c['wall_s']:.2f}x")
    return b, c


def bench_traffic_warm(report, arch="smollm-135m", n_req=9, max_new=16):
    """Same workload with compiles amortized: in-flight batching still wins
    on scheduling (one dense step for all rows vs per-bucket loops)."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    max_cache = max(PROMPT_LENS) + max_new + 8

    # warm each engine on a throwaway round, then measure a fresh workload
    warm = _traffic(cfg.vocab, n_req, seed=1)
    meas = _traffic(cfg.vocab, n_req, seed=2)

    eng = Engine(params, cfg, ServeConfig(max_cache=max_cache,
                                          max_new_tokens=max_new))
    buckets: dict[int, list[np.ndarray]] = {}
    for p in warm:
        buckets.setdefault(len(p), []).append(p)
    for L, ps in buckets.items():
        eng.generate(np.stack(ps))
    t0 = time.perf_counter()
    total = 0
    for L, ps in sorted(buckets.items()):
        out = eng.generate(np.stack([p for p in meas if len(p) == L]))
        total += out.size
    wall_b = time.perf_counter() - t0

    ceng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=max_cache, max_new_tokens=max_new, page_size=16,
        max_seqs=n_req))
    ceng.run(warm)
    _, cs = ceng.run(meas)
    report("serve_bucketed_warm", wall_b * 1e6,
           f"tok_s={total/wall_b:.1f}")
    report("serve_continuous_warm", cs["wall_s"] * 1e6,
           f"tok_s={cs['tokens_per_s']:.1f} "
           f"page_util={cs['mean_page_utilization']:.2f} "
           f"preemptions={cs['n_preemptions']}")


def bench_preemption(report, arch="smollm-135m"):
    """Recompute preemption under page pressure: throughput degrades
    gracefully instead of rejecting traffic."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (30, 28, 25, 20)]
    c = _serve_continuous(params, cfg, prompts, 20, 64,
                          page_size=16, max_seqs=4, n_pages=10)
    report("serve_preemption_tiny_pool", c["wall_s"] * 1e6,
           f"tok_s={c['tokens_per_s']:.1f} preemptions={c['n_preemptions']} "
           f"page_util={c['mean_page_utilization']:.2f}")


def bench_window_longstream(report, arch="smollm-135m", max_new=96):
    """Long decode streams in a page pool far smaller than the stream:
    the windowed engine recycles pages behind the sliding window and
    sails through with zero preemptions, where the unwindowed engine in
    the same pool thrashes on recompute preemption (or, single-row,
    cannot even be configured).  Reports tokens/sec, mean page-pool
    occupancy, and cumulative pages recycled by window eviction."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (10,)).astype(np.int32)
               for _ in range(2)]
    max_cache = 10 + max_new + 8
    # 15 usable pages: exactly ONE full 106-token row fits unwindowed, so
    # the two rows can only proceed serially via recompute preemption;
    # windowed rows each stay under ~6 resident pages and run together
    pool = dict(page_size=8, max_seqs=2, n_pages=16)
    win = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                            window_tokens=32, **pool)
    full = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                             **pool)
    report("serve_window_longstream", win["wall_s"] * 1e6,
           f"tok_s={win['tokens_per_s']:.1f} "
           f"page_util={win['mean_page_utilization']:.2f} "
           f"pages_window_evicted={win['pages_window_evicted']} "
           f"preemptions={win['n_preemptions']}")
    report("serve_window_off_longstream", full["wall_s"] * 1e6,
           f"tok_s={full['tokens_per_s']:.1f} "
           f"page_util={full['mean_page_utilization']:.2f} "
           f"preemptions={full['n_preemptions']}")
    return win, full


def bench_rns_serving(report, arch="smollm-135m"):
    """The serving-side slow-op budget: per-step structural RNS counts
    through the continuous engine (deferred-MLP policy on)."""
    import dataclasses

    from repro.core.rns_matmul import RnsDotConfig

    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (7, 33)]
    for tag, defer in (("per_op", False), ("deferred", True)):
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=64, max_new_tokens=4, page_size=16, max_seqs=2,
            rns_defer=defer))
        _, stats = eng.run(prompts)
        ops = stats["steps"][-1]["rns_ops"]        # decode-only step
        report(f"serve_step_rns_{tag}", stats["wall_s"] * 1e6,
               f"decode_step: norm_per_matmul="
               f"{ops.normalizes_per_matmul:.3f} normalizes={ops.normalizes} "
               f"matmuls={ops.matmuls} converts={ops.converts}")


def bench_resident_serving(report, arch="smollm-135m"):
    """PR-6 tentpole at the serve level: resident residue-domain weights
    (encode once at engine build) vs per-matmul re-encode, same traffic,
    same tokens.  weight_converts must be zero on the resident rows; the
    per-layer variant additionally reports the auto-selected narrow
    profiles."""
    import dataclasses

    from repro.core.rns_matmul import RnsDotConfig
    from repro.models.resident import resident_profiles

    cfg = dataclasses.replace(get_config(arch, smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (7, 33)]
    variants = (("reencode", {}),
                ("resident", dict(resident_weights=True)),
                ("resident_narrow", dict(resident_weights=True,
                                         per_layer_profiles=True)))
    toks = {}
    for tag, knobs in variants:
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=64, max_new_tokens=8, page_size=16, max_seqs=2,
            **knobs))
        res, stats = eng.run(prompts)
        toks[tag] = {r: v.tolist() for r, v in res.items()}
        ops = stats["steps"][-1]["rns_ops"]
        profs = sorted(set(resident_profiles(eng.params).values())) or ["-"]
        report(f"serve_resident_{tag}", stats["wall_s"] * 1e6,
               f"tok_s={stats['tokens_per_s']:.1f} "
               f"weight_converts={ops.weight_converts} "
               f"activation_converts={ops.activation_converts} "
               f"profiles={','.join(profs)}")
        assert toks[tag] == toks["reencode"], tag  # tokens must not move


def _shared_prefix_traffic(vocab, n_req, prefix_len=48, tail=8, seed=7):
    """Multi-turn-style workload: every request extends one system
    prompt; the tails repeat a short pattern so n-gram lookup has
    something to find (the realistic best case for prompt-lookup)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, (prefix_len,)).astype(np.int32)
    out = []
    for i in range(n_req):
        pat = rng.integers(1, vocab, (4,)).astype(np.int32)
        out.append(np.concatenate([prefix, np.tile(pat, tail // 4 + 1)[:tail]]))
    return out


def bench_prefix_cache(report, arch="smollm-135m", n_req=6, max_new=16):
    """Shared-prefix traffic with and without COW prefix caching: the
    cached run must allocate fewer pages and write none redundantly
    (shared blocks are adopted, not blitted)."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prefix_traffic(cfg.vocab, n_req)
    max_cache = max(len(p) for p in prompts) + max_new + 8
    base = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                             page_size=16, max_seqs=2)
    hit = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                            page_size=16, max_seqs=2, prefix_cache=True)
    report("serve_prefix_cache_off", base["wall_s"] * 1e6,
           f"tok_s={base['tokens_per_s']:.1f} "
           f"pages_allocated={base['pages_allocated']}")
    report("serve_prefix_cache_on", hit["wall_s"] * 1e6,
           f"tok_s={hit['tokens_per_s']:.1f} "
           f"pages_allocated={hit['pages_allocated']} "
           f"pages_shared={hit['pages_shared']} "
           f"cache_hit_tokens={hit['cache_hit_tokens']} "
           f"cow_splits={hit['cow_splits']} "
           f"alloc_saved={base['pages_allocated'] - hit['pages_allocated']}")
    return base, hit


def bench_spec_decode(report, arch="smollm-135m", n_req=4, max_new=32):
    """Self-speculative decoding: tokens/step (per row) and acceptance
    rate on the shared-prefix workload, vanilla vs [R, k+1] verify."""
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    prompts = _shared_prefix_traffic(cfg.vocab, n_req, prefix_len=24,
                                     tail=16)
    max_cache = max(len(p) for p in prompts) + max_new + 16
    base = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                             page_size=16, max_seqs=n_req)
    spec = _serve_continuous(params, cfg, prompts, max_new, max_cache,
                             page_size=16, max_seqs=n_req, spec_decode=True,
                             spec_k=4, prefix_cache=True)
    report("serve_spec_decode_off", base["wall_s"] * 1e6,
           f"tok_s={base['tokens_per_s']:.1f} "
           f"tokens_per_step={base['tokens_per_step']:.2f} "
           f"steps={base['n_steps']}")
    report("serve_spec_decode_on", spec["wall_s"] * 1e6,
           f"tok_s={spec['tokens_per_s']:.1f} "
           f"tokens_per_step={spec['tokens_per_step']:.2f} "
           f"acceptance_rate={spec['acceptance_rate']:.2f} "
           f"steps={spec['n_steps']} "
           f"step_reduction={base['n_steps']/max(spec['n_steps'],1):.2f}x")
    return base, spec


def bench_mixed_traffic(report, arch="smollm-135m", n_req=8, max_new=8):
    """Chunked prefill vs the prefill/decode phase barrier under queue
    pressure: long prompts keep arriving while short requests decode.

    The barrier engine runs each admission as a separate whole-prompt
    [1, Tpad] pass (padded to prompt_pad) that stalls every decode row;
    the chunked engine streams prompt chunks through the same packed
    step the decode rows ride, so first tokens come out while long
    prefills are still in flight.  Both engines are warmed on a
    throwaway round first — steady-state scheduling is the cost being
    compared, not the one-off compiles.  Reports tokens/sec and p95
    time-to-first-token for both.
    """
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    lens = (120, 7, 96, 7)

    def traffic(seed):
        r = np.random.default_rng(seed)
        return [r.integers(1, cfg.vocab, (lens[i % len(lens)],))
                .astype(np.int32) for i in range(n_req)]

    max_cache = max(lens) + max_new + 16
    common = dict(max_cache=max_cache, max_new_tokens=max_new,
                  page_size=16, max_seqs=4)
    barrier = ContinuousEngine(params, cfg, ServeConfig(**common))
    barrier.run(traffic(1))
    _, b = barrier.run(traffic(2))
    chunked = ContinuousEngine(params, cfg, ServeConfig(
        chunked_prefill=True, token_budget=64, chunk_size=64, **common))
    chunked.run(traffic(1))
    _, c = chunked.run(traffic(2))
    mixed_steps = sum(1 for s in c["steps"]
                      if s["prefill_tokens"] > 0 and s["decode_tokens"] > 0)
    report("serve_mixed_phase_barrier", b["wall_s"] * 1e6,
           f"tok_s={b['tokens_per_s']:.1f} "
           f"ttft_p95={b['ttft_p95_s']:.3f}s "
           f"ttft_p50={b['ttft_p50_s']:.3f}s steps={b['n_steps']}")
    report("serve_mixed_chunked", c["wall_s"] * 1e6,
           f"tok_s={c['tokens_per_s']:.1f} "
           f"ttft_p95={c['ttft_p95_s']:.3f}s "
           f"ttft_p50={c['ttft_p50_s']:.3f}s steps={c['n_steps']} "
           f"mixed_steps={mixed_steps} "
           f"compiles={chunked._mixed._cache_size()} "
           f"tok_s_gain={c['tokens_per_s']/max(b['tokens_per_s'],1e-9):.2f}x "
           f"ttft_p95_gain={b['ttft_p95_s']/max(c['ttft_p95_s'],1e-9):.2f}x")
    return b, c


def run_all(report):
    bench_traffic(report)
    bench_traffic_warm(report)
    bench_preemption(report)
    bench_window_longstream(report)
    bench_rns_serving(report)
    bench_resident_serving(report)
    bench_prefix_cache(report)
    bench_spec_decode(report)
    bench_mixed_traffic(report)
