# One function per paper claim. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import os
import sys


def main() -> None:
    rows = []

    def report(name: str, us: float, derived: str = ""):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    from benchmarks import bench_core

    bench_core.run_all(report)

    # roofline summary from the newest dry-run artifacts
    for tag, d in (("baseline", "artifacts/dryrun"),
                   ("optimized", "artifacts/dryrun_opt")):
        if not os.path.isdir(d):
            continue
        from benchmarks import roofline

        recs = roofline.load_all(d)
        done = [r for r in recs if "skipped" not in r and not r.get("rns")]
        if done:
            worst = min(done, key=lambda r: r["roofline_frac"])
            best = max(done, key=lambda r: r["roofline_frac"])
            report(f"roofline_cells_{tag}", float(len(done)),
                   f"worst={worst['arch']}/{worst['shape']}/{worst['mesh']}"
                   f"@{worst['roofline_frac']:.4f} "
                   f"best={best['arch']}/{best['shape']}/{best['mesh']}"
                   f"@{best['roofline_frac']:.4f}")


if __name__ == "__main__":
    main()
