# One function per paper claim. Print ``name,us_per_call,derived`` CSV.
# ``--json PATH`` additionally writes the rows as a BENCH_*.json artifact
# (CI uploads BENCH_core.json so the normalize-ops-per-matmul amortization
# figures are tracked per commit).
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (e.g. BENCH_core.json)")
    args = ap.parse_args()
    rows = []

    def report(name: str, us: float, derived: str = ""):
        rows.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    from benchmarks import bench_core

    bench_core.run_all(report)

    # roofline summary from the newest dry-run artifacts
    for tag, d in (("baseline", "artifacts/dryrun"),
                   ("optimized", "artifacts/dryrun_opt")):
        if not os.path.isdir(d):
            continue
        from benchmarks import roofline

        recs = roofline.load_all(d)
        done = [r for r in recs if "skipped" not in r and not r.get("rns")]
        if done:
            worst = min(done, key=lambda r: r["roofline_frac"])
            best = max(done, key=lambda r: r["roofline_frac"])
            report(f"roofline_cells_{tag}", float(len(done)),
                   f"worst={worst['arch']}/{worst['shape']}/{worst['mesh']}"
                   f"@{worst['roofline_frac']:.4f} "
                   f"best={best['arch']}/{best['shape']}/{best['mesh']}"
                   f"@{best['roofline_frac']:.4f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
