# One function per paper claim. Print ``name,us_per_call,derived`` CSV.
# ``--json PATH`` additionally writes the rows as a BENCH_core.json
# artifact (normalize-ops-per-matmul amortization, tracked per commit);
# ``--serve-json PATH`` runs the mixed-length synthetic-traffic benchmark
# (benchmarks/bench_serve.py) and writes BENCH_serve.json — tokens/sec,
# p50/p99 latency, page utilization for continuous vs bucketed serving.
# ``--dist-json PATH`` runs the digit-sharded benchmark
# (benchmarks/bench_dist.py; subprocesses with 1 and 8 virtual devices)
# and writes BENCH_dist.json — residue-chain latency and serve tokens/sec
# per device count.
# ``--kernels-json PATH`` runs the fused-kernel benchmark
# (benchmarks/bench_kernels.py) and writes BENCH_kernels.json — HBM bytes
# moved and wall-clock, fused vs unfused chain, plus the recompile and
# autotune smoke rows; ``--skip-kernels`` suppresses it.
# ``--audit-json PATH`` runs ALL the static auditors over the smoke
# serve config and writes the combined reports as BENCH_audit.json —
# the exactness proof (repro.analysis.ledger_audit: headroom tables,
# per-site fallback tallies), the kernel legality/VMEM sweep
# (repro.analysis.kernel_audit: every family x autotune config, plus
# the engine's own traced launches), and the jit compile-churn proof
# (repro.analysis.trace_audit) — tracked per commit by the CI
# static-analysis job.
from __future__ import annotations

import argparse
import json
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write core rows as JSON (e.g. BENCH_core.json)")
    ap.add_argument("--serve-json", default=None, metavar="PATH",
                    help="run the serve traffic benchmark, write its rows "
                         "as JSON (e.g. BENCH_serve.json)")
    ap.add_argument("--dist-json", default=None, metavar="PATH",
                    help="run the digit-sharded 1-vs-8-virtual-device "
                         "benchmark, write its rows as JSON "
                         "(e.g. BENCH_dist.json)")
    ap.add_argument("--kernels-json", default=None, metavar="PATH",
                    help="run the fused-kernel benchmark, write its rows "
                         "as JSON (e.g. BENCH_kernels.json)")
    ap.add_argument("--audit-json", default=None, metavar="PATH",
                    help="run the static auditors (exactness + kernel "
                         "legality/VMEM + trace churn) on the smoke serve "
                         "config, write the combined reports "
                         "(e.g. BENCH_audit.json)")
    ap.add_argument("--skip-core", action="store_true",
                    help="skip the core benches (serve-only run)")
    ap.add_argument("--skip-kernels", action="store_true",
                    help="skip the fused-kernel benches")
    args = ap.parse_args()
    rows = []
    serve_rows = []
    dist_rows = []
    kernel_rows = []
    sink = rows

    def report(name: str, us: float, derived: str = ""):
        sink.append({"name": name, "us_per_call": us, "derived": derived})
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    if not args.skip_core:
        from benchmarks import bench_core

        bench_core.run_all(report)

    if args.serve_json:
        from benchmarks import bench_serve

        sink = serve_rows
        bench_serve.run_all(report)
        sink = rows

    if args.dist_json:
        from benchmarks import bench_dist

        sink = dist_rows
        bench_dist.run_all(report)
        sink = rows

    if args.kernels_json and not args.skip_kernels:
        from benchmarks import bench_kernels

        sink = kernel_rows
        bench_kernels.run_all(report)
        sink = rows

    audit_blob = None
    if args.audit_json:
        import dataclasses

        import jax

        from repro.analysis.kernel_audit import (audit_all,
                                                 audit_engine_kernels)
        from repro.analysis.ledger_audit import audit_serve
        from repro.analysis.trace_audit import audit_traces
        from repro.configs.base import get_config
        from repro.core.rns_matmul import RnsDotConfig
        from repro.models import model as M
        from repro.serve.engine import ContinuousEngine, ServeConfig

        cfg = dataclasses.replace(
            get_config("smollm-135m", smoke=True),
            rns=RnsDotConfig(profile="rns9", qx=8, qw=8), rns_targets="mlp")
        params = M.init_model(jax.random.PRNGKey(0), cfg)[0]
        scfg = ServeConfig(max_cache=24, page_size=8, max_seqs=2)
        audit_report = audit_serve(params, cfg, scfg)
        h = audit_report.min_headroom
        derived = "PROVED" if audit_report.ok else "FAILED"
        if h is not None:
            derived += f" min_headroom={h:+.1f}b"
        report("exactness_audit", 0.0, derived)

        # kernel legality sweep: every family x autotune config, plus
        # the launches a built smoke engine actually traces
        kernel_report = audit_all(profiles=(cfg.rns.profile,))
        eng = ContinuousEngine(params, cfg, scfg)
        engine_kernels = audit_engine_kernels(eng)
        k_ok = kernel_report.ok and engine_kernels.ok
        report("kernel_audit", 0.0,
               ("PROVED" if k_ok else "FAILED")
               + f" configs={len(kernel_report.entries)}"
               + f" engine_phases={len(engine_kernels.entries)}")

        # jit compile-churn proof over the generated traffic family
        trace_report = audit_traces(eng)
        report("trace_audit", 0.0,
               ("PROVED" if trace_report.ok else "FAILED")
               + f" phases={len(trace_report.phases)}"
               + f" variants={trace_report.n_variants}")
        audit_blob = {
            "exactness": json.loads(audit_report.to_json()),
            "kernels": kernel_report.to_dict(),
            "engine_kernels": engine_kernels.to_dict(),
            "trace": trace_report.to_dict(),
        }

    # roofline summary from the newest dry-run artifacts
    for tag, d in (("baseline", "artifacts/dryrun"),
                   ("optimized", "artifacts/dryrun_opt")):
        if not os.path.isdir(d):
            continue
        from benchmarks import roofline

        recs = roofline.load_all(d)
        done = [r for r in recs if "skipped" not in r and not r.get("rns")]
        if done:
            worst = min(done, key=lambda r: r["roofline_frac"])
            best = max(done, key=lambda r: r["roofline_frac"])
            report(f"roofline_cells_{tag}", float(len(done)),
                   f"worst={worst['arch']}/{worst['shape']}/{worst['mesh']}"
                   f"@{worst['roofline_frac']:.4f} "
                   f"best={best['arch']}/{best['shape']}/{best['mesh']}"
                   f"@{best['roofline_frac']:.4f}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
        print(f"wrote {args.json}", flush=True)
    if args.serve_json:
        with open(args.serve_json, "w") as f:
            json.dump(serve_rows, f, indent=2)
        print(f"wrote {args.serve_json}", flush=True)
    if args.dist_json:
        with open(args.dist_json, "w") as f:
            json.dump(dist_rows, f, indent=2)
        print(f"wrote {args.dist_json}", flush=True)
    if args.kernels_json and not args.skip_kernels:
        with open(args.kernels_json, "w") as f:
            json.dump(kernel_rows, f, indent=2)
        print(f"wrote {args.kernels_json}", flush=True)
    if args.audit_json and audit_blob is not None:
        with open(args.audit_json, "w") as f:
            json.dump(audit_blob, f, indent=2)
        print(f"wrote {args.audit_json}", flush=True)


if __name__ == "__main__":
    main()
