"""Three-term roofline from the dry-run artifacts (TPU v5e constants).

  compute    = HLO_dot_FLOPs / peak_bf16            (197 TFLOP/s per chip)
  memory     = HLO write-traffic bytes / HBM bw     (819 GB/s per chip)
  collective = collective wire bytes / ICI link bw  (50 GB/s per chip)

All numerators are PER-DEVICE, extracted trip-count-aware from the
post-SPMD compiled module (launch/hlo_cost.py).  The memory numerator is
the post-fusion write-traffic model (every fusion result written once);
read traffic roughly doubles it — both are recorded in the artifacts, we
report the write model and flag memory-bound cells conservatively.

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (inference),
per device; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/attention/
padding overheads (how much compiled compute is "useful").
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 per chip
PEAK_INT8 = 394e12           # int8 MXU rate (RNS digit slices)
HBM_BW = 819e9
LINK_BW = 50e9


def shape_token_info(rec):
    shape = rec["shape"]
    n = rec["n_devices"]
    table = {
        "train_4k": (4096 * 256, 6),
        "prefill_32k": (32768 * 32, 2),
        "decode_32k": (128, 2),
        "long_500k": (1, 2),
    }
    tokens, mult = table[shape]
    return tokens, mult


def analyze_record(rec):
    if "skipped" in rec or "error" in rec:
        return None
    tokens, mult = shape_token_info(rec)
    n_dev = rec["n_devices"]
    model_flops = mult * rec["params_active"] * tokens / n_dev
    t_compute = rec["flops_per_device"] / (
        PEAK_INT8 if rec.get("rns") else PEAK_FLOPS)
    # vector-unit floor (elementwise work: recurrences, norms, softmax)
    t_vpu = rec.get("vflops_per_device", 0.0) / (PEAK_FLOPS / 8)
    hbm = rec.get("hbm_write_bytes") or rec["bytes_per_device"]
    t_memory = rec["memory"].get("hbm_write_bytes", hbm) / HBM_BW
    t_memory = hbm / HBM_BW
    t_coll = rec["collectives"]["total_wire_bytes"] / LINK_BW
    terms = {"compute": max(t_compute, t_vpu), "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "rns": rec.get("rns", False),
        "t_compute_s": t_compute,
        "t_vpu_s": t_vpu,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(rec["flops_per_device"], 1.0),
        # roofline fraction: useful work at peak vs the bounding term
        "roofline_frac": (model_flops / PEAK_FLOPS) / max(total, 1e-12),
        "step_bound_s": total,
    }


def load_all(art_dir="artifacts/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(f))
        r = analyze_record(rec)
        if r is not None:
            r["file"] = os.path.basename(f)
            out.append(r)
        elif "skipped" in rec:
            out.append({"arch": rec["arch"], "shape": rec["shape"],
                        "mesh": rec["mesh"], "skipped": rec["skipped"]})
    return out


def markdown_table(rows, mesh="single", rns=False):
    hdr = ("| arch | shape | compute s | vpu s | memory s | collective s | "
           "dominant | useful (6ND/HLO) | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            if not rns:
                lines.append(
                    f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                    f"skipped (sub-quadratic rule) | — | — |")
            continue
        if r.get("rns", False) != rns:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_vpu_s']:.3f} | {r['t_memory_s']:.3f} | "
            f"{r['t_collective_s']:.3f} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.3f} | {r['roofline_frac']:.4f} |")
    return "\n".join(lines)


def main():
    import os

    sections = [("BASELINE (pre-§Perf, scatter dispatch / stepwise WKV / "
                 "no microbatching)", "artifacts/dryrun", False)]
    if os.path.isdir("artifacts/dryrun_opt"):
        sections.append(("OPTIMIZED DEFAULTS (post-§Perf)",
                         "artifacts/dryrun_opt", False))
        sections.append(("RNS DATAPATH (paper technique, rns9 on MLPs)",
                         "artifacts/dryrun_opt", True))
    with open("artifacts/roofline.md", "w") as f:
        for title, d, rns in sections:
            rows = load_all(d)
            if rns and not any(r.get("rns") for r in rows):
                continue
            f.write(f"\n# {title}\n")
            for mesh in ("single", "multi"):
                table = markdown_table(rows, mesh, rns=rns)
                if table.count("\n") < 2:
                    continue
                f.write(f"\n## Roofline — {mesh} pod "
                        f"({256 if mesh=='single' else 512} chips)\n\n")
                f.write(table)
                f.write("\n")
    print(open("artifacts/roofline.md").read())
    print("\nwrote artifacts/roofline.md")


if __name__ == "__main__":
    main()
