"""Fused residue-datapath kernels vs the unfused chain (PR-4 tentpole).

The headline claim is a measured speed win: fusing encode -> digit
matmul -> MRC normalize into one Pallas pass removes the [K, M, D]
residue-plane and [K, M, N] accumulator round-trips through HBM.  Rows
land in ``BENCH_kernels.json`` via ``benchmarks/run.py --kernels-json``:

  * HBM bytes moved (``launch/hlo_cost`` over the compiled HLO) for the
    fused kernel vs the unfused three-``pallas_call`` chain — fused must
    be strictly fewer;
  * wall-clock for both (CPU-interpret proxies off-TPU; the bytes row is
    the hardware-independent claim);
  * the zero-per-length-recompile contract of the fixed-tile wrappers;
  * the autotuner's measure -> persist -> reuse loop (smoke);
  * serve-engine tokens/sec with the fused backend vs unfused pallas.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.quantize import absmax_scale
from repro.launch.hlo_cost import analyze_hlo

PROFILE = "rns9"
BITS = 14


def _t(f, *args, n=3):
    jax.block_until_ready(f(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def _hbm_bytes(fn, *args) -> float:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt)["hbm_bytes"]


def _operands(M=128, D=512, N=128, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((M, D)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((D, N)), jnp.float32)
    sx = absmax_scale(x, BITS)
    w_res = dispatch.convert(PROFILE, w, absmax_scale(w, BITS), bits=BITS,
                             backend="pallas")
    return x, sx, w_res


def _unfused(x, sx, w_res):
    r = dispatch.convert(PROFILE, x, sx, bits=BITS, backend="pallas")
    o = dispatch.matmul(PROFILE, r, w_res, backend="pallas")
    return dispatch.normalize(PROFILE, o, backend="pallas")


def _fused(x, sx, w_res):
    return dispatch.fused_dot(PROFILE, x, sx, w_res, bits=BITS,
                              backend="pallas_fused")


def bench_fused_chain(report):
    """The tentpole row: HBM bytes + wall-clock, fused vs unfused."""
    x, sx, w_res = _operands()
    yu = np.asarray(jax.jit(_unfused)(x, sx, w_res))
    yf = np.asarray(jax.jit(_fused)(x, sx, w_res))
    assert np.array_equal(yu, yf), "fused chain is not bit-identical"
    bu = _hbm_bytes(_unfused, x, sx, w_res)
    bf = _hbm_bytes(_fused, x, sx, w_res)
    tu = _t(jax.jit(_unfused), x, sx, w_res)
    tf = _t(jax.jit(_fused), x, sx, w_res)
    report("fused_dot_128x512x128", tf,
           f"unfused={tu:.0f}us hbm_bytes_fused={bf:.0f} "
           f"hbm_bytes_unfused={bu:.0f} bytes_ratio={bf/bu:.3f} "
           f"bit_identical=1 fused_fewer_bytes={int(bf < bu)}")
    return bf, bu


def bench_fused_encode_matmul(report):
    """Half-fusion rows: each boundary individually."""
    x, sx, w_res = _operands(seed=1)

    def unfused_em(x, sx, w_res):
        r = dispatch.convert(PROFILE, x, sx, bits=BITS, backend="pallas")
        return dispatch.matmul(PROFILE, r, w_res, backend="pallas")

    def fused_em(x, sx, w_res):
        return dispatch.fused_encode_matmul(PROFILE, x, sx, w_res, bits=BITS,
                                            backend="pallas_fused")

    a_res = jax.jit(unfused_em)(x, sx, w_res)

    def unfused_mn(a_res, w_res):
        o = dispatch.matmul(PROFILE, a_res, w_res, backend="pallas")
        return dispatch.normalize(PROFILE, o, backend="pallas")

    def fused_mn(a_res, w_res):
        return dispatch.fused_matmul_normalize(PROFILE, a_res, w_res,
                                               backend="pallas_fused")

    for tag, uf, f, args in (
            ("encode_matmul", unfused_em, fused_em, (x, sx, w_res)),
            ("matmul_normalize", unfused_mn, fused_mn, (a_res, w_res))):
        assert np.array_equal(np.asarray(jax.jit(uf)(*args)),
                              np.asarray(jax.jit(f)(*args))), tag
        bu, bf = _hbm_bytes(uf, *args), _hbm_bytes(f, *args)
        tu, tf = _t(jax.jit(uf), *args), _t(jax.jit(f), *args)
        report(f"fused_{tag}", tf,
               f"unfused={tu:.0f}us hbm_bytes_fused={bf:.0f} "
               f"hbm_bytes_unfused={bu:.0f} fused_fewer_bytes={int(bf < bu)}")


def bench_recompiles(report):
    """Ragged lengths hit ONE compiled kernel per fixed-tile wrapper."""
    from repro.core.rns import encode_int32
    from repro.kernels.rns_convert.kernel import rns_convert_tiles
    from repro.kernels.rns_convert.ops import rns_convert
    from repro.kernels.rns_normalize.kernel import rns_normalize_tiles
    from repro.kernels.rns_normalize.ops import rns_normalize

    rng = np.random.default_rng(2)
    n0 = rns_normalize_tiles._cache_size()
    c0 = rns_convert_tiles._cache_size()
    lens = (5, 40, 333, 1000, 1024)
    for L in lens:
        res = jnp.asarray(encode_int32(
            PROFILE, rng.integers(-2**20, 2**20, L).astype(np.int32)))
        rns_normalize(PROFILE, res)
        rns_convert(PROFILE,
                    jnp.asarray(rng.standard_normal(L), jnp.float32),
                    np.float32(37.5))
    dn = rns_normalize_tiles._cache_size() - n0
    dc = rns_convert_tiles._cache_size() - c0
    report("wrapper_recompiles", 0.0,
           f"ragged_lens={len(lens)} normalize_compiles={dn} "
           f"convert_compiles={dc} (1 apiece: the fixed-tile contract)")


def bench_autotune(report):
    """The measure -> persist -> reuse loop (interpret-mode smoke: wall
    times are proxies; the mechanism is what's exercised)."""
    from repro.kernels import autotune

    t0 = time.perf_counter()
    blocks = autotune.tune("rns_matmul", PROFILE, (64, 256, 64), repeats=1)
    tuned_us = (time.perf_counter() - t0) * 1e6
    hit = autotune.get_blocks("rns_matmul", PROFILE, (64, 256, 64))
    assert hit == blocks
    report("autotune_rns_matmul_64x256x64", tuned_us,
           f"blocks=bm{blocks['bm']}xbn{blocks['bn']}xbk{blocks['bk']} "
           f"cache={autotune.cache_path()}")


def bench_fused_serving(report):
    """System-level: continuous serving tokens/sec, fused vs unfused
    pallas backend (token streams asserted identical)."""
    import dataclasses

    from repro.configs.base import get_config
    from repro.core.rns_matmul import RnsDotConfig
    from repro.models import model as M
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (7, 33)]
    toks = {}
    for tag in ("pallas", "pallas_fused"):
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=64, max_new_tokens=4, page_size=16, max_seqs=2,
            rns_backend=tag))
        res, stats = eng.run(prompts)
        toks[tag] = {r: t.tolist() for r, t in res.items()}
        ops = stats["steps"][-1]["rns_ops"]
        report(f"serve_tok_s_{tag}", stats["wall_s"] * 1e6,
               f"tok_s={stats['tokens_per_s']:.1f} fused_ops={ops.fused} "
               f"fallbacks={ops.fallbacks}")
    assert toks["pallas"] == toks["pallas_fused"], "fused serve diverged"


def run_all(report):
    bench_fused_chain(report)
    bench_fused_encode_matmul(report)
    bench_recompiles(report)
    bench_autotune(report)
    bench_fused_serving(report)
