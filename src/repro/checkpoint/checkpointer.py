"""Atomic, resumable, elastic checkpointing.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json     # keys, shapes, dtypes, per-file sha256, extra meta
        data_00000.npz    # flattened leaves (chunked into <=2GB files)

Properties engineered for fleet-scale fault tolerance:
  * atomic publish: write into ``.tmp-step_X`` then ``os.rename`` — a crash
    mid-save can never produce a readable-but-corrupt step directory.
  * integrity: manifest carries sha256 per data file; ``latest_valid`` skips
    any step whose hashes mismatch (torn writes on shared filesystems).
  * async: ``save_async`` snapshots to host memory synchronously (so
    training can mutate the live buffers) and writes in a worker thread.
  * elastic restore: leaves are saved consolidated (device-gathered), so a
    restart may use ANY mesh shape — ``restore(..., shardings=...)`` lays
    the arrays out for the new topology (tested 1->8->2 devices).
"""

from __future__ import annotations

import concurrent.futures as cf
import hashlib
import json
import os
import re
import shutil

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")

# numpy .npz can't round-trip ml_dtypes (bfloat16/f8): store a bit-view and
# record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(a: np.ndarray) -> tuple[np.ndarray, str]:
    name = a.dtype.name
    if name in _VIEW_AS:
        return a.view(_VIEW_AS[name]), name
    return a, name


def _from_storable(a: np.ndarray, name: str) -> np.ndarray:
    if name in _VIEW_AS:
        import ml_dtypes

        return a.view(np.dtype(getattr(ml_dtypes, name)))
    return a


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in leaves]
    return keys, [leaf for _, leaf in leaves], jax.tree.structure(tree)


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None,
         max_bytes_per_file: int = 2 << 30) -> str:
    """Synchronous atomic save.  Returns the published directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(ckpt_dir, f".tmp-{name}")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    keys, leaves, _ = _flatten(tree)
    raw = [np.asarray(jax.device_get(x)) for x in leaves]
    stored = [_to_storable(a) for a in raw]
    arrays = [s[0] for s in stored]
    dtypes = [s[1] for s in stored]

    files = []
    cur, cur_bytes, idx = {}, 0, 0

    def flush():
        nonlocal cur, cur_bytes, idx
        if not cur:
            return
        fname = f"data_{idx:05d}.npz"
        np.savez(os.path.join(tmp, fname), **cur)
        files.append(fname)
        cur, cur_bytes = {}, 0
        idx += 1

    key_to_file = {}
    for k, a in zip(keys, arrays):
        if cur_bytes + a.nbytes > max_bytes_per_file and cur:
            flush()
        cur[k.replace("/", "__")] = a
        key_to_file[k] = f"data_{idx:05d}.npz"
        cur_bytes += a.nbytes
    flush()

    manifest = {
        "format": 1,
        "step": step,
        "extra": extra or {},
        "keys": {k: {"file": key_to_file[k],
                     "shape": list(a.shape), "dtype": d}
                 for k, a, d in zip(keys, arrays, dtypes)},
        "hashes": {f: _sha256(os.path.join(tmp, f)) for f in files},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_EXEC = cf.ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def save_async(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    """Snapshot now (device_get), write in background.  Returns a future."""
    keys, leaves, _ = _flatten(tree)
    snap = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _EXEC.submit(save, ckpt_dir, step, snap, extra)


def _is_valid(step_dir: str) -> bool:
    man = os.path.join(step_dir, "manifest.json")
    if not os.path.exists(man):
        return False
    try:
        with open(man) as f:
            manifest = json.load(f)
        for fname, want in manifest["hashes"].items():
            got = _sha256(os.path.join(step_dir, fname))
            if got != want:
                return False
        return True
    except Exception:
        return False


def latest_valid(ckpt_dir: str) -> str | None:
    """Newest step dir that passes integrity checks (corrupt ones skipped)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(
        (d for d in os.listdir(ckpt_dir) if _STEP_RE.match(d)), reverse=True)
    for d in steps:
        full = os.path.join(ckpt_dir, d)
        if _is_valid(full):
            return full
    return None


def restore(step_dir: str, like_tree, shardings=None):
    """Load into the structure of ``like_tree`` (values replaced).

    ``shardings``: optional matching tree of jax.sharding.Sharding — enables
    elastic restore onto a different mesh than the one that saved.
    """
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    cache: dict[str, dict] = {}

    def get_arr(key):
        rec = manifest["keys"][key]
        fname = rec["file"]
        if fname not in cache:
            cache[fname] = dict(np.load(os.path.join(step_dir, fname)))
        return _from_storable(cache[fname][key.replace("/", "__")],
                              rec["dtype"])

    keys, leaves, treedef = _flatten(like_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for k, ref, sh in zip(keys, leaves, shard_leaves):
        a = get_arr(k)
        assert list(a.shape) == list(ref.shape), (k, a.shape, ref.shape)
        out.append(jax.device_put(a, sh) if sh is not None else jax.device_put(a))
    return jax.tree.unflatten(treedef, out), manifest["extra"], manifest["step"]


def corrupt_for_test(step_dir: str):
    """Flip a byte in the first data file (used by fault-tolerance tests)."""
    for f in sorted(os.listdir(step_dir)):
        if f.startswith("data_"):
            p = os.path.join(step_dir, f)
            with open(p, "r+b") as fh:
                fh.seek(10)
                b = fh.read(1)
                fh.seek(10)
                fh.write(bytes([b[0] ^ 0xFF]))
            return
