"""jit'd wrapper: fused quantize + forward conversion, arbitrary shapes."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.rns_convert.kernel import rns_convert_tiles


def rns_convert(
    profile, x, scale, *, bits: int = 16, bt: int = 1024,
    interpret: bool | None = None, out_dtype=jnp.int8,
):
    """x [...] float32, scale scalar -> [K, ...] residues."""
    if interpret is None:
        interpret = dispatch.default_interpret()
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    T = flat.shape[0]
    bt_eff = min(bt, T) if T % min(bt, T) == 0 else T
    pad = (-T) % bt_eff
    if pad:
        flat = jnp.pad(flat, (0, pad))
    out = rns_convert_tiles(
        flat, jnp.asarray(scale, jnp.float32), profile=profile, bits=bits,
        bt=bt_eff, interpret=interpret, out_dtype=out_dtype,
    )
    return out[:, :T].reshape((out.shape[0],) + shape)
