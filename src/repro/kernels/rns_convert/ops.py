"""jit'd wrapper: fused quantize + forward conversion, arbitrary shapes."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.rns_convert.kernel import rns_convert_tiles


def rns_convert(
    profile, x, scale, *, bits: int = 16, bt: int | None = None,
    interpret: bool | None = None, out_dtype=jnp.int8,
):
    """x [...] float32, scale scalar or broadcastable -> [K, ...] residues.

    ``scale`` may be any shape that broadcasts against ``x`` (the
    reference rule is ``round(x * scale)``), so per-sequence quantization
    grids ([B, 1, 1] rows from mask-aware absmax) run through the kernel
    instead of falling back to the reference path.  Non-scalar scales are
    broadcast to ``x``'s shape and streamed tile-by-tile next to ``x``.

    The tile size is FIXED (``bt``) with zero-padding up to a ``bt``
    multiple — one compiled kernel per padded-size bucket, never one per
    distinct length (see rns_normalize/ops.py for the shared rationale).
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    shape = x.shape
    flat = x.reshape(-1).astype(jnp.float32)
    scale = jnp.asarray(scale, jnp.float32)
    if scale.ndim:
        scale = jnp.broadcast_to(scale, shape).reshape(-1)
    T = flat.shape[0]
    if bt is None:
        from repro.kernels import autotune

        bt = autotune.get_blocks("rns_convert", profile, (T,))["bt"]
    pad = (-T) % bt
    if pad:
        flat = jnp.pad(flat, (0, pad))
        if scale.ndim:
            scale = jnp.pad(scale, (0, pad))
    from repro.analysis.kernel_audit import check_wrapper_blocks
    from repro.core.moduli import get_profile

    p = get_profile(profile) if isinstance(profile, str) else profile
    check_wrapper_blocks(
        "rns_convert", {"bt": bt}, dims={"T": T + pad},
        n_digits=p.n_digits, res_bytes=jnp.dtype(out_dtype).itemsize)
    out = rns_convert_tiles(
        flat, scale, profile=profile, bits=bits,
        bt=bt, interpret=interpret, out_dtype=out_dtype,
    )
    return out[:, :T].reshape((out.shape[0],) + shape)
