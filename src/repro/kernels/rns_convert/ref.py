"""Pure-jnp oracle for the forward-conversion kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.rns import encode_int32


def rns_convert_ref(x, scale, *, profile, bits: int = 16, out_dtype=jnp.int8):
    """x [T] float32 -> [K, T] residues of clip(round(x*scale))."""
    qmax = 2 ** (bits - 1) - 1
    v = jnp.clip(jnp.round(x * scale), -qmax, qmax).astype(jnp.int32)
    return encode_int32(profile, v).astype(out_dtype)
