"""Pallas TPU kernel: forward conversion pipeline (binary -> residues).

Fuses the fixed-point quantize (round(x * s), clip) with the per-digit
modular reduction, emitting int8 digit planes ready for the digit-slice
matmul array.  This is the input half of the paper's purple conversion
pipeline; it is O(K) PAC work per element (cheap), unlike the reverse
direction's O(K^2) MRC.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compiler_params

from repro.core.rns import tables


def _kernel(x_ref, s_ref, o_ref, *, profile, qmax: int, per_elem: bool):
    t = tables(profile)
    x = x_ref[...]
    # per_elem: a [bt] scale tile rides next to the x tile (per-sequence
    # quantization grids broadcast to elements); else one scalar in VMEM
    s = s_ref[...] if per_elem else s_ref[0, 0]
    v = jnp.clip(jnp.round(x * s), -qmax, qmax).astype(jnp.int32)
    for j, m in enumerate(t.moduli):
        o_ref[j] = jnp.remainder(v, jnp.int32(int(m))).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("profile", "bits", "bt", "interpret", "out_dtype")
)
def rns_convert_tiles(
    x, scale, *, profile, bits: int = 16, bt: int = 1024,
    interpret: bool = False, out_dtype=jnp.int8,
):
    """x [T] float32, scale scalar or [T] -> [K, T] residues."""
    t = tables(profile)
    K = t.profile.n_digits
    (T,) = x.shape
    grid = (T // bt,)
    per_elem = scale.ndim > 0
    s_spec = (pl.BlockSpec((bt,), lambda i: (i,)) if per_elem
              else pl.BlockSpec((1, 1), lambda i: (0, 0)))
    return pl.pallas_call(
        functools.partial(_kernel, profile=profile, qmax=2 ** (bits - 1) - 1,
                          per_elem=per_elem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            s_spec,
        ],
        out_specs=pl.BlockSpec((K, bt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((K, T), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, scale if per_elem else scale.reshape(1, 1))
