"""Pure-jnp oracle for the normalization kernel: core mrc.decode_float."""

from __future__ import annotations

from repro.core import mrc


def rns_normalize_ref(x, *, profile):
    """x [K, T] int32 -> [T] float32 signed values (unscaled)."""
    return mrc.decode_float(profile, x, inv_scale=1.0)
