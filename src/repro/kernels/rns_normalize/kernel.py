"""Pallas TPU kernel: fused normalization unit (Fig. 5's purple pipeline).

Takes [K, T] residues, emits [T] float32 values: sign detection + mixed-
radix conversion + float reconstruction, all in VMEM.  Every modular
constant (m_j, MRC inverses, M/2 digits, W_j weights) is compiled into the
kernel — the hardware analogue is the fixed normalization pipeline the
paper sandwiches after the accumulator array.

The MRC is the paper's "slow" O(K^2) op; it runs ONCE per output element
(deferred normalization), so its cost is amortized over the whole product
summation that produced the element.

The tile-level helpers (:func:`mrc_digit_rows`, :func:`lex_ge`,
:func:`mrc_float_tile`) are shape-agnostic — they operate on a python
list of K same-shape residue blocks — so the fused matmul kernels
(kernels/rns_fused) run the SAME reconstruction on their [bm, bn]
accumulator tiles, which is what makes fused and unfused normalization
bit-identical.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compiler_params

from repro.core.rns import tables


def mrc_digit_rows(rows, t):
    """rows: list of K same-shape int32 blocks -> list of K digit blocks."""
    K = len(rows)
    ms = [int(m) for m in t.moduli]
    r = list(rows)
    digits = []
    for i in range(K):
        d = r[i]
        digits.append(d)
        for j in range(i + 1, K):
            inv = int(t.mrc_inv[i, j])
            r[j] = jnp.remainder((r[j] - d) * inv, ms[j])
    return digits


def lex_ge(digits, ref_digits):
    """Lexicographic (most-significant-last) digits >= ref (elementwise)."""
    K = len(digits)
    ge = jnp.zeros_like(digits[0], dtype=jnp.bool_)
    eq = jnp.ones_like(digits[0], dtype=jnp.bool_)
    for j in range(K - 1, -1, -1):
        ref = jnp.int32(int(ref_digits[j]))
        ge = ge | (eq & (digits[j] > ref))
        eq = eq & (digits[j] == ref)
    return ge | eq


def mrc_float_tile(rows, t):
    """Two-pass MRC + float32 reconstruction of K residue blocks.

    Pass 1 detects the sign (X >= M/2 <=> negative), pass 2 re-runs the
    MRC on the magnitude so the float reconstruction never cancels
    against M.  Accumulation order (digit-ascending, float32) is the
    contract shared with core/mrc.decode_float — keep them in lockstep.
    """
    K = len(rows)
    ms = [int(m) for m in t.moduli]
    digits = mrc_digit_rows(rows, t)
    neg = lex_ge(digits, t.half_digits)
    mag = [
        jnp.where(neg, jnp.remainder(jnp.int32(ms[j]) - rows[j], ms[j]), rows[j])
        for j in range(K)
    ]
    mdig = mrc_digit_rows(mag, t)
    acc = jnp.zeros(rows[0].shape, dtype=jnp.float32)
    for j in range(K):
        acc = acc + mdig[j].astype(jnp.float32) * jnp.float32(float(t.W_f64[j]))
    return jnp.where(neg, -acc, acc)


def _kernel(x_ref, o_ref, *, profile):
    t = tables(profile)
    K = t.profile.n_digits
    rows = [x_ref[j][None, :] for j in range(K)]
    o_ref[...] = mrc_float_tile(rows, t)[0]


@functools.partial(jax.jit, static_argnames=("profile", "bt", "interpret"))
def rns_normalize_tiles(x, *, profile, bt: int = 1024, interpret: bool = False):
    """x [K, T] int32 residues -> [T] float32 signed values (unscaled)."""
    K, T = x.shape
    grid = (T // bt,)
    return pl.pallas_call(
        functools.partial(_kernel, profile=profile),
        grid=grid,
        in_specs=[pl.BlockSpec((K, bt), lambda i: (0, i))],
        out_specs=pl.BlockSpec((bt,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((T,), jnp.float32),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x)
