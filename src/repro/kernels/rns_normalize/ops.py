"""jit'd wrapper: arbitrary-shape residues -> float values via the kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.rns_normalize.kernel import rns_normalize_tiles


def rns_normalize(profile, res, *, bt: int = 1024, interpret: bool | None = None):
    """res [K, ...] int32 -> [...] float32 signed values (unscaled)."""
    if interpret is None:
        interpret = dispatch.default_interpret()
    K = res.shape[0]
    shape = res.shape[1:]
    flat = res.reshape(K, -1)
    T = flat.shape[1]
    bt_eff = min(bt, T) if T % min(bt, T) == 0 else T
    pad = (-T) % bt_eff
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = rns_normalize_tiles(flat, profile=profile, bt=bt_eff, interpret=interpret)
    return out[:T].reshape(shape)
