"""jit'd wrapper: arbitrary-shape residues -> float values via the kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.rns_normalize.kernel import rns_normalize_tiles


def rns_normalize(profile, res, *, bt: int | None = None,
                  interpret: bool | None = None):
    """res [K, ...] int32 -> [...] float32 signed values (unscaled).

    The tile size is FIXED (``bt``, autotuner default 1024) and ``T`` is
    zero-padded up to a ``bt`` multiple: every length in a padded-size
    bucket shares one compiled kernel (``rns_normalize_tiles._cache_size()``
    stays 1 across ragged lengths), and VMEM block size is bounded by
    ``bt`` no matter how large the tensor is.  The old behaviour —
    collapsing the tile to ``T`` whenever ``T % bt != 0`` — compiled one
    whole-array VMEM block (unbounded VMEM at large T) and a fresh kernel
    per distinct length.
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    K = res.shape[0]
    shape = res.shape[1:]
    flat = res.reshape(K, -1)
    T = flat.shape[1]
    if bt is None:
        from repro.kernels import autotune

        bt = autotune.get_blocks("rns_normalize", profile, (T,))["bt"]
    pad = (-T) % bt
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    from repro.analysis.kernel_audit import check_wrapper_blocks

    check_wrapper_blocks("rns_normalize", {"bt": bt}, dims={"T": T + pad},
                         n_digits=K)
    out = rns_normalize_tiles(flat, profile=profile, bt=bt, interpret=interpret)
    return out[:T].reshape(shape)
