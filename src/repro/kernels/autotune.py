"""Block-size autotuner for the Pallas kernel wrappers.

The kernel wrappers used to hardcode their tile sizes (``bm/bn/bk`` for
the matmul-shaped kernels, ``bt`` for the elementwise pipelines).  Good
tiles depend on the machine and on the problem shape, so the wrappers now
resolve ``None`` block arguments here:

  * lookups are keyed on ``(kind, profile, shape-bucket, backend)`` —
    shapes are bucketed to powers of two, so a serving engine cycling
    through ragged batch sizes hits ONE cache row per bucket;
  * tuned rows persist to a JSON cache (``REPRO_AUTOTUNE_CACHE`` or
    ``~/.cache/repro_rns/autotune.json``) so a machine is measured once;
  * :func:`get_blocks` NEVER measures — it returns the tuned row or the
    defaults.  Measurement is the explicit :func:`tune` call (run it from
    ``benchmarks/bench_kernels.py`` or offline); keeping timing out of
    the hot path means trace-time lookups stay pure python.

Cache file format (versioned)::

    {"version": 1,
     "entries": {"rns_matmul|rns9|128x512x128|cpu":
                 {"blocks": {"bm": 128, "bn": 128, "bk": 512},
                  "us": 123.4}}}
"""

# lint-ok-file: host-in-jit (the autotuner times candidate tiles on the
# host BY DESIGN; get_blocks keeps measurement off the traced hot path)

from __future__ import annotations

import json
import logging
import os
import threading
import time

import jax

_log = logging.getLogger(__name__)

__all__ = ["get_blocks", "tune", "shape_bucket", "pow2_at_least",
           "cache_path", "clear_cache", "DEFAULTS", "CANDIDATES"]

_MATMUL_DEFAULTS = {"bm": 128, "bn": 128, "bk": 512}
_TILE_DEFAULTS = {"bt": 1024}

#: per-kernel-kind hardcoded fallbacks (what the wrappers shipped with)
DEFAULTS: dict[str, dict[str, int]] = {
    "rns_matmul": _MATMUL_DEFAULTS,
    "rns_fused_encode_matmul": _MATMUL_DEFAULTS,
    "rns_fused_matmul_normalize": _MATMUL_DEFAULTS,
    "rns_fused_dot": _MATMUL_DEFAULTS,
    "rns_convert": _TILE_DEFAULTS,
    "rns_normalize": _TILE_DEFAULTS,
    "flash_attention": {"bq": 128, "bk": 128},
}

#: the search space :func:`tune` sweeps.  bm/bn stay MXU-aligned
#: multiples of the sublane/lane tile; bk trades VMEM residency against
#: modular-reduction frequency (every step is one ``rem``).
CANDIDATES: dict[str, list[dict[str, int]]] = {
    "rns_matmul": [
        {"bm": bm, "bn": bn, "bk": bk}
        for bm in (64, 128) for bn in (128, 256) for bk in (256, 512)
    ],
    "rns_convert": [{"bt": t} for t in (512, 1024, 2048)],
    "rns_normalize": [{"bt": t} for t in (256, 512, 1024)],
    "flash_attention": [
        {"bq": q, "bk": k} for q in (64, 128) for k in (128, 256)
    ],
}
for _kind in ("rns_fused_encode_matmul", "rns_fused_matmul_normalize",
              "rns_fused_dot"):
    CANDIDATES[_kind] = CANDIDATES["rns_matmul"]

_lock = threading.Lock()
_cache: dict[str, dict] | None = None      # loaded lazily, saved on tune


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_rns",
                     "autotune.json"))


def pow2_at_least(n: int, lo: int = 8) -> int:
    """Smallest power of two >= max(n, lo) — THE bucketing rule, shared
    with the wrappers' M padding so tuned rows land on the exact buckets
    the kernels compile for."""
    p = lo
    while p < n:
        p <<= 1
    return p


def shape_bucket(shape) -> tuple[int, ...]:
    """Power-of-two bucket per dim — the recompile-granularity the
    wrappers already pad to, so one tuned row covers the bucket."""
    return tuple(pow2_at_least(int(d), 8) for d in shape)


def _backend_tag(backend: str | None) -> str:
    return backend or jax.default_backend()


def _key(kind: str, profile, shape, backend: str | None) -> str:
    name = getattr(profile, "name", profile)
    dims = "x".join(str(d) for d in shape_bucket(shape))
    return f"{kind}|{name}|{dims}|{_backend_tag(backend)}"


def _valid_entry(entry) -> bool:
    """A cache row the wrappers can actually consume: a dict whose
    ``blocks`` maps known tile names to positive ints.  Anything else —
    hand-edited files, partial writes, rows from a future format —
    is dropped at load time so a poisoned cache can never push a
    non-integer (or absurd) tile size into a kernel launch."""
    if not isinstance(entry, dict) or not isinstance(entry.get("blocks"), dict):
        return False
    names = {n for d in DEFAULTS.values() for n in d}
    return all(
        isinstance(k, str) and k in names
        and isinstance(v, int) and not isinstance(v, bool) and v > 0
        for k, v in entry["blocks"].items())


def _row_violations(key: str, entry: dict) -> list[str]:
    """Mosaic/VMEM legality of a structurally-valid cache row.

    The audit kind and profile are parsed back out of the cache key, so
    the sublane rule sees the right residue width (int8 profiles need
    32-row tiles).  Unparseable metadata degrades to the conservative
    f32/int32 model rather than crashing the load path."""
    from repro.analysis.kernel_audit import _profile_meta, validate_blocks

    parts = key.split("|")
    kind = parts[0]
    if kind not in DEFAULTS:
        return [f"unknown kernel kind {kind!r}"]
    try:
        n_digits, res_bytes = _profile_meta(
            kind, parts[1] if len(parts) > 1 else None)
    except Exception:
        n_digits, res_bytes = 1, 4
    return validate_blocks(kind, dict(DEFAULTS[kind], **entry["blocks"]),
                           n_digits=n_digits, res_bytes=res_bytes)


def _load() -> dict[str, dict]:
    global _cache
    with _lock:
        if _cache is None:
            _cache = {}
            # Corruption tolerance: a missing/unreadable file, invalid
            # JSON, a non-dict top level, a version mismatch, or junk
            # rows must all degrade to "no tuned entries" (the wrappers
            # fall back to DEFAULTS) — never crash a serving process over
            # a cache file.  The next tune() rewrites the file whole.
            try:
                with open(cache_path()) as f:
                    data = json.load(f)
                if isinstance(data, dict) and data.get("version") == 1:
                    entries = data.get("entries")
                    if isinstance(entries, dict):
                        _cache = {k: v for k, v in entries.items()
                                  if isinstance(k, str) and _valid_entry(v)}
            except (OSError, ValueError, TypeError):
                pass
            # Legality self-heal: a structurally-fine row whose blocks
            # are Mosaic-illegal or VMEM-over-budget (hand-edited file,
            # tuned on a machine with different limits) is dropped with
            # a logged reason — the wrappers fall back to DEFAULTS.
            for k in list(_cache):
                bad = _row_violations(k, _cache[k])
                if bad:
                    _log.warning(
                        "autotune: dropping illegal cache row %s "
                        "(blocks %s): %s — self-healing to DEFAULTS",
                        k, _cache[k].get("blocks"), bad[0])
                    del _cache[k]
        return _cache


def _save() -> None:
    path = cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with _lock:
        data = {"version": 1, "entries": _cache or {}}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def clear_cache() -> None:
    """Drop the in-memory table (tests repoint REPRO_AUTOTUNE_CACHE)."""
    global _cache
    with _lock:
        _cache = None


def get_blocks(kind: str, profile, shape, backend: str | None = None
               ) -> dict[str, int]:
    """Tuned blocks for this (kind, profile, shape-bucket, backend), or
    the hardcoded defaults.  Pure lookup — never measures."""
    out = dict(DEFAULTS[kind])
    entry = _load().get(_key(kind, profile, shape, backend))
    if entry:
        out.update(entry["blocks"])
    return out


def tune(kind: str, profile, shape, backend: str | None = None, *,
         bench_fn=None, repeats: int = 3) -> dict[str, int]:
    """Measure the candidate tilings and persist the winner.

    ``bench_fn(blocks) -> seconds`` overrides the built-in micro-bench
    (tests inject a deterministic cost model; CPU-interpret smoke runs
    exercise the full measure→persist path even though interpreter wall
    times are only a proxy for real-TPU tile quality).
    """
    from repro.analysis.kernel_audit import _profile_meta, validate_blocks

    try:
        n_digits, res_bytes = _profile_meta(
            kind, getattr(profile, "name", profile))
    except Exception:
        n_digits, res_bytes = 1, 4
    legal = []
    for cand in CANDIDATES[kind]:
        bad = validate_blocks(kind, dict(DEFAULTS[kind], **cand),
                              n_digits=n_digits, res_bytes=res_bytes)
        if bad:
            _log.warning("autotune: skipping illegal candidate %s for "
                         "%s: %s", cand, kind, bad[0])
        else:
            legal.append(cand)
    if not legal:
        _log.warning("autotune: no legal candidates for %s — keeping "
                     "DEFAULTS untuned", kind)
        return dict(DEFAULTS[kind])
    if bench_fn is None:
        bench_fn = _default_bench(kind, profile, shape, backend)
    best, best_t = None, None
    for cand in legal:
        t = min(bench_fn(dict(cand)) for _ in range(repeats))
        if best_t is None or t < best_t:
            best, best_t = dict(cand), t
    entries = _load()
    with _lock:
        entries[_key(kind, profile, shape, backend)] = {
            "blocks": best, "us": float(best_t * 1e6)}
    _save()
    return dict(DEFAULTS[kind], **best)


def _default_bench(kind: str, profile, shape, backend: str | None):
    """Wall-clock micro-bench of the real wrapper on random operands."""
    import numpy as np

    rng = np.random.default_rng(0)

    if kind == "flash_attention":
        from repro.kernels.flash_attention.ops import flash_attention

        # ``profile`` is the dtype tag here — flash has no RNS profile.
        Tq, Tk, Dh = shape
        q = jax.numpy.asarray(
            rng.standard_normal((1, Tq, 4, Dh)).astype(np.float32))
        kv = jax.numpy.asarray(
            rng.standard_normal((1, Tk, 4, Dh)).astype(np.float32))

        def run(blocks):
            return flash_attention(q, kv, kv, **blocks)

        def bench(blocks) -> float:
            jax.block_until_ready(run(blocks))   # compile off the clock
            t0 = time.perf_counter()
            jax.block_until_ready(run(blocks))
            return time.perf_counter() - t0

        return bench

    from repro.core.moduli import get_profile
    from repro.core.rns import encode_int32

    p = get_profile(profile) if isinstance(profile, str) else profile

    if kind in ("rns_convert", "rns_normalize"):
        (T,) = shape
        if kind == "rns_convert":
            from repro.kernels.rns_convert.ops import rns_convert

            x = jax.numpy.asarray(
                rng.standard_normal(T).astype(np.float32))

            def run(blocks):
                return rns_convert(p.name, x, np.float32(37.5), **blocks)
        else:
            from repro.kernels.rns_normalize.ops import rns_normalize

            res = jax.numpy.asarray(encode_int32(
                p, rng.integers(-2**20, 2**20, T).astype(np.int32)))

            def run(blocks):
                return rns_normalize(p.name, res, **blocks)
    else:
        M, D, N = shape
        a = rng.integers(-2**11, 2**11, (M, D)).astype(np.int32)
        b = rng.integers(-2**11, 2**11, (D, N)).astype(np.int32)
        ra = jax.numpy.asarray(encode_int32(p, a))
        rb = jax.numpy.asarray(encode_int32(p, b))
        if kind == "rns_matmul":
            from repro.kernels.rns_matmul.ops import rns_matmul

            def run(blocks):
                return rns_matmul(p.name, ra, rb, **blocks)
        elif kind == "rns_fused_matmul_normalize":
            from repro.kernels.rns_fused.ops import rns_fused_matmul_normalize

            def run(blocks):
                return rns_fused_matmul_normalize(p.name, ra, rb, **blocks)
        else:
            from repro.kernels.rns_fused.ops import (
                rns_fused_dot, rns_fused_encode_matmul)

            xf = jax.numpy.asarray(a.astype(np.float32))
            s = np.float32(1.0)
            fn = (rns_fused_dot if kind == "rns_fused_dot"
                  else rns_fused_encode_matmul)

            def run(blocks):
                return fn(p.name, xf, s, rb, **blocks)

    def bench(blocks) -> float:
        jax.block_until_ready(run(blocks))       # compile outside the clock
        t0 = time.perf_counter()
        jax.block_until_ready(run(blocks))
        return time.perf_counter() - t0

    return bench
