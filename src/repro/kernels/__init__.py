# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas compatibility helpers for the kernel packages."""

from jax.experimental.pallas import tpu as _pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
compiler_params = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams"
)
