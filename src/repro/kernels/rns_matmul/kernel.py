"""Pallas TPU kernel: digit-sliced modular matmul (the RNS-TPU matrix unit).

One grid slot per (digit slice, M tile, N tile, K step).  Each digit slice is
an independent "layer" of the paper's Fig. 5 — an int8 MXU matmul with a
modular reduction folded into the accumulator ("fixed MOD ... inserted as a
final step just after accumulation", which the paper identifies as the
TPU-compatible option).  Residues < 128 keep every int8 product < 2**14, so
a K-step partial sum of up to bk<=2**17 terms plus the carried accumulator
stays inside int32 — the lazy-reduction guarantee.

BlockSpec tiling: (bm, bk) x (bk, bn) VMEM tiles, MXU-aligned (128x128
output tile, 512-deep K streaming), int32 accumulator scratch in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compiler_params


def _kernel(m_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0].astype(jnp.int32)          # [bm, bk]
    b = b_ref[0].astype(jnp.int32)          # [bk, bn]
    prod = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    m = m_ref[0, 0]
    # lazy modular reduction: one rem per K step keeps the carry < m
    acc_ref[...] = jnp.remainder(acc_ref[...] + prod, m)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def rns_matmul_tiles(
    moduli, a_res, b_res, *, bm: int = 128, bn: int = 128, bk: int = 512,
    interpret: bool = False,
):
    """a_res [S, M, D] int8/int32, b_res [S, D, N] -> [S, M, N] int32.

    M, N, D must be multiples of (bm, bn, bk); ops.py pads (zero padding is
    exact: zeros contribute nothing to the product-sum mod m).
    """
    S, M, D = a_res.shape
    _, _, N = b_res.shape
    n_k = D // bk
    grid = (S, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, i, j, k: (s, 0)),
            pl.BlockSpec((1, bm, bk), lambda s, i, j, k: (s, i, k)),
            pl.BlockSpec((1, bk, bn), lambda s, i, j, k: (s, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, k: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((S, M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(moduli.reshape(-1, 1), a_res, b_res)
