"""Pure-jnp oracle for the digit-sliced modular matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def rns_matmul_ref(moduli, a_res, b_res):
    """a_res [S, M, D], b_res [S, D, N] int residues -> [S, M, N] int32.

    Straight modular einsum with int32 accumulation; the chunking concern
    (int32 overflow past ~131k terms for 7-bit moduli) is the caller's —
    same contract as the kernel (D <= lazy_chunk per K block is guaranteed
    by construction because each bk-step is reduced).
    """
    m = jnp.asarray(moduli, jnp.int32).reshape(-1, 1, 1)
    mmax = int(max(int(x) for x in jnp.asarray(moduli)))
    chunk = (2**31 - 1) // (mmax - 1) ** 2
    D = a_res.shape[-1]
    acc = None
    for c in range(-(-D // chunk)):
        sl = slice(c * chunk, min((c + 1) * chunk, D))
        part = jnp.einsum(
            "smd,sdn->smn",
            a_res[..., sl].astype(jnp.int32),
            b_res[:, sl, :].astype(jnp.int32),
            preferred_element_type=jnp.int32,
        )
        part = jnp.remainder(part, m)
        acc = part if acc is None else jnp.remainder(acc + part, m)
    return acc
