"""jit'd public wrapper for the RNS matmul kernel (padding + batching)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.rns import tables
from repro.kernels.rns_matmul.kernel import rns_matmul_tiles


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def rns_matmul(
    profile, a_res, b_res, *, bm: int = 128, bn: int = 128, bk: int = 512,
    interpret: bool | None = None,
):
    """a_res [K, ..., M, D], b_res [K, D, N] residues -> [K, ..., M, N] int32.

    Zero-pads every dim to the BlockSpec tile multiples (exact: zero
    residues contribute nothing mod m) and flattens leading batch dims.
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    t = tables(profile)
    moduli = jnp.asarray(np.asarray(t.moduli, np.int32))
    S = a_res.shape[0]
    D = a_res.shape[-1]
    N = b_res.shape[-1]
    lead = a_res.shape[1:-1]
    a2 = a_res.reshape(S, -1, D)
    M = a2.shape[1]
    bm_eff = min(bm, max(8, M))
    a2 = _pad_to(_pad_to(a2, 1, bm_eff), 2, bk)
    b2 = _pad_to(_pad_to(b_res, 1, bk), 2, bn)
    out = rns_matmul_tiles(
        moduli, a2, b2, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    out = out[:, :M, :N]
    return out.reshape((S,) + lead + (N,))
