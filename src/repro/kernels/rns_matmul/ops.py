"""jit'd public wrapper for the RNS matmul kernel (padding + batching)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.rns import tables
from repro.kernels.autotune import pow2_at_least as _pow2_at_least
from repro.kernels.rns_matmul.kernel import rns_matmul_tiles


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def rns_matmul(
    profile, a_res, b_res, *, bm: int | None = None, bn: int | None = None,
    bk: int | None = None, interpret: bool | None = None,
):
    """a_res [K, ..., M, D], b_res [K, D, N] residues -> [K, ..., M, N] int32.

    Zero-pads every dim to the BlockSpec tile multiples (exact: zero
    residues contribute nothing mod m) and flattens leading batch dims.

    The M tile is always a multiple of 8 (TPU sublanes — ``min(bm, M)``
    alone produced Mosaic-illegal block shapes that only ran in interpret
    mode) and M is bucketed to the next power of two: mixed-batch callers
    whose row counts land in one bucket reuse ONE compiled kernel instead
    of keying a recompile on every distinct M.
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    t = tables(profile)
    if bm is None or bn is None or bk is None:
        from repro.kernels import autotune

        blk = autotune.get_blocks(
            "rns_matmul", t.profile.name,
            (int(np.prod(a_res.shape[1:-1], dtype=np.int64)),
             a_res.shape[-1], b_res.shape[-1]))
        bm = bm if bm is not None else blk["bm"]
        bn = bn if bn is not None else blk["bn"]
        bk = bk if bk is not None else blk["bk"]
    moduli = jnp.asarray(np.asarray(t.moduli, np.int32))
    S = a_res.shape[0]
    D = a_res.shape[-1]
    N = b_res.shape[-1]
    lead = a_res.shape[1:-1]
    a2 = a_res.reshape(S, -1, D)
    M = a2.shape[1]
    bm_eff = min(bm, _pow2_at_least(M))
    a2 = _pad_to(_pad_to(a2, 1, bm_eff), 2, bk)
    b2 = _pad_to(_pad_to(b_res, 1, bk), 2, bn)
    from repro.analysis.kernel_audit import check_wrapper_blocks

    check_wrapper_blocks(
        "rns_matmul", {"bm": bm_eff, "bn": bn, "bk": bk},
        dims={"M": a2.shape[1], "D": a2.shape[2], "N": b2.shape[2]},
        n_digits=S, res_bytes=a2.dtype.itemsize)
    out = rns_matmul_tiles(
        moduli, a2, b2, bm=bm_eff, bn=bn, bk=bk, interpret=interpret
    )
    out = out[:, :M, :N]
    return out.reshape((S,) + lead + (N,))
