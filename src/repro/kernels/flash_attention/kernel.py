"""Pallas TPU kernel: fused online-softmax attention (inference forward).

The jnp flash path (models/attention.py) tiles q x kv at the XLA level;
this kernel fuses the whole online-softmax pipeline into one VMEM-resident
loop per q tile — no scores/probs ever reach HBM.  Used by the serving
path; training keeps the differentiable jnp formulation.

Grid: (batch*heads, Tq/bq, Tk/bk), KV innermost ("arbitrary" semantics);
BlockSpec tiles are MXU-aligned; running max/denominator/accumulator live
in VMEM scratch across KV steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_k: int, bq: int, bk: int, causal: bool, scale: float,
            tk_valid: int):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # [bq, D]
    k = k_ref[0].astype(jnp.float32)            # [bk, D]
    v = v_ref[0].astype(jnp.float32)            # [bk, Dv]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                    # [bq, bk]

    qi = pl.program_id(1)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = kpos < tk_valid                      # padded KV tail
    if causal:
        valid = valid & (qpos >= kpos)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "bq", "bk", "tk_valid", "interpret"))
def flash_attention_bhtd(q, k, v, *, causal: bool, tk_valid: int,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = False):
    """q [BH, Tq, D], k/v [BH, Tk, D(v)] -> out [BH, Tq, Dv].

    Tq % bq == 0 and Tk % bk == 0 (ops.py pads; tk_valid masks the pad).
    """
    BH, Tq, D = q.shape
    _, Tk, Dv = v.shape
    n_k = Tk // bk
    grid = (BH, Tq // bq, n_k)
    scale = float(1.0 / np.sqrt(D))
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, bq=bq, bk=bk, causal=causal,
                          scale=scale, tk_valid=tk_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
