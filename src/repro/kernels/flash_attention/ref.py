"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool, tk_valid: int):
    """q [BH,Tq,D], k/v [BH,Tk,Dv] -> [BH,Tq,Dv]; masked softmax attention."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(q.shape[-1])
    Tq, Tk = s.shape[-2], s.shape[-1]
    valid = (jnp.arange(Tk) < tk_valid)[None, :]
    if causal:
        valid = valid & (jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :])
    s = jnp.where(valid[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
