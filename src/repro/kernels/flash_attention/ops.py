"""jit'd wrapper: model-layout GQA attention through the Pallas kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import dispatch
from repro.kernels.flash_attention.kernel import flash_attention_bhtd


def flash_attention(q, k, v, *, causal: bool = True, bq: int | None = None,
                    bk: int | None = None, interpret: bool | None = None):
    """q [B,Tq,H,D], k/v [B,Tk,Hk,D(v)] (GQA) -> [B,Tq,H,Dv].

    ``None`` block sizes resolve through kernels/autotune.py (kind
    ``flash_attention``, keyed on the dtype tag instead of an RNS
    profile); the resolved config is gated by the static legality
    checker before lowering (see analysis/kernel_audit.py).
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    B, Tq, H, D = q.shape
    _, Tk, Hk, Dv = v.shape
    if bq is None or bk is None:
        from repro.kernels import autotune

        blk = autotune.get_blocks("flash_attention", str(q.dtype),
                                  (Tq, Tk, D))
        bq = bq if bq is not None else blk["bq"]
        bk = bk if bk is not None else blk["bk"]
    G = H // Hk
    # expand KV heads to match q heads (GQA)
    k = jnp.repeat(k, G, axis=2)
    v = jnp.repeat(v, G, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, Tk, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, Tk, Dv)
    bq_eff = min(bq, Tq)
    bk_eff = min(bk, Tk)
    pq = (-Tq) % bq_eff
    pk = (-Tk) % bk_eff
    if pq:
        qb = jnp.pad(qb, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kb = jnp.pad(kb, ((0, 0), (0, pk), (0, 0)))
        vb = jnp.pad(vb, ((0, 0), (0, pk), (0, 0)))
    from repro.analysis.kernel_audit import check_wrapper_blocks

    check_wrapper_blocks(
        "flash_attention", {"bq": bq_eff, "bk": bk_eff},
        dims={"Tq": Tq + pq, "Tk": Tk + pk, "D": D, "Dv": Dv})
    out = flash_attention_bhtd(qb, kb, vb, causal=causal, tk_valid=Tk,
                               bq=bq_eff, bk=bk_eff, interpret=interpret)
    out = out[:, :Tq].reshape(B, H, Tq, Dv).transpose(0, 2, 1, 3)
    return out
