"""Pure-jnp oracles: the UNFUSED convert -> matmul -> normalize chain.

The fused kernels' exactness contract is "bit-identical to running the
three stages separately", so the oracles are literally the composition of
the stage references — no independent math to drift."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import mrc
from repro.core.quantize import quantize_with_scale
from repro.core.rns import encode_int32
from repro.core.rns_matmul import rns_matmul_res


def rns_fused_encode_matmul_ref(profile, x, scale, b_res, *, bits: int = 16):
    """convert(x, scale) -> matmul: [K, ..., N] int32 residues."""
    res = encode_int32(profile, quantize_with_scale(x, scale, bits))
    return rns_matmul_res(profile, res, b_res)


def rns_fused_matmul_normalize_ref(profile, a_res, b_res):
    """matmul -> normalize: [..., N] float32 signed values (unscaled)."""
    out = rns_matmul_res(profile, a_res, b_res)
    return mrc.decode_float(profile, out, inv_scale=1.0, dtype=jnp.float32)


def rns_fused_dot_ref(profile, x, scale, b_res, *, bits: int = 16):
    """The full chain: convert -> matmul -> normalize."""
    out = rns_fused_encode_matmul_ref(profile, x, scale, b_res, bits=bits)
    return mrc.decode_float(profile, out, inv_scale=1.0, dtype=jnp.float32)
