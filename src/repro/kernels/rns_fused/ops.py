"""jit-friendly wrappers for the fused kernels (padding + batching).

Shared conventions with the unfused wrappers:

  * leading batch dims flatten into M; every dim zero-pads to its tile
    multiple (exact — zero rows quantize to zero residues);
  * the M tile is bucketed to a power of two >= 8 (Mosaic sublane
    legality + one compile per bucket, not per distinct M);
  * ``None`` block sizes resolve through kernels/autotune.py.

Scale layout: ``scale`` may be a scalar or anything that broadcasts to
``x.shape[:-1] + (1,)`` — i.e. at most one scale per ROW of the flattened
[M, D] activation (the per-sequence grids of ragged prefill).  Per-column
grids cannot fold into a row operand; core/dispatch.py guards that and
decomposes instead.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.rns import tables
from repro.kernels.rns_fused.kernel import (
    rns_fused_dot_tiles,
    rns_fused_encode_matmul_tiles,
    rns_fused_matmul_normalize_tiles,
)
from repro.kernels.rns_matmul.ops import _pad_to, _pow2_at_least


def _blocks(kind, t, shape, bm, bn, bk):
    if bm is None or bn is None or bk is None:
        from repro.kernels import autotune

        blk = autotune.get_blocks(kind, t.profile.name, shape)
        bm = bm if bm is not None else blk["bm"]
        bn = bn if bn is not None else blk["bn"]
        bk = bk if bk is not None else blk["bk"]
    return bm, bn, bk


def _check_blocks(kind, bm_eff, bn, bk, dims, n_digits, res_bytes):
    """Fail fast (kernel + blocks + VMEM bytes named) before lowering."""
    from repro.analysis.kernel_audit import check_wrapper_blocks

    check_wrapper_blocks(kind, {"bm": bm_eff, "bn": bn, "bk": bk},
                         dims=dims, n_digits=n_digits, res_bytes=res_bytes)


def _prep_activation(x, scale, bm_eff, bk):
    """Flatten x to padded [Mp, Dp] and scale to padded [Mp, 1] rows."""
    lead = x.shape[:-1]
    D = x.shape[-1]
    x2 = x.reshape(-1, D).astype(jnp.float32)
    M = x2.shape[0]
    s = jnp.asarray(scale, jnp.float32)
    s2 = jnp.broadcast_to(s, lead + (1,)).reshape(M, 1) if s.ndim else (
        jnp.broadcast_to(s, (M, 1)))
    x2 = _pad_to(_pad_to(x2, 0, bm_eff), 1, bk)
    s2 = _pad_to(s2, 0, bm_eff)
    return x2, s2, M, lead


def rns_fused_encode_matmul(
    profile, x, scale, b_res, *, bits: int = 16, bm: int | None = None,
    bn: int | None = None, bk: int | None = None,
    interpret: bool | None = None,
):
    """x [..., D] f32 + scale rows + b_res [K, D, N] -> [K, ..., N] int32.

    Bit-identical to ``convert(x, scale)`` -> ``matmul`` without the
    [K, ..., D] activation-residue round-trip through HBM.
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    t = tables(profile)
    D = x.shape[-1]
    N = b_res.shape[-1]
    bm, bn, bk = _blocks("rns_fused_encode_matmul", t,
                         (int(np.prod(x.shape[:-1], dtype=np.int64)), D, N),
                         bm, bn, bk)
    moduli = jnp.asarray(np.asarray(t.moduli, np.int32))
    bm_eff = min(bm, _pow2_at_least(x.reshape(-1, D).shape[0]))
    x2, s2, M, lead = _prep_activation(x, scale, bm_eff, bk)
    b2 = _pad_to(_pad_to(b_res, 1, bk), 2, bn)
    _check_blocks("rns_fused_encode_matmul", bm_eff, bn, bk,
                  {"M": x2.shape[0], "D": x2.shape[1], "N": b2.shape[2]},
                  b_res.shape[0], b2.dtype.itemsize)
    out = rns_fused_encode_matmul_tiles(
        moduli, x2, s2, b2, bits=bits, bm=bm_eff, bn=bn, bk=bk,
        interpret=interpret)
    return out[:, :M, :N].reshape((out.shape[0],) + lead + (N,))


def rns_fused_matmul_normalize(
    profile, a_res, b_res, *, bm: int | None = None, bn: int | None = None,
    bk: int | None = None, interpret: bool | None = None,
):
    """a_res [K, ..., D] + b_res [K, D, N] -> [..., N] float32 (unscaled).

    Bit-identical to ``matmul`` -> ``normalize`` without the [K, ..., N]
    int32 accumulator write.
    """
    if interpret is None:
        interpret = dispatch.default_interpret()
    t = tables(profile)
    K = a_res.shape[0]
    D = a_res.shape[-1]
    N = b_res.shape[-1]
    lead = a_res.shape[1:-1]
    a2 = a_res.reshape(K, -1, D)
    M = a2.shape[1]
    bm, bn, bk = _blocks("rns_fused_matmul_normalize", t, (M, D, N),
                         bm, bn, bk)
    bm_eff = min(bm, _pow2_at_least(M))
    a2 = _pad_to(_pad_to(a2, 1, bm_eff), 2, bk)
    b2 = _pad_to(_pad_to(b_res, 1, bk), 2, bn)
    _check_blocks("rns_fused_matmul_normalize", bm_eff, bn, bk,
                  {"M": a2.shape[1], "D": a2.shape[2], "N": b2.shape[2]},
                  K, a2.dtype.itemsize)
    out = rns_fused_matmul_normalize_tiles(
        a2, b2, profile=t.profile.name, bm=bm_eff, bn=bn, bk=bk,
        interpret=interpret)
    return out[:M, :N].reshape(lead + (N,))


def rns_fused_dot(
    profile, x, scale, b_res, *, bits: int = 16, bm: int | None = None,
    bn: int | None = None, bk: int | None = None,
    interpret: bool | None = None,
):
    """x [..., D] f32 + scale rows + b_res [K, D, N] -> [..., N] float32
    signed values (unscaled): encode -> digit matmul -> MRC normalize in
    ONE pass; residues only ever live in VMEM."""
    if interpret is None:
        interpret = dispatch.default_interpret()
    t = tables(profile)
    D = x.shape[-1]
    N = b_res.shape[-1]
    bm, bn, bk = _blocks("rns_fused_dot", t,
                         (int(np.prod(x.shape[:-1], dtype=np.int64)), D, N),
                         bm, bn, bk)
    bm_eff = min(bm, _pow2_at_least(x.reshape(-1, D).shape[0]))
    x2, s2, M, lead = _prep_activation(x, scale, bm_eff, bk)
    b2 = _pad_to(_pad_to(b_res, 1, bk), 2, bn)
    _check_blocks("rns_fused_dot", bm_eff, bn, bk,
                  {"M": x2.shape[0], "D": x2.shape[1], "N": b2.shape[2]},
                  b_res.shape[0], b2.dtype.itemsize)
    out = rns_fused_dot_tiles(
        x2, s2, b2, profile=t.profile.name, bits=bits, bm=bm_eff, bn=bn,
        bk=bk, interpret=interpret)
    return out[:M, :N].reshape(lead + (N,))
