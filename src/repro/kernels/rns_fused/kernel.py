"""Pallas TPU kernels: the paper's Fig. 5 datapath as ONE pass.

The unfused layer materializes every pipeline stage through HBM: the
conversion kernel writes [K, T] residue planes, the matmul kernel writes
[K, M, N] int32 accumulators, and the normalization kernel reads them
back.  On the paper's hardware those stages are a single wired pipeline —
forward converters sit at the edge of the digit-slice array and the MRC
unit sits after the accumulators — so the software analogue is kernel
fusion:

  * ``rns_fused_encode_matmul_tiles`` — the forward conversion
    (quantize/clip + per-digit reduction) runs in VMEM inside the matmul
    grid's K-loop prologue.  Activation residues NEVER round-trip HBM;
    the quantize is recomputed per digit slice and per K step, which is
    the classic fusion trade (cheap VPU work for HBM bandwidth).  The
    scale rides as a block-indexed [bm, 1] row operand, so per-sequence
    quantization grids (ragged prefill) fuse exactly like scalar grids.
  * ``rns_fused_matmul_normalize_tiles`` — the digit loop moves INSIDE
    the kernel (a [K, bm, bn] accumulator scratch instead of a K-sized
    grid axis) so the ``k == n_k - 1`` step can run the two-pass MRC +
    float reconstruction on the finished tile.  The [K, M, N] int32
    write of a main-path normalize disappears entirely.
  * ``rns_fused_dot_tiles`` — both fusions at once: float activations
    in, float values out, residues only ever exist in VMEM.

Exactness: all residue arithmetic is integer and the reduction schedule
per digit is the unfused kernel's (one lazy ``rem`` per bk step), so the
fused residues are bit-identical to convert->matmul; the epilogue reuses
``rns_normalize.kernel.mrc_float_tile``, so the floats are bit-identical
to the unfused normalize (asserted in tests/test_fused_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401

from repro.kernels import compiler_params
from repro.kernels.rns_normalize.kernel import mrc_float_tile

from repro.core.rns import tables


def _quantize_tile(x, s, qmax: int):
    """clip(round(x * s)) — THE fixed-point rule (core/quantize.py)."""
    return jnp.clip(jnp.round(x * s), -qmax, qmax).astype(jnp.int32)


def _dot_s32(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)


# ------------------------------------------------- encode + matmul --------
def _encode_matmul_kernel(m_ref, x_ref, s_ref, b_ref, o_ref, acc_ref, *,
                          n_k: int, qmax: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m = m_ref[0, 0]
    v = _quantize_tile(x_ref[...], s_ref[...], qmax)      # [bm, bk] int32
    a = jnp.remainder(v, m)                               # digit residues
    prod = _dot_s32(a, b_ref[0].astype(jnp.int32))
    # lazy modular reduction: one rem per K step keeps the carry < m
    acc_ref[...] = jnp.remainder(acc_ref[...] + prod, m)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "bm", "bn", "bk", "interpret")
)
def rns_fused_encode_matmul_tiles(
    moduli, x, s_rows, b_res, *, bits: int = 16, bm: int = 128,
    bn: int = 128, bk: int = 512, interpret: bool = False,
):
    """x [M, D] f32, s_rows [M, 1] f32, b_res [K, D, N] -> [K, M, N] int32.

    M, N, D must be multiples of (bm, bn, bk); ops.py pads (zero activation
    rows quantize to zero residues, which contribute nothing mod m).
    """
    K = b_res.shape[0]
    M, D = x.shape
    N = b_res.shape[-1]
    n_k = D // bk
    grid = (K, M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_encode_matmul_kernel, n_k=n_k,
                          qmax=2 ** (bits - 1) - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, i, j, k: (s, 0)),
            pl.BlockSpec((bm, bk), lambda s, i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda s, i, j, k: (i, 0)),
            pl.BlockSpec((1, bk, bn), lambda s, i, j, k: (s, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, k: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((K, M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=(
                "parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(moduli.reshape(-1, 1), x, s_rows, b_res)


# ---------------------------------------------- matmul + normalize --------
def _matmul_normalize_kernel(a_ref, b_ref, o_ref, acc_ref, *, profile,
                             n_k: int):
    t = tables(profile)
    K = t.profile.n_digits
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for j in range(K):
        m = jnp.int32(int(t.moduli[j]))
        prod = _dot_s32(a_ref[j].astype(jnp.int32), b_ref[j].astype(jnp.int32))
        acc_ref[j] = jnp.remainder(acc_ref[j] + prod, m)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = mrc_float_tile([acc_ref[j] for j in range(K)], t)


@functools.partial(
    jax.jit, static_argnames=("profile", "bm", "bn", "bk", "interpret")
)
def rns_fused_matmul_normalize_tiles(
    a_res, b_res, *, profile, bm: int = 128, bn: int = 128, bk: int = 512,
    interpret: bool = False,
):
    """a_res [K, M, D], b_res [K, D, N] residues -> [M, N] float32
    signed values (unscaled) — no [K, M, N] int32 ever leaves the core."""
    K, M, D = a_res.shape
    N = b_res.shape[-1]
    n_k = D // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_normalize_kernel, profile=profile, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((K, bk, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a_res, b_res)


# --------------------------------- encode + matmul + normalize (full) -----
def _fused_dot_kernel(x_ref, s_ref, b_ref, o_ref, acc_ref, *, profile,
                      n_k: int, qmax: int):
    t = tables(profile)
    K = t.profile.n_digits
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = _quantize_tile(x_ref[...], s_ref[...], qmax)      # shared by digits
    for j in range(K):
        m = jnp.int32(int(t.moduli[j]))
        a = jnp.remainder(v, m)
        prod = _dot_s32(a, b_ref[j].astype(jnp.int32))
        acc_ref[j] = jnp.remainder(acc_ref[j] + prod, m)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = mrc_float_tile([acc_ref[j] for j in range(K)], t)


@functools.partial(
    jax.jit, static_argnames=("profile", "bits", "bm", "bn", "bk", "interpret")
)
def rns_fused_dot_tiles(
    x, s_rows, b_res, *, profile, bits: int = 16, bm: int = 128,
    bn: int = 128, bk: int = 512, interpret: bool = False,
):
    """x [M, D] f32, s_rows [M, 1], b_res [K, D, N] -> [M, N] float32
    signed values (unscaled): the whole Fig. 5 pipeline in one pass."""
    K = b_res.shape[0]
    M, D = x.shape
    N = b_res.shape[-1]
    n_k = D // bk
    grid = (M // bm, N // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fused_dot_kernel, profile=profile, n_k=n_k,
                          qmax=2 ** (bits - 1) - 1),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((K, bk, bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, bm, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, s_rows, b_res)
