"""Fused residue-datapath kernels: encode -> digit matmul -> normalize
as single Pallas passes (the paper's Fig. 5 pipeline without the HBM
round-trips the three separate kernels paid between stages)."""
