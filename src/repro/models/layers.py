"""Building-block layers.  Functional style: explicit param dicts.

Every projection routes through :func:`linear`, which dispatches to the RNS
digit-sliced datapath when the model config asks for it — that is how the
paper's technique becomes a first-class, per-layer-selectable feature.

Param-spec convention: ``init_*`` returns ``(params, specs)`` where specs
mirror params with logical-axis tuples (see distributed/sharding.py for the
logical->mesh rules).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rns_matmul import RnsDotConfig, rns_dot

Axes = tuple  # logical axis names, one per param dim


def _split(key, n):
    return jax.random.split(key, n)


# ------------------------------------------------------------- linear -----
def init_linear(key, d_in, d_out, *, axes: Axes, bias=False, dtype=jnp.float32,
                scale=None):
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), dtype) * scale)}
    s = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (axes[-1],)
    return p, s


def linear(p, x, rns: RnsDotConfig | None = None):
    w = p["w"]
    if rns is not None:
        y = rns_dot(x.astype(jnp.float32), w.astype(jnp.float32), rns)
        y = y.astype(x.dtype)
    else:
        y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# --------------------------------------------------------------- norms ----
def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed_vec",)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        {"scale": ("embed_vec",), "bias": ("embed_vec",)},
    )


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm(p, x, kind: str):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def init_norm(d, kind: str, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


# ----------------------------------------------------------- embedding ----
def init_embedding(key, vocab, d, dtype=jnp.float32):
    # vocab-parallel only (Megatron): sharding d_model over `data` makes
    # GSPMD reshard activations instead of gathering the (small) table.
    p = {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}
    return p, {"table": ("vocab", None)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """LM head (tied transpose use is the caller's choice)."""
    return x @ p["table"].T


# ----------------------------------------------------------------- MLP ----
def _act(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def init_mlp(key, d, d_ff, *, gated=True, act="silu", dtype=jnp.float32,
             down_axes: Axes = ("mlp", "embed")):
    """down_axes: the RNS path uses (None, "mlp") — an unsharded contraction
    gathers bf16 activations instead of all-reducing 9x-int32 residue
    partial sums (§Perf rns iter 2)."""
    ks = _split(key, 3)
    p, s = {}, {}
    p["wi"], s["wi"] = init_linear(ks[0], d, d_ff, axes=("embed", "mlp"), dtype=dtype)
    if gated:
        p["wg"], s["wg"] = init_linear(ks[1], d, d_ff, axes=("embed", "mlp"), dtype=dtype)
    p["wo"], s["wo"] = init_linear(ks[2], d_ff, d, axes=down_axes, dtype=dtype)
    return p, s


def mlp(p, x, *, gated=True, act="silu", rns=None):
    h = linear(p["wi"], x, rns)
    if gated:
        h = _act(act)(linear(p["wg"], x, rns)) * h
    else:
        h = _act(act)(h)
    # NOTE §Perf rns iter 4: constraining h to replicated before the down
    # conversion (to reshard bf16 instead of s8 residues) backfired — XLA
    # lowered it to 12.8 TiB of collective-permutes.  Refuted, reverted.
    return linear(p["wo"], h, rns)


# ------------------------------------------------------------- pos-emb ----
def sinusoidal_positions(length: int, d: int, dtype=jnp.float32):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (2 * dim / d))
    ang = pos * inv
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)
