"""Building-block layers.  Functional style: explicit param dicts.

Every projection routes through :func:`linear`, which dispatches to the RNS
digit-sliced datapath when the model config asks for it — that is how the
paper's technique becomes a first-class, per-layer-selectable feature.

Residue-domain execution: :func:`linear` also consumes/produces
:class:`~repro.core.tensor.RnsTensor`, and the MLP has a deferred datapath
(``cfg.rns.defer``) where the wi -> gate-multiply -> wo chain stays in
residues end to end — the slow MRC normalization runs once per block
(plus once inside the unavoidable float nonlinearity), not once per
matmul.  ``rns_linear_chain`` is the same idea for a bare stack of
linears.

Param-spec convention: ``init_*`` returns ``(params, specs)`` where specs
mirror params with logical-axis tuples (see distributed/sharding.py for the
logical->mesh rules).
"""

from __future__ import annotations

import dataclasses
import functools
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.rns_matmul import (
    RnsDotConfig,
    rns_dot,
    rns_multi_dot,
    rns_resident_dot,
    rns_resident_multi_dot,
)
from repro.core.tensor import (
    RnsTensor,
    rt_decode,
    rt_dot,
    rt_encode,
    rt_encode_matmul,
    rt_matmul,
    rt_matmul_decode,
    rt_mul,
)

Axes = tuple  # logical axis names, one per param dim


def _split(key, n):
    return jax.random.split(key, n)


# ------------------------------------------------------------- linear -----
def init_linear(key, d_in, d_out, *, axes: Axes, bias=False, dtype=jnp.float32,
                scale=None):
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(d_in))
    p = {"w": (jax.random.normal(key, (d_in, d_out), dtype) * scale)}
    s = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (axes[-1],)
    return p, s


# Eager weight-encode cache.  Outside jit every forward re-encodes the same
# param array; residue digits are pure functions of (values, profile, qw,
# backend, digit layout), so keying on the array's identity is sound as long
# as the entry dies with the array (weakref) — params are never mutated
# in place, only replaced.  Tracers bypass the cache entirely: inside jit
# the compiler already CSEs the encode, and tracer ids are meaningless.
_ENCODE_CACHE: dict = {}
_ENCODE_CACHE_MAX = 256


def _cached_encode(w, profile: str, qw: int, backend) -> RnsTensor:
    if isinstance(w, jax.core.Tracer):
        return rt_encode(w.astype(jnp.float32), profile, bits=qw,
                         backend=backend, weight=True)
    from repro.distributed.sharding import digit_sharding

    key = (id(w), profile, qw, backend, digit_sharding())
    hit = _ENCODE_CACHE.get(key)
    if hit is not None and hit[0]() is w:
        return hit[1]
    res = rt_encode(w.astype(jnp.float32), profile, bits=qw, backend=backend,
                    weight=True)
    try:
        ref = weakref.ref(w)
    except TypeError:
        return res
    if len(_ENCODE_CACHE) >= _ENCODE_CACHE_MAX:
        _ENCODE_CACHE.clear()
    _ENCODE_CACHE[key] = (ref, res)
    return res


def _encode_weight(p, rns: RnsDotConfig) -> RnsTensor:
    res = p.get("w_res")
    if isinstance(res, RnsTensor):
        if res.profile == rns.profile:
            return res          # resident: encoded once at build time
        if "w" not in p:
            raise ValueError(
                f"resident weight is encoded on profile {res.profile!r} but "
                f"the config asks for {rns.profile!r}, and the float master "
                "was dropped — re-encode is impossible")
    return _cached_encode(p["w"], rns.profile, rns.qw, rns.resolved_backend())


def linear(p, x, rns: RnsDotConfig | None = None):
    """x @ w (+ b).  ``x`` may be a float array or an :class:`RnsTensor`.

    With an RnsTensor input the op stays in the residue domain and returns
    an RnsTensor — no normalization happens here; the caller decodes (or
    keeps chaining) when it actually needs float values.
    """
    if isinstance(x, RnsTensor):
        if rns is None:
            raise ValueError("RnsTensor input requires an RnsDotConfig")
        if "b" in p:
            raise ValueError(
                "bias add on a residue-domain activation needs a matching "
                "fixed-point grid; decode first or drop the bias")
        return rt_matmul(x, _encode_weight(p, rns),
                         backend=rns.resolved_backend(), renorm_bits=rns.qx)
    res = p.get("w_res")
    if rns is not None and isinstance(res, RnsTensor):
        if rns.profile != res.profile:
            rns = dataclasses.replace(rns, profile=res.profile)
        y = rns_resident_dot(x.astype(jnp.float32), res, rns).astype(x.dtype)
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        return y
    w = p["w"]
    if rns is not None:
        y = rns_dot(x.astype(jnp.float32), w.astype(jnp.float32), rns)
        y = y.astype(x.dtype)
    else:
        y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------- residue-domain chain -
def _chain_float_ref(ws, x):
    return functools.reduce(lambda h, w: h @ w, ws, x)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rns_linear_chain(x, ws: tuple, cfg: RnsDotConfig):
    """x @ w1 @ w2 @ ... entirely in residues: ONE MRC normalization.

    The scale/magnitude ledger inserts intermediate renormalizations only
    if the profile's exact range would overflow.  Backward is the float
    chain with straight-through quantizer gradients.
    """
    be = cfg.resolved_backend()
    ht = rt_encode(x.astype(jnp.float32), cfg.profile, bits=cfg.qx, backend=be)
    for w in ws:
        wt = rt_encode(w.astype(jnp.float32), cfg.profile, bits=cfg.qw,
                       backend=be, weight=True)
        ht = rt_matmul(ht, wt, backend=be, renorm_bits=cfg.qx)
    return rt_decode(ht, backend=be).astype(x.dtype)


def _chain_fwd(x, ws, cfg):
    return rns_linear_chain(x, ws, cfg), (x, ws)


def _chain_bwd(cfg, resids, g):
    x, ws = resids
    _, vjp = jax.vjp(lambda x, ws: _chain_float_ref(ws, x), x, ws)
    return vjp(g)


rns_linear_chain.defvjp(_chain_fwd, _chain_bwd)


# --------------------------------------------------------------- norms ----
def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed_vec",)}


def rmsnorm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d, dtype=jnp.float32):
    return (
        {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
        {"scale": ("embed_vec",), "bias": ("embed_vec",)},
    )


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def norm(p, x, kind: str):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def init_norm(d, kind: str, dtype=jnp.float32):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


# ----------------------------------------------------------- embedding ----
def init_embedding(key, vocab, d, dtype=jnp.float32):
    # vocab-parallel only (Megatron): sharding d_model over `data` makes
    # GSPMD reshard activations instead of gathering the (small) table.
    p = {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}
    return p, {"table": ("vocab", None)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """LM head (tied transpose use is the caller's choice)."""
    return x @ p["table"].T


# ----------------------------------------------------------------- MLP ----
def _act(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def init_mlp(key, d, d_ff, *, gated=True, act="silu", dtype=jnp.float32,
             down_axes: Axes = ("mlp", "embed")):
    """down_axes: the RNS path uses (None, "mlp") — an unsharded contraction
    gathers bf16 activations instead of all-reducing 9x-int32 residue
    partial sums (§Perf rns iter 2)."""
    ks = _split(key, 3)
    p, s = {}, {}
    p["wi"], s["wi"] = init_linear(ks[0], d, d_ff, axes=("embed", "mlp"), dtype=dtype)
    if gated:
        p["wg"], s["wg"] = init_linear(ks[1], d, d_ff, axes=("embed", "mlp"), dtype=dtype)
    p["wo"], s["wo"] = init_linear(ks[2], d_ff, d, axes=down_axes, dtype=dtype)
    return p, s


def _mlp_float_ref(p, x, gated, act):
    h = x @ p["wi"]["w"]
    if gated:
        h = _act(act)(x @ p["wg"]["w"]) * h
    else:
        h = _act(act)(h)
    return h @ p["wo"]["w"]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def mlp_rns_deferred(p, x, gated: bool, act: str, cfg: RnsDotConfig):
    """The MLP block with a residue-domain main datapath.

    wi(x) and the gate product and wo(.) chain in residues; the magnitude
    ledger inserts a renormalization only when the profile would overflow.
    Slow-op budget per block (when capacity holds): ONE normalize on the
    main path (after wo) plus one inside the gate nonlinearity — versus
    one per matmul (3) on the per-op path.

    Backward: float-reference vjp with straight-through quantizer grads
    (the per-op path's cfg.backward_rns RNS-backward is available by
    switching defer off for training steps that want it).

    On a fused backend the same chain runs through the composite kernels:
    wi is a fused encode+matmul (residues out, for the PAC gate product),
    the gate branch is one fully-fused dot (its only consumer is the
    float nonlinearity), and wo is a fused matmul+normalize — identical
    numerics and slow-op budget, but neither the activation residues nor
    the [K, ..., d] main-path accumulator ever round-trip HBM.
    """
    be = cfg.resolved_backend()
    xf = x.astype(jnp.float32)
    if dispatch.fusion_active(cfg.profile, be) and not cfg.slice_parallel:
        if gated:
            hi = rt_encode_matmul(xf, _encode_weight(p["wi"], cfg),
                                  bits=cfg.qx, backend=be)
            # shared_encode: x's conversion was tallied by wi's composite
            hg = rt_dot(xf, _encode_weight(p["wg"], cfg), bits=cfg.qx,
                        backend=be, shared_encode=True)
            g = _act(act)(hg)                                  # slow op (act)
            gt = rt_encode(g, cfg.profile, bits=cfg.qx, backend=be)
            hi = rt_mul(hi, gt, backend=be, renorm_bits=cfg.qx)
        else:
            a = _act(act)(rt_dot(xf, _encode_weight(p["wi"], cfg),
                                 bits=cfg.qx, backend=be))     # slow op (act)
            hi = rt_encode(a, cfg.profile, bits=cfg.qx, backend=be)
        out = rt_matmul_decode(hi, _encode_weight(p["wo"], cfg), backend=be,
                               renorm_bits=cfg.qx)             # THE normalize
        return out.astype(x.dtype)
    xt = rt_encode(xf, cfg.profile, bits=cfg.qx, backend=be)   # 1 conversion
    hi = linear(p["wi"], xt, cfg)                              # stays residues
    if gated:
        hg = linear(p["wg"], xt, cfg)
        g = _act(act)(rt_decode(hg, backend=be))               # slow op (act)
        gt = rt_encode(g, cfg.profile, bits=cfg.qx, backend=be)
        hi = rt_mul(hi, gt, backend=be, renorm_bits=cfg.qx)    # PAC, deferred
    else:
        a = _act(act)(rt_decode(hi, backend=be))               # slow op (act)
        hi = rt_encode(a, cfg.profile, bits=cfg.qx, backend=be)
    out = linear(p["wo"], hi, cfg)                             # stays residues
    return rt_decode(out, backend=be).astype(x.dtype)          # THE normalize


def _mlp_deferred_fwd(p, x, gated, act, cfg):
    return mlp_rns_deferred(p, x, gated, act, cfg), (p, x)


def _mlp_deferred_bwd(gated, act, cfg, resids, g):
    p, x = resids
    _, vjp = jax.vjp(
        lambda p, x: _mlp_float_ref(p, x.astype(jnp.float32), gated, act), p, x)
    gp, gx = vjp(g.astype(jnp.float32))
    return gp, gx.astype(x.dtype)


mlp_rns_deferred.defvjp(_mlp_deferred_fwd, _mlp_deferred_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def mlp_rns_resident_perop(p, x, gated: bool, act: str, cfg: RnsDotConfig):
    """Per-op-normalized MLP on resident weights: zero weight conversions.

    Arithmetic is a bit-identical mirror of the re-encode per-op path
    (``rns_multi_dot`` + ``linear``): same activation grids, same primitive
    schedule, same intermediate dtype casts — only the weight conversions
    vanish, because the operands arrive as residues.  Backward is the
    float-reference vjp over the masters (straight-through quantizer
    grads); integer digit leaves get symbolic-zero cotangents.
    """
    xf = x.astype(jnp.float32)
    if gated:
        hi, hg = rns_resident_multi_dot(
            xf, (p["wi"]["w_res"], p["wg"]["w_res"]), cfg)
        h = (_act(act)(hg) * hi).astype(x.dtype)
    else:
        h = _act(act)(rns_resident_dot(xf, p["wi"]["w_res"], cfg)
                      .astype(x.dtype))
    y = rns_resident_dot(h.astype(jnp.float32), p["wo"]["w_res"], cfg)
    return y.astype(x.dtype)


def _mlp_resident_fwd(p, x, gated, act, cfg):
    return mlp_rns_resident_perop(p, x, gated, act, cfg), (p, x)


def _mlp_resident_bwd(gated, act, cfg, resids, g):
    p, x = resids
    _, vjp = jax.vjp(
        lambda p, x: _mlp_float_ref(p, x.astype(jnp.float32), gated, act), p, x)
    gp, gx = vjp(g.astype(jnp.float32))
    return gp, gx.astype(x.dtype)


mlp_rns_resident_perop.defvjp(_mlp_resident_fwd, _mlp_resident_bwd)


def _mlp_no_bias(p, gated):
    return ("b" not in p["wi"] and "b" not in p["wo"]
            and (not gated or "b" not in p.get("wg", {})))


def _mlp_resident(p, gated):
    names = ("wi", "wg", "wo") if gated else ("wi", "wo")
    return all(isinstance(p.get(n), dict) and "w_res" in p[n] for n in names)


def mlp(p, x, *, gated=True, act="silu", rns=None):
    if rns is not None and rns.defer and not (
            _mlp_no_bias(p, gated) and not rns.slice_parallel):
        # fall back to per-op normalization: residue-domain bias adds need
        # a matching fixed-point grid, and the deferred chain does not yet
        # emit the slice-parallel sharding constraints
        import warnings

        warnings.warn(
            "rns.defer requested but the MLP has biases or slice_parallel "
            "is set; falling back to per-op normalization", stacklevel=2)
        rns = dataclasses.replace(rns, defer=False)
    if rns is not None and _mlp_no_bias(p, gated):
        if _mlp_resident(p, gated):
            # resident weights: thread the layer's (possibly narrower)
            # encode-time profile through the whole chain so every helper
            # that consults cfg.profile agrees with the resident digits
            res_prof = p["wi"]["w_res"].profile
            if rns.profile != res_prof:
                rns = dataclasses.replace(rns, profile=res_prof)
            if rns.defer:
                return mlp_rns_deferred(p, x, gated, act, rns)
            return mlp_rns_resident_perop(p, x, gated, act, rns)
        if rns.defer:
            return mlp_rns_deferred(p, x, gated, act, rns)
        if gated:
            # per-op normalization, but ONE shared forward conversion of x
            # for the wi/wg pair (identical numerics to separate rns_dots)
            hi, hg = rns_multi_dot(
                x.astype(jnp.float32),
                (p["wi"]["w"].astype(jnp.float32),
                 p["wg"]["w"].astype(jnp.float32)), rns)
            h = (_act(act)(hg) * hi).astype(x.dtype)
            return linear(p["wo"], h, rns)
    h = linear(p["wi"], x, rns)
    if gated:
        h = _act(act)(linear(p["wg"], x, rns)) * h
    else:
        h = _act(act)(h)
    # NOTE §Perf rns iter 4: constraining h to replicated before the down
    # conversion (to reshard bf16 instead of s8 residues) backfired — XLA
    # lowered it to 12.8 TiB of collective-permutes.  Refuted, reverted.
    return linear(p["wo"], h, rns)


# ------------------------------------------------------------- pos-emb ----
def sinusoidal_positions(length: int, d: int, dtype=jnp.float32):
    pos = np.arange(length)[:, None]
    dim = np.arange(d // 2)[None, :]
    inv = 1.0 / (10000 ** (2 * dim / d))
    ang = pos * inv
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(out, dtype)
