"""Resident residue-domain weights: encode once at build time, serve forever.

The paper's premise is that weights *live* in the residue domain on an
RNS TPU — converted at the boundary once (Olsen's Rez-9 RALU makes the
same argument from the hardware side), not re-quantized and re-encoded on
every forward matmul the way ``models/layers._encode_weight`` does on the
re-encode path.  This module performs that boundary conversion:

* :func:`encode_resident` (eager, build time) walks a params tree, finds
  every RNS-target MLP weight (``wi``/``wg``/``wo``), and attaches a
  pre-encoded :class:`~repro.core.tensor.RnsTensor` under ``"w_res"``
  next to the float master ``"w"``.  Stacked per-period weights
  (``[P, d_in, d_out]``, the scanned-transformer layout) become
  period-major stacked residents (digits ``[P, K, d_in, d_out]``, scale
  ``[P]``) so ``lax.scan`` slices out one valid RnsTensor per period —
  see :func:`~repro.core.tensor.rt_stack` for why the period axis leads.
  Per-period quantization grids are bit-identical to what the re-encode
  path computes (the absmax reduction is exact), so serving output is
  token-identical, minus every weight conversion.

* **Per-layer moduli profiles** (``per_layer_profiles=True``): at encode
  time the *quantized* weights' maximum column abs-sums are known, so the
  worst case of each layer's product summations can be bounded tightly —
  ``|sum_d q_x[d] * q_w[d, j]| <= 2**(qx-1) * max_j sum_d |q_w[d, j]|``
  — instead of generically (``2**(qx-1) * 2**(qw-1) * D``).  The layer
  chain's tight bound picks the narrowest registered profile whose exact
  signed range still covers it (``core/moduli.narrowest_profile``):
  narrow layers run on fewer/smaller moduli — fewer residue planes moved
  and multiplied — while the magnitude ledger proof keeps the integers
  exact.  The bound is carried into the ledger by storing the resident
  ``mag_bits`` *amortized over the contraction*: ``log2(colsum) -
  log2(D)``, so the existing ledger formula ``a.mag + w.mag + log2(D)``
  reconstructs exactly ``(qx-1) + log2(colsum)``.

* :func:`attach_resident` (traceable) is the train-step variant: same
  tree surgery under jit, encoding from the (traced) float masters each
  step so the optimizer keeps updating masters while the forward runs on
  residues.  Profile selection needs concrete weights, so it is
  eager-only.

Scope: MLP weights (the default ``rns_targets="mlp"`` datapath — every
RNS matmul in the serving configs).  Attention projections still
re-encode; making ``models/attention`` resident-aware is a ROADMAP item.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.moduli import get_profile, narrowest_profile
from repro.core.quantize import absmax_scale, quantize_with_scale
from repro.core.tensor import _SAFETY_BITS, RnsTensor

__all__ = [
    "encode_resident",
    "attach_resident",
    "strip_resident",
    "has_resident",
    "resident_profiles",
]

# _SAFETY_BITS comes from core/tensor — ONE ledger headroom constant, so
# the encode-side profile selection and the rt_* runtime checks (and the
# static auditor) can never drift apart.

_MLP_WEIGHTS = ("wi", "wg", "wo")


def _is_mlp(tree) -> bool:
    return (isinstance(tree, dict) and "wi" in tree and "wo" in tree
            and isinstance(tree.get("wi"), dict) and "w" in tree["wi"])


def _walk_mlps(tree, fn, path=()):
    """Rebuild ``tree`` with ``fn(mlp_dict, path)`` applied to every MLP
    param dict (identified structurally: has ``wi``/``wo`` linears)."""
    if isinstance(tree, dict):
        if _is_mlp(tree):
            return fn(tree, path)
        return {k: _walk_mlps(v, fn, path + (k,)) for k, v in tree.items()}
    return tree


def _mlp_has_bias(mlp) -> bool:
    return any("b" in mlp[n] for n in _MLP_WEIGHTS if n in mlp)


def _encode_one(w, profile: str, qw: int, mag_bits: float) -> RnsTensor:
    """Encode one master weight — ``[d, n]`` plain or ``[P, d, n]``
    stacked — into a resident RnsTensor on the reference conversion path
    (bit-identical to every backend's convert; the kernel exactness tests
    pin that).  Stacked masters get per-period grids: exactly the scale
    the re-encode path computes for each period's slice."""
    p = get_profile(profile)
    wf = jnp.asarray(w, jnp.float32)
    if wf.ndim == 3:                                   # [P, d, n] stacked
        s = absmax_scale(wf, qw, axis=(1, 2))          # [P, 1, 1]
        digits = dispatch.convert(p, wf, s, bits=qw, backend="reference",
                                  weight=True)         # [K, P, d, n]
        return RnsTensor(jnp.moveaxis(digits, 0, 1),   # [P, K, d, n]
                         s.reshape(-1), p.name, float(mag_bits), 0)
    s = absmax_scale(wf, qw)
    digits = dispatch.convert(p, wf, s, bits=qw, backend="reference",
                              weight=True)             # [K, d, n]
    return RnsTensor(digits, jnp.asarray(s, jnp.float32), p.name,
                     float(mag_bits), 0)


def _colsum_bits(w, qw: int) -> float:
    """log2 of the max column abs-sum of the qw-bit quantized weight —
    the tight per-layer bound on one activation row's product summation
    (worst case over periods for stacked masters).  Concrete (eager)
    weights only."""
    wf = jnp.asarray(w, jnp.float32)
    axis = (1, 2) if wf.ndim == 3 else None
    s = absmax_scale(wf, qw, axis=axis)
    q = quantize_with_scale(wf, s, qw)
    col = int(jnp.max(jnp.sum(jnp.abs(q), axis=-2)))   # sum over d_in
    return math.log2(max(col, 1))


def _select_profile(mlp, rns, gated: bool):
    """Pick the narrowest registered profile covering this layer's
    deferred chain, and the amortized per-weight ledger bounds.

    Gated chain worst case (defer on — it dominates the per-op path):
      encode(x, qx)          ->  qx-1
      @ wi                   ->  (qx-1) + cb_wi
      * encode(gate, qx)     ->  + (qx-1)
      @ wo                   ->  + cb_wo
    with ``cb_* = log2(max colsum of the quantized weight)``; the decoded
    gate branch needs ``(qx-1) + cb_wg`` on its own.
    """
    qx = rns.qx
    cb = {n: _colsum_bits(mlp[n]["w"], rns.qw)
          for n in _MLP_WEIGHTS if n in mlp}
    x_bits = float(qx - 1)
    if gated and "wg" in cb:
        chain = x_bits + cb["wi"] + x_bits + cb["wo"]
        need = max(chain, x_bits + cb["wg"])
    else:
        need = max(x_bits + cb["wi"], x_bits + cb["wo"])
    prof = narrowest_profile(need + _SAFETY_BITS, cap=rns.profile)
    mags = {n: cb[n] - math.log2(max(mlp[n]["w"].shape[-2], 1)) for n in cb}
    return prof.name, mags


def _rns_mlp_cfg(cfg):
    """The model's MLP-target RnsDotConfig, or None (nothing to encode)."""
    if cfg.rns is None or cfg.rns_targets not in ("all", "mlp"):
        return None
    return cfg.rns


def encode_resident(params, cfg, *, per_layer_profiles: bool = False,
                    drop_masters: bool = False, mesh=None,
                    digit_axis: str = "model"):
    """Encode every RNS-target MLP weight once (eager, build time).

    Returns a new params tree with ``"w_res"`` residents next to (or,
    with ``drop_masters=True`` — serving, where the floats would only
    burn HBM — instead of) each float master ``"w"``.  With ``mesh`` set
    the resident digits are placed into the digit-sharded layout
    (``[P, K, ...]``: digit axis 1 over ``digit_axis``) so the per-step
    jit consumes them without a layout change.
    """
    rns = _rns_mlp_cfg(cfg)
    if rns is None:
        return params
    ds = None
    if mesh is not None:
        from repro.distributed.sharding import DigitSharding

        ds = DigitSharding(mesh, digit_axis)

    def encode_mlp(mlp, path):
        if _mlp_has_bias(mlp):
            return mlp        # biased MLPs keep the float per-op path
        gated = "wg" in mlp
        if per_layer_profiles:
            if any(isinstance(mlp[n]["w"], jax.core.Tracer)
                   for n in _MLP_WEIGHTS if n in mlp):
                raise ValueError(
                    "per-layer profile selection needs concrete weights "
                    "(eager encode_resident, not a traced attach)")
            prof, mags = _select_profile(mlp, rns, gated)
        else:
            prof = rns.profile
            mags = {n: float(rns.qw - 1) for n in _MLP_WEIGHTS if n in mlp}
        out = {}
        for name, p_lin in mlp.items():
            if name in _MLP_WEIGHTS and isinstance(p_lin, dict) \
                    and "w" in p_lin:
                res = _encode_one(p_lin["w"], prof, rns.qw, mags[name])
                if ds is not None and ds.shards(res.rns_profile.n_digits):
                    axis_pos = 1 if res.digits.ndim == 4 else 0
                    res = RnsTensor(
                        jax.device_put(res.digits, ds.digit_sharding(
                            res.digits.ndim, axis_pos=axis_pos)),
                        res.scale, res.profile, res.mag_bits, res.frac_exp)
                new = dict(p_lin, w_res=res)
                if drop_masters:
                    new.pop("w")
                out[name] = new
            else:
                out[name] = p_lin
        return out

    return _walk_mlps(params, encode_mlp)


def attach_resident(params, cfg):
    """Traceable resident attach for the train step: encode residents
    from the (traced) float masters with the config profile.  Masters
    stay in the tree — the optimizer updates them, the custom_vjp STE
    backward reads them, and no gradient flows through the integer
    digits.  Per-layer profile selection is eager-only; use
    :func:`encode_resident` for that."""
    rns = _rns_mlp_cfg(cfg)
    if rns is None:
        return params

    def encode_mlp(mlp, path):
        if _mlp_has_bias(mlp):
            return mlp
        out = {}
        for name, p_lin in mlp.items():
            if name in _MLP_WEIGHTS and isinstance(p_lin, dict) \
                    and "w" in p_lin:
                res = _encode_one(p_lin["w"], rns.profile, rns.qw,
                                  float(rns.qw - 1))
                out[name] = dict(p_lin, w_res=res)
            else:
                out[name] = p_lin
        return out

    return _walk_mlps(params, encode_mlp)


def strip_resident(params):
    """Drop every ``"w_res"`` entry (checkpointing float masters only,
    or forcing the re-encode path for an A/B comparison)."""

    def strip_mlp(mlp, path):
        return {k: ({kk: vv for kk, vv in v.items() if kk != "w_res"}
                    if isinstance(v, dict) else v)
                for k, v in mlp.items()}

    return _walk_mlps(params, strip_mlp)


def has_resident(params) -> bool:
    found = []

    def probe(mlp, path):
        found.extend(k for k in _MLP_WEIGHTS
                     if k in mlp and isinstance(mlp[k], dict)
                     and "w_res" in mlp[k])
        return mlp

    _walk_mlps(params, probe)
    return bool(found)


def resident_profiles(params) -> dict:
    """{'/'.join(path): profile name} for every resident MLP (one entry
    per layer slot — wi/wg/wo share the slot's profile)."""
    out = {}

    def probe(mlp, path):
        if "wi" in mlp and isinstance(mlp["wi"], dict) \
                and "w_res" in mlp["wi"]:
            out["/".join(map(str, path))] = mlp["wi"]["w_res"].profile
        return mlp

    _walk_mlps(params, probe)
    return out
