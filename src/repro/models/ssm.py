"""State-space / linear-recurrence layers: Mamba-1 (Jamba) and RWKV-6 (Finch).

Both are implemented as chunked recurrences: ``lax.scan`` over fixed-size
time chunks with a carried state, ``jax.checkpoint`` per chunk (bounded
residual memory), and an O(1)-state single-token step for decode — the
property that makes these archs the ``long_500k`` shapes' designated
runners.

The projections in/out of the recurrences are matmuls and route through the
RNS datapath when enabled; the recurrences themselves are elementwise fp
(outside the paper's product-summation scope; noted in DESIGN.md §6).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import init_linear, linear, init_norm, norm


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"       # "mamba" | "rwkv6"
    d_state: int = 16         # mamba N
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None
    head_dim: int = 64        # rwkv6 head size
    chunk: int = 256          # recurrence chunk length
    impl: str = "scan"        # rwkv6: "scan" (stepwise) | "chunked" (matmul
    #                            GLA-form: intra-chunk attention-like matmuls
    #                            + per-chunk state passing; §Perf rwkv iters)


# ================================================================ Mamba ====
def init_mamba(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_in = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(1, d_model // 16)
    ks = jax.random.split(key, 8)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = init_linear(
        ks[0], d_model, 2 * d_in, axes=("embed", "mlp"), dtype=dtype)
    p["conv_w"] = jax.random.normal(ks[1], (cfg.d_conv, d_in), dtype) * 0.2
    s["conv_w"] = (None, "mlp")
    p["conv_b"] = jnp.zeros((d_in,), dtype)
    s["conv_b"] = ("mlp",)
    p["x_proj"], s["x_proj"] = init_linear(
        ks[2], d_in, dt_rank + 2 * cfg.d_state, axes=("mlp", None), dtype=dtype)
    p["dt_proj"], s["dt_proj"] = init_linear(
        ks[3], dt_rank, d_in, axes=(None, "mlp"), bias=True, dtype=dtype)
    # init dt bias so softplus(dt) ~ [1e-3, 1e-1]: draw log-uniform dt,
    # invert the softplus (bias = log(expm1(dt)))
    u = jax.random.uniform(ks[5], (d_in,), minval=np.log(1e-3),
                           maxval=np.log(1e-1))
    p["dt_proj"]["b"] = jnp.log(jnp.expm1(jnp.exp(u))).astype(dtype)
    a = np.tile(np.arange(1, cfg.d_state + 1, dtype=np.float32), (d_in, 1))
    p["A_log"] = jnp.asarray(np.log(a), dtype)
    s["A_log"] = ("mlp", None)
    p["D"] = jnp.ones((d_in,), dtype)
    s["D"] = ("mlp",)
    p["out_proj"], s["out_proj"] = init_linear(
        ks[4], d_in, d_model, axes=("mlp", "embed"), dtype=dtype)
    return p, s


def _mamba_scan_chunk(h0, a, bx):
    """Associative scan within a chunk.  a,bx: [T,B,d_in,N]; h0 [B,d_in,N]."""

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    a_all, b_all = jax.lax.associative_scan(comb, (a, bx), axis=0)
    h = a_all * h0[None] + b_all
    return h, h[-1]


def mamba_seq(p, x, cfg: SSMConfig, *, rns=None, h0=None, conv0=None):
    """x [B,T,d] -> (y [B,T,d], (h_last, conv_tail)) — chunked selective scan."""
    B, T, d = x.shape
    d_in = cfg.expand * d
    N = cfg.d_state
    xz = linear(p["in_proj"], x, rns)
    xs, z = jnp.split(xz, 2, axis=-1)                      # [B,T,d_in]
    # causal depthwise conv (carry conv tail for decode continuity)
    K = cfg.d_conv
    tail = conv0 if conv0 is not None else jnp.zeros((B, K - 1, d_in), xs.dtype)
    xpad = jnp.concatenate([tail, xs], axis=1)
    xc = sum(
        xpad[:, i : i + T] * p["conv_w"][i][None, None] for i in range(K)
    ) + p["conv_b"][None, None]
    new_tail = xpad[:, T:]
    xc = jax.nn.silu(xc)

    dbc = linear(p["x_proj"], xc, rns)
    dt_rank = dbc.shape[-1] - 2 * N
    dt, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(linear(p["dt_proj"], dt, rns))    # [B,T,d_in]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # [d_in,N]

    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A[None, None])
    bx = (dt * xc).astype(jnp.float32)[..., None] * Bc.astype(jnp.float32)[:, :, None, :]
    # chunked scan over time
    ch = cfg.chunk
    nch = -(-T // ch)
    pad = nch * ch - T
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = a.reshape(B, nch, ch, d_in, N).transpose(1, 2, 0, 3, 4)
    bx = bx.reshape(B, nch, ch, d_in, N).transpose(1, 2, 0, 3, 4)

    h_init = h0 if h0 is not None else jnp.zeros((B, d_in, N), jnp.float32)

    @jax.checkpoint
    def chunk_body(carry, inp):
        ac, bc = inp                                       # [ch,B,d_in,N]
        h_all, h_last = _mamba_scan_chunk(carry, ac, bc)
        return h_last, h_all

    h_last, h_seq = jax.lax.scan(chunk_body, h_init, (a, bx))
    h_seq = h_seq.reshape(nch * ch, B, d_in, N)[:T].transpose(1, 0, 2, 3)
    y = jnp.einsum("btdn,btn->btd", h_seq, Cc.astype(jnp.float32))
    y = (y + xc.astype(jnp.float32) * p["D"][None, None]) * jax.nn.silu(
        z.astype(jnp.float32))
    out = linear(p["out_proj"], y.astype(x.dtype), rns)
    return out, (h_last, new_tail)


def mamba_step(p, x, cfg: SSMConfig, state, *, rns=None):
    """One-token step.  state = (h [B,d_in,N], conv_tail [B,K-1,d_in])."""
    y, (h, tail) = mamba_seq(p, x, cfg, rns=rns, h0=state[0], conv0=state[1])
    return y, (h, tail)


# ================================================================ RWKV-6 ===
def init_rwkv6(key, d_model: int, cfg: SSMConfig, d_ff: int, dtype=jnp.float32):
    H = d_model // cfg.head_dim
    ks = jax.random.split(key, 12)
    p, s = {}, {}
    for i, name in enumerate(["wr", "wk", "wv", "wg"]):
        p[name], s[name] = init_linear(
            ks[i], d_model, d_model, axes=("embed", "heads"), dtype=dtype)
    # o_proj: input lives in (model-sharded) head space -> Megatron pattern
    p["wout"], s["wout"] = init_linear(
        ks[4], d_model, d_model, axes=("heads", "embed"), dtype=dtype)
    # token-shift mix coefficients (static part) for r,k,v,w,g
    p["mix"] = jax.random.uniform(ks[5], (5, d_model), dtype, 0.0, 1.0)
    s["mix"] = (None, "embed_vec")
    # data-dependent decay lora: w = exp(-exp(w0 + tanh(x A) B))
    lora = 64
    p["w0"] = jnp.asarray(
        np.linspace(-6.0, -1.0, d_model, dtype=np.float32), dtype)
    s["w0"] = ("embed_vec",)
    p["wA"], s["wA"] = init_linear(ks[6], d_model, lora, axes=("embed", None), dtype=dtype)
    p["wB"], s["wB"] = init_linear(ks[7], lora, d_model, axes=(None, "embed_vec"), dtype=dtype)
    p["u"] = jax.random.normal(ks[8], (H, cfg.head_dim), dtype) * 0.1  # bonus
    s["u"] = ("kv_heads", None)
    # per-head GroupNorm (RWKV's ln_x): stats are local to each head, so
    # the normalization never crosses the model-axis shard boundary
    p["ln_x"], s["ln_x"] = init_norm(d_model, "layernorm", dtype)
    p["ln_cm"], s["ln_cm"] = init_norm(d_model, "layernorm", dtype)
    # channel-mix
    p["ck"], s["ck"] = init_linear(ks[9], d_model, d_ff, axes=("embed", "mlp"), dtype=dtype)
    p["cv"], s["cv"] = init_linear(ks[10], d_ff, d_model, axes=("mlp", "embed"), dtype=dtype)
    p["cr"], s["cr"] = init_linear(ks[11], d_model, d_model, axes=("embed", "embed_vec"), dtype=dtype)
    p["cmix"] = jax.random.uniform(jax.random.fold_in(key, 3), (2, d_model), dtype, 0.0, 1.0)
    s["cmix"] = (None, "embed_vec")
    return p, s


def _rwkv_chunk_matmul(S0, r, k, v, w, u):
    """Chunked matmul (GLA) form of the WKV recurrence.

    S0 [B,H,D,D] (k-major), r/k/v/w [L,B,H,D], u [H,D].  Exactly equivalent
    to the stepwise recurrence up to f32 rounding; per-channel decays are
    factored as exp(cumsum(log w)) with a +/-30 clamp on the exponent (the
    clipped cross-chunk terms are < e^-30).

      out_i = (r_i*P_{i-1}) @ S0 + sum_{j<i} <r_i*P_{i-1}, k_j/P_j> v_j
              + <r_i, u*k_i> v_i
      S_L   = diag(P_{L-1}) S0 + sum_j diag(P_{L-1}/P_j) k_j v_j^T
    """
    lw = jnp.log(jnp.maximum(w, 1e-38))
    cum = jnp.cumsum(lw, axis=0)                         # [L,B,H,D] inclusive
    q2 = r * jnp.exp(cum - lw)                           # r_i * P_{i-1}
    k2 = k * jnp.exp(-jnp.maximum(cum, -30.0))           # k_j / P_j (clamped)
    scores = jnp.einsum("ibhd,jbhd->bhij", q2, k2)
    L = r.shape[0]
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)        # strictly lower
    scores = jnp.where(mask[None, None], scores, 0.0)
    inter = jnp.einsum("bhij,jbhd->ibhd", scores, v)
    direct = jnp.einsum("ibhd,bhde->ibhe", q2, S0)
    bonus = jnp.sum(r * u[None, None] * k, axis=-1, keepdims=True) * v
    outs = inter + direct + bonus
    p_last = cum[-1]                                     # [B,H,D]
    kdec = k * jnp.exp(jnp.minimum(p_last[None] - cum, 30.0))
    S_next = jnp.exp(p_last)[..., None] * S0 + jnp.einsum(
        "ibhd,ibhe->bhde", kdec, v)
    return S_next, outs


def _rwkv_chunk(carry, inp, H, D):
    """Sequential wkv recurrence within a chunk (scan over time).

    carry S [B,H,D,D]; inp per-step (r,k,v,w,u) each [ch,B,H,D].
    """
    r, k, v, w, u = inp

    def step(S, t):
        rt, kt, vt, wt = r[t], k[t], v[t], w[t]
        kv = kt[..., :, None] * vt[..., None, :]            # [B,H,D,D]
        out = jnp.einsum("bhd,bhde->bhe", rt, S + u[None] [..., None] * kv)
        S = wt[..., None] * S + kv
        return S, out

    S, outs = jax.lax.scan(step, carry, jnp.arange(r.shape[0]))
    return S, outs


def rwkv6_timemix(p, x, cfg: SSMConfig, *, rns=None, state=None):
    """x [B,T,d] -> (y, (S_last, x_last)).  state carries (S, prev token)."""
    B, T, d = x.shape
    D = cfg.head_dim
    H = d // D
    x_prev_0 = state[1] if state is not None else jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_0, x[:, :-1]], axis=1)

    # NOTE §Perf rwkv iter 3: fusing the five projections into one matmul
    # (via [x, xp-x] @ [[W],[diag(m)W]]) REDUCED dx all-reduces 11% but the
    # on-the-fly weight concat of differently-sharded pieces cost more in
    # collective-permutes than it saved — refuted, reverted.
    def mix(i):
        m = p["mix"][i][None, None]
        return x + (x_prev - x) * m

    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = linear(p["wr"], xr, rns).reshape(B, T, H, D)
    k = linear(p["wk"], xk, rns).reshape(B, T, H, D)
    v = linear(p["wv"], xv, rns).reshape(B, T, H, D)
    g = jax.nn.silu(linear(p["wg"], xg, rns))
    # data-dependent decay (Finch)
    wlog = p["w0"][None, None] + linear(
        p["wB"], jnp.tanh(linear(p["wA"], xw, rns)), rns)
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))).reshape(B, T, H, D)

    ch = cfg.chunk
    nch = -(-T // ch)
    pad = nch * ch - T
    seq = [r, k, v, w]
    if pad:
        seq = [jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)),
                       constant_values=(1.0 if t is w else 0.0)) for t in seq]
    r_, k_, v_, w_ = (
        t.astype(jnp.float32).reshape(B, nch, ch, H, D).transpose(1, 2, 0, 3, 4)
        for t in seq
    )
    u = p["u"].astype(jnp.float32)
    S0 = state[0] if state is not None else jnp.zeros((B, H, D, D), jnp.float32)

    chunked = cfg.impl == "chunked"

    @jax.checkpoint
    def chunk_body(S, inp):
        rc, kc, vc, wc = inp
        if chunked:
            S, outs = _rwkv_chunk_matmul(S, rc, kc, vc, wc, u)
        else:
            S, outs = _rwkv_chunk(S, (rc, kc, vc, wc, u), H, D)
        return S, outs

    S_last, outs = jax.lax.scan(chunk_body, S0, (r_, k_, v_, w_))
    y = outs.reshape(nch * ch, B, H, D)[:T].transpose(1, 0, 2, 3)  # [B,T,H,D]
    # GroupNorm over each head's D dims (shard-local on the model axis)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), axis=-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, d)
    y = (y * p["ln_x"]["scale"][None, None]
         + p["ln_x"]["bias"][None, None]).astype(x.dtype) * g
    out = linear(p["wout"], y, rns)
    return out, (S_last, x[:, -1:])


def rwkv6_channelmix(p, x, *, rns=None, state=None):
    """RWKV channel-mix (the FFN analogue).  state carries prev token."""
    B, T, d = x.shape
    x_prev_0 = state if state is not None else jnp.zeros((B, 1, d), x.dtype)
    x_prev = jnp.concatenate([x_prev_0, x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["cmix"][0][None, None]
    xr = x + (x_prev - x) * p["cmix"][1][None, None]
    kk = jnp.square(jax.nn.relu(linear(p["ck"], xk, rns)))
    out = jax.nn.sigmoid(linear(p["cr"], xr, rns)) * linear(p["cv"], kk, rns)
    return out, x[:, -1:]
