"""Attention: GQA/MQA/MHA + RoPE, MLA (DeepSeek-V2), KV caches.

Three execution modes:
  * ``dense``   — training; einsum scores with causal/padding mask.
  * ``chunked`` — long prefill (inference); online-softmax scan over KV
                  blocks so [Tq, Tk] scores never materialize.
  * ``decode``  — one query token against a cache; supports a
                  sequence-sharded cache via LSE-combinable partials
                  (flash-decoding across chips, see distributed/sharding).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rns_matmul import rns_multi_dot
from repro.models.layers import init_linear, linear

NEG_INF = -1e30


def _multi_proj(x, ps, rns):
    """Project ``x`` through several weight dicts with ONE shared forward
    conversion on the RNS path (numerically identical to per-projection
    ``linear`` calls — same absmax grid), or plain matmuls otherwise."""
    if rns is None:
        return tuple(linear(p, x) for p in ps)
    ys = rns_multi_dot(
        x.astype(jnp.float32),
        tuple(p["w"].astype(jnp.float32) for p in ps), rns)
    out = []
    for p, y in zip(ps, ys):
        y = y.astype(x.dtype)
        if "b" in p:
            y = y + p["b"].astype(y.dtype)
        out.append(y)
    return tuple(out)


# ---------------------------------------------------------------- rope ----
def rope(x, positions, theta: float = 10000.0):
    """x [B, T, H, D], positions [B, T] -> rotated x (half-split convention)."""
    d2 = x.shape[-1] // 2
    freqs = 1.0 / (theta ** (np.arange(d2, dtype=np.float32) / d2))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, d2]
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = x[..., :d2], x[..., d2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------- core maths ----
def _gqa_scores(q, k):
    """q [B,Tq,H,D], k [B,Tk,Hk,D] -> scores [B,Hk,G,Tq,Tk] (G=H/Hk)."""
    B, Tq, H, D = q.shape
    Hk = k.shape[2]
    qg = q.reshape(B, Tq, Hk, H // Hk, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(D).astype(np.float32)


def _gqa_out(probs, v):
    """probs [B,Hk,G,Tq,Tk], v [B,Tk,Hk,D] -> [B,Tq,H,D]."""
    B, Hk, G, Tq, _ = probs.shape
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Tq, Hk * G, v.shape[-1])


def dense_attention(q, k, v, *, causal: bool, kv_mask=None, q_offset=0,
                    window=None):
    """Training-mode attention.  kv_mask [B, Tk] optional padding mask.
    ``window``: sliding-window width — query q attends keys in
    [q - window + 1, q] (causal only; masked with exact zeros)."""
    scores = _gqa_scores(q.astype(jnp.float32), k.astype(jnp.float32))
    Tq, Tk = scores.shape[-2], scores.shape[-1]
    if causal:
        qpos = jnp.arange(Tq) + q_offset
        kpos = jnp.arange(Tk)
        mask = qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    if kv_mask is not None:
        scores = jnp.where(kv_mask[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v.astype(jnp.float32)).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 1024,
                      kv_mask=None, q_offset=0, window=None):
    """Online-softmax scan over KV chunks (inference prefill; no O(T^2) buf).

    ``window``: sliding-window width (causal only).  A fully-masked chunk
    contributes exactly nothing: its ``p = exp(NEG_INF - NEG_INF) = 1``
    garbage is cancelled by ``corr = exp(NEG_INF - m_finite) = 0`` at the
    first chunk with a valid key, the same exact-zero mechanism the
    all-padded leading chunks already rely on.
    """
    B, Tq, H, D = q.shape
    Tk = k.shape[1]
    Hk = k.shape[2]
    Dv = v.shape[-1]
    G = H // Hk
    nchunk = -(-Tk // chunk)
    pad = nchunk * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_mask = jnp.pad(
            kv_mask if kv_mask is not None else jnp.ones((B, Tk), bool),
            ((0, 0), (0, pad)),
        )
    kc = k.reshape(B, nchunk, chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunk, chunk, Hk, Dv).transpose(1, 0, 2, 3, 4)
    maskc = (
        kv_mask.reshape(B, nchunk, chunk).transpose(1, 0, 2)
        if kv_mask is not None
        else jnp.ones((nchunk, B, chunk), bool)
    )
    qf = q.astype(jnp.float32).reshape(B, Tq, Hk, G, D)
    qpos = jnp.arange(Tq) + q_offset

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry  # [B,Hk,G,Tq], [B,Hk,G,Tq], [B,Hk,G,Tq,D]
        kb, vb, mb, c = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32))
        s = s / np.sqrt(D).astype(np.float32)
        kpos = c * chunk + jnp.arange(chunk)
        valid = mb[:, None, None, None, :]
        if causal:
            keep = qpos[:, None] >= kpos[None, :]
            if window is not None:
                keep &= qpos[:, None] - kpos[None, :] < window
            valid = valid & keep[None, None, None]
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hk, G, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hk, G, Tq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, vc, maskc, jnp.arange(nchunk))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, Dv)
    return out.astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, kv_mask=None,
                    q_chunk: int = 512, kv_chunk: int = 1024, window=None):
    """2-level tiled attention: scan over q tiles, online-softmax over KV
    tiles with a rematerialized inner body — O(T) live memory forward AND
    backward (the inner scores/probs are recomputed in the bwd pass), at
    the standard flash-attention 2x-recompute cost.
    """
    B, Tq, H, D = q.shape
    nq = -(-Tq // q_chunk)
    pad = nq * q_chunk - Tq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(B, nq, q_chunk, H, D).transpose(1, 0, 2, 3, 4)

    def qbody(_, inp):
        qi, i = inp
        out = chunked_attention(
            qi, k, v, causal=causal, kv_mask=kv_mask, chunk=kv_chunk,
            q_offset=i * q_chunk, window=window)
        return None, out

    _, outs = jax.lax.scan(qbody, None, (qc, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_chunk, H, -1)
    return out[:, :Tq]


def decode_attention(q, k_cache, v_cache, lengths, window=None):
    """q [B,Tq,H,D] against cache [B,S,Hk,D]; ``lengths`` [B] valid prefix
    sizes shared by every query, or [B, Tq] per-query valid counts (the
    speculative-verify window: query ``i`` sees ``lengths[b, i]`` keys —
    its own window predecessors included, later/rejected KV excluded).

    ``window``: sliding-window width — a query with ``n`` valid keys (its
    position is ``n - 1``) additionally masks keys below ``n - window``
    with exact zeros, so evicted cache slots (trash-page garbage included)
    contribute exactly nothing.

    Returns (out [B,Tq,H,D], lse [B,Hk,G,Tq]) — the LSE makes partial
    results combinable across a sequence-sharded cache (flash-decoding).
    """
    B, S = k_cache.shape[:2]
    if lengths.ndim == 2:       # per-query valid counts (verify window)
        kpos = jnp.arange(S)[None, None, :]
        mask = kpos < lengths[:, :, None]
        if window is not None:
            mask &= kpos >= lengths[:, :, None] - window
        mask = mask[:, None, None, :, :]
    else:
        kpos = jnp.arange(S)[None, :]
        mask = kpos < lengths[:, None]
        if window is not None:
            mask &= kpos >= lengths[:, None] - window
        mask = mask[:, None, None, None, :]
    s = _gqa_scores(q.astype(jnp.float32), k_cache.astype(jnp.float32))
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v_cache.astype(jnp.float32))
    out = out / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    B, Hk, G, Tq, D = out.shape
    return (
        out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, Hk * G, D).astype(q.dtype),
        lse,
    )


# --------------------------------------------------------- GQA module -----
def init_gqa(key, cfg, dtype=jnp.float32):
    """cfg needs: d_model, n_heads, n_kv_heads, d_head, qkv_bias."""
    ks = jax.random.split(key, 4)
    H, Hk, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p, s = {}, {}
    p["wq"], s["wq"] = init_linear(
        ks[0], cfg.d_model, H * D, axes=("embed", "heads"),
        bias=cfg.qkv_bias, dtype=dtype)
    p["wk"], s["wk"] = init_linear(
        ks[1], cfg.d_model, Hk * D, axes=("embed", "kv_heads"),
        bias=cfg.qkv_bias, dtype=dtype)
    p["wv"], s["wv"] = init_linear(
        ks[2], cfg.d_model, Hk * D, axes=("embed", "kv_heads"),
        bias=cfg.qkv_bias, dtype=dtype)
    p["wo"], s["wo"] = init_linear(
        ks[3], H * D, cfg.d_model, axes=("heads", "embed"), dtype=dtype)
    return p, s


def gqa_qkv(p, x, cfg, positions, rns=None, *, use_rope=True):
    B, T, _ = x.shape
    H, Hk, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q, k, v = _multi_proj(x, (p["wq"], p["wk"], p["wv"]), rns)
    q = q.reshape(B, T, H, D)
    k = k.reshape(B, T, Hk, D)
    v = v.reshape(B, T, Hk, D)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attend(p, x, cfg, *, mode: str, positions=None, kv_mask=None,
               rns=None, use_rope=True, chunk=1024, xkv=None):
    """Self- (or cross-, via xkv) attention for train/prefill.

    Returns (y, (k, v)) so prefill can populate a KV cache.
    """
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if xkv is None:
        q, k, v = gqa_qkv(p, x, cfg, positions, rns, use_rope=use_rope)
        causal = cfg.causal
        if getattr(cfg, "attn_batch_shard", False):
            from repro.distributed.sharding import constrain

            q = constrain(q, ("batch_all", None, None, None))
            k = constrain(k, ("batch_all", None, None, None))
            v = constrain(v, ("batch_all", None, None, None))
    else:  # cross-attention: keys/values from the encoder stream
        Hk, D = cfg.n_kv_heads, cfg.d_head
        q = linear(p["wq"], x, rns).reshape(B, T, cfg.n_heads, D)
        Tk = xkv.shape[1]
        k, v = _multi_proj(xkv, (p["wk"], p["wv"]), rns)
        k = k.reshape(B, Tk, Hk, D)
        v = v.reshape(B, Tk, Hk, D)
        causal = False
    window = cfg.attn_window if causal else None
    if mode == "dense":
        out = dense_attention(q, k, v, causal=causal, kv_mask=kv_mask,
                              window=window)
    elif mode == "chunked":
        out = chunked_attention(q, k, v, causal=causal, kv_mask=kv_mask,
                                chunk=chunk, window=window)
    elif mode == "flash":
        out = flash_attention(q, k, v, causal=causal, kv_mask=kv_mask,
                              kv_chunk=chunk, window=window)
    else:
        raise ValueError(mode)
    return linear(p["wo"], out.reshape(B, T, -1), rns), (k, v)


def gqa_decode(p, x, cfg, cache, *, rns=None, use_rope=True):
    """One-token decode.  cache: {"k","v" [B,S,Hk,D], "lengths" [B]}.

    Returns (y [B,1,d], k_cache, v_cache) with the new token's K/V planes
    scattered in at per-row ``lengths``.
    """
    B = x.shape[0]
    positions = cache["lengths"][:, None]
    q, k, v = gqa_qkv(p, x, cfg, positions, rns, use_rope=use_rope)
    idx = jnp.arange(B)
    k_cache = cache["k"].at[idx, cache["lengths"]].set(
        k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[idx, cache["lengths"]].set(
        v[:, 0].astype(cache["v"].dtype))
    out, _lse = decode_attention(q, k_cache, v_cache, cache["lengths"] + 1,
                                 window=cfg.attn_window)
    y = linear(p["wo"], out.reshape(B, 1, -1), rns)
    return y, k_cache, v_cache


def gqa_decode_paged(p, x, cfg, cache, *, rns=None, use_rope=True):
    """One-token decode against a paged KV cache (continuous batching).

    cache: {"k_pages","v_pages" [P,bs,Hk,D], "block_table" [R,nb],
    "lengths" [R]}.  The new token's K/V are scattered into the row's
    current page, then the row's pages are gathered back into a dense
    [R, nb*bs, Hk, D] view — numerically identical to the dense-cache
    path (positions past ``lengths`` are masked to exact zeros in the
    softmax, so the page-pool garbage there never contributes).

    Returns (y [B,1,d], k_pages, v_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_token

    B = x.shape[0]
    positions = cache["lengths"][:, None]
    q, k, v = gqa_qkv(p, x, cfg, positions, rns, use_rope=use_rope)
    k_pages = write_token(cache["k_pages"], cache["block_table"],
                          cache["lengths"], k[:, 0])
    v_pages = write_token(cache["v_pages"], cache["block_table"],
                          cache["lengths"], v[:, 0])
    kd = gather_pages(k_pages, cache["block_table"])
    vd = gather_pages(v_pages, cache["block_table"])
    out, _lse = decode_attention(q, kd, vd, cache["lengths"] + 1,
                                 window=cfg.attn_window)
    y = linear(p["wo"], out.reshape(B, 1, -1), rns)
    return y, k_pages, v_pages


def gqa_decode_paged_window(p, x, cfg, cache, *, rns=None, use_rope=True):
    """W-token speculative-verify decode against a paged KV cache.

    x [R, W, d]: the window [last_token, draft_1, ..., draft_{W-1}].  All
    W tokens' K/V are scattered at positions lengths..lengths+W-1 (writes
    past the row's allocated pages are redirected to the trash page — the
    engine caps acceptance to what landed on real pages), then causal
    window attention runs over the gathered dense view.  ``lengths`` is
    NOT advanced here: the engine sets it to length + accepted + 1 after
    the greedy accept/reject.

    Returns (y [R, W, d], k_pages, v_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_token_window

    B, W = x.shape[:2]
    positions = cache["lengths"][:, None] + jnp.arange(W)[None]
    q, k, v = gqa_qkv(p, x, cfg, positions, rns, use_rope=use_rope)
    k_pages = write_token_window(cache["k_pages"], cache["block_table"],
                                 cache["lengths"], k)
    v_pages = write_token_window(cache["v_pages"], cache["block_table"],
                                 cache["lengths"], v)
    kd = gather_pages(k_pages, cache["block_table"])
    vd = gather_pages(v_pages, cache["block_table"])
    qlen = cache["lengths"][:, None] + 1 + jnp.arange(W)[None]   # [R, W]
    out, _lse = decode_attention(q, kd, vd, qlen, window=cfg.attn_window)
    y = linear(p["wo"], out.reshape(B, W, -1), rns)
    return y, k_pages, v_pages


def gqa_decode_packed(p, x, cfg, cache, seg, pos, *, rns=None, use_rope=True):
    """Packed mixed-phase step: N tokens, each with explicit (segment,
    position) coordinates, against a paged KV cache.

    ``x`` [1, N, d]: token i belongs to row ``seg[i]`` at absolute
    position ``pos[i]`` — any mix of prefill-chunk tokens and decode
    rows, padding-free (pad lanes carry ``seg = -1`` and write to the
    trash page).  All N tokens' K/V are scattered *before* the gather,
    so a chunk token attends both earlier chunks' KV pages and its own
    chunk predecessors; the per-token causal mask is ``pos + 1`` keys.

    Exactness: every token runs :func:`decode_attention` over its row's
    gathered pages, which is bitwise the solo math for both token kinds
    — for decode rows it IS the solo path (``gqa_decode_paged``
    modulo layout), and for chunk tokens it equals the single-chunk
    online softmax of :func:`chunked_attention` (the ``m0 = -inf``
    correction underflows to an exact 0.0 and masked keys contribute
    exact zeros), valid while a row's gathered context fits one KV chunk
    (``max_blocks * page_size <= 1024`` — smoke/serve scales here).

    Returns (y [1, N, d], k_pages, v_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_packed_tokens

    N = x.shape[1]
    q, k, v = gqa_qkv(p, x, cfg, pos[None], rns, use_rope=use_rope)
    k_pages = write_packed_tokens(cache["k_pages"], cache["block_table"],
                                  seg, pos, k[0])
    v_pages = write_packed_tokens(cache["v_pages"], cache["block_table"],
                                  seg, pos, v[0])
    R = cache["block_table"].shape[0]
    segc = jnp.clip(seg, 0, R - 1)
    kd = gather_pages(k_pages, cache["block_table"])[segc]   # [N, S, Hk, D]
    vd = gather_pages(v_pages, cache["block_table"])[segc]
    out, _lse = decode_attention(q[0][:, None], kd, vd, pos + 1,
                                 window=cfg.attn_window)
    y = linear(p["wo"], out.reshape(1, N, -1), rns)
    return y, k_pages, v_pages


def cross_decode(p, x, cfg, xkv, *, rns=None):
    """Decode-time cross-attention over a static encoder KV (enc-dec archs).

    xkv: {"k","v" [B,Te,Hk,D], "lengths" [B]} precomputed at prefill through
    this layer's wk/wv.
    """
    B = x.shape[0]
    H, D = cfg.n_heads, cfg.d_head
    q = linear(p["wq"], x, rns).reshape(B, 1, H, D)
    out, _ = decode_attention(q, xkv["k"], xkv["v"], xkv["lengths"])
    return linear(p["wo"], out.reshape(B, 1, -1), rns)


# ----------------------------------------------------------- MLA (DSv2) ---
def init_mla(key, cfg, dtype=jnp.float32):
    """DeepSeek-V2 multi-head latent attention params."""
    m = cfg.mla
    ks = jax.random.split(key, 8)
    H = cfg.n_heads
    p, s = {}, {}
    p["wdq"], s["wdq"] = init_linear(
        ks[0], cfg.d_model, m.q_lora_rank, axes=("embed", "lora"), dtype=dtype)
    # nope/rope up-projections kept as separate weights: a fused [lora,
    # H*(dn+dr)] projection shards on the flat dim and the per-head split
    # then crosses shard boundaries (XLA re-gathers the whole q; see
    # EXPERIMENTS.md §Perf deepseek iter 3)
    p["wuqn"], s["wuqn"] = init_linear(
        ks[1], m.q_lora_rank, H * m.qk_nope_dim, axes=("lora", "heads"),
        dtype=dtype)
    p["wuqr"], s["wuqr"] = init_linear(
        jax.random.fold_in(ks[1], 1), m.q_lora_rank, H * m.qk_rope_dim,
        axes=("lora", "heads"), dtype=dtype)
    p["wdkv"], s["wdkv"] = init_linear(
        ks[2], cfg.d_model, m.kv_lora_rank, axes=("embed", "lora"), dtype=dtype)
    p["wkr"], s["wkr"] = init_linear(
        ks[3], cfg.d_model, m.qk_rope_dim, axes=("embed", "lora"), dtype=dtype)
    p["wuk"], s["wuk"] = init_linear(
        ks[4], m.kv_lora_rank, H * m.qk_nope_dim, axes=("lora", "heads"), dtype=dtype)
    p["wuv"], s["wuv"] = init_linear(
        ks[5], m.kv_lora_rank, H * m.v_dim, axes=("lora", "heads"), dtype=dtype)
    p["wo"], s["wo"] = init_linear(
        ks[6], H * m.v_dim, cfg.d_model, axes=("heads", "embed"), dtype=dtype)
    from repro.models.layers import init_rmsnorm

    p["q_norm"], s["q_norm"] = init_rmsnorm(m.q_lora_rank, dtype)
    p["kv_norm"], s["kv_norm"] = init_rmsnorm(m.kv_lora_rank, dtype)
    return p, s


def mla_qkv(p, x, cfg, positions, rns=None):
    """Returns q, k, v expanded per head + the compressed (c_kv, k_rope) pair."""
    from repro.distributed.sharding import constrain
    from repro.models.layers import rmsnorm

    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    # the down-projection pair (wdkv, wkr) and the up-projection pair
    # (wuqn, wuqr) each share one forward conversion on the RNS path
    dq, dkv, kr = _multi_proj(x, (p["wdq"], p["wdkv"], p["wkr"]), rns)
    cq = rmsnorm(p["q_norm"], dq)
    q_nope, q_rope = _multi_proj(cq, (p["wuqn"], p["wuqr"]), rns)
    q_nope = q_nope.reshape(B, T, H, m.qk_nope_dim)
    q_rope = q_rope.reshape(B, T, H, m.qk_rope_dim)
    q_nope = constrain(q_nope, ("batch", None, "model", None))
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    c_kv = rmsnorm(p["kv_norm"], dkv)                              # [B,T,r]
    k_rope = rope(
        kr[:, :, None, :], positions, cfg.rope_theta
    )                                                              # [B,T,1,dr]
    k_nope, v = _multi_proj(c_kv, (p["wuk"], p["wuv"]), rns)
    k_nope = k_nope.reshape(B, T, H, m.qk_nope_dim)
    k_nope = constrain(k_nope, ("batch", None, "model", None))
    v = v.reshape(B, T, H, m.v_dim)
    v = constrain(v, ("batch", None, "model", None))
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, H, m.qk_rope_dim))], axis=-1
    )
    return q, k, v, (c_kv, k_rope[:, :, 0, :])


def mla_attend(p, x, cfg, *, mode: str, positions=None, kv_mask=None,
               rns=None, chunk=1024):
    """Train/prefill MLA.  Returns (y, (c_kv, k_rope)) for the latent cache."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    q, k, v, latent = mla_qkv(p, x, cfg, positions, rns)
    window = cfg.attn_window if cfg.causal else None
    if mode == "dense":
        out = dense_attention(q, k, v, causal=cfg.causal, kv_mask=kv_mask,
                              window=window)
    elif mode == "chunked":
        out = chunked_attention(q, k, v, causal=cfg.causal, kv_mask=kv_mask,
                                chunk=chunk, window=window)
    elif mode == "flash":
        out = flash_attention(q, k, v, causal=cfg.causal, kv_mask=kv_mask,
                              kv_chunk=chunk, window=window)
    else:
        raise ValueError(mode)
    return linear(p["wo"], out.reshape(B, T, -1), rns), latent


def _mla_proj_at(p, x, cfg, positions, rns):
    """Decode-time MLA projections at explicit absolute ``positions`` [B,T].

    Returns (q_nope [B,T,H,dn], q_rope [B,T,H,dr] roped, c_kv_t [B,T,r],
    k_rope_t [B,T,dr] roped) — everything the cache write + absorbed
    attention need, for either cache layout.  Per token this is the same
    math as :func:`mla_qkv` up to (and excluding) the k/v expansion, so
    the latents written to the cache are bitwise those a whole-prompt
    prefill would produce.
    """
    from repro.models.layers import rmsnorm

    m = cfg.mla
    B, T = x.shape[:2]
    H = cfg.n_heads
    dq, dkv, kr = _multi_proj(x, (p["wdq"], p["wdkv"], p["wkr"]), rns)
    cq = rmsnorm(p["q_norm"], dq)
    q_nope, q_rope = _multi_proj(cq, (p["wuqn"], p["wuqr"]), rns)
    q_nope = q_nope.reshape(B, T, H, m.qk_nope_dim)
    q_rope = q_rope.reshape(B, T, H, m.qk_rope_dim)
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    c_kv_t = rmsnorm(p["kv_norm"], dkv)                             # [B,T,r]
    k_rope_t = rope(
        kr[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]                                                    # [B,T,dr]
    return q_nope, q_rope, c_kv_t, k_rope_t


def _mla_decode_proj(p, x, cfg, lengths, rns):
    """MLA projections for T=1 decode or a T=W verify window: token ``i``
    sits at absolute position ``lengths + i`` (see :func:`_mla_proj_at`)."""
    T = x.shape[1]
    positions = lengths[:, None] + jnp.arange(T)[None]
    return _mla_proj_at(p, x, cfg, positions, rns)


def _mla_absorbed_ctx(p, cfg, q_nope, q_rope, c_kv, k_rope, lengths,
                      window=None):
    """Absorbed-matrix latent attention core (everything before ``wo``).

    W_uk is absorbed into the query and W_uv into the output so attention
    runs directly in the latent space (MQA-shaped, Hk=1).  ``lengths``:
    [B] valid key counts shared by every query (one-token decode), or
    [B, T] per-query counts (speculative-verify window, query ``i`` sees
    ``lengths[b, i]`` keys).  ``window``: sliding-window width — keys
    below ``lengths - window`` are masked with exact zeros (see
    :func:`decode_attention`).  Returns (out [B,T,H,v_dim] float32,
    lse [B,1,H,T]) — the packed mixed step selects between this and the
    expanded (prefill-math) context per token before the shared ``wo``.
    """
    m = cfg.mla
    H = cfg.n_heads
    wuk = p["wuk"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_abs = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = (
        jnp.einsum("bthr,bsr->bhts", q_abs, c_kv.astype(jnp.float32))
        + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * scale                                                        # [B,H,T,S]
    S = c_kv.shape[1]
    if lengths.ndim == 2:       # per-query valid counts (verify window)
        kpos = jnp.arange(S)[None, None, :]
        mask = kpos < lengths[:, :, None]
        if window is not None:
            mask &= kpos >= lengths[:, :, None] - window
        mask = mask[:, None, :, :]
    else:
        kpos = jnp.arange(S)[None, :]
        mask = kpos < lengths[:, None]
        if window is not None:
            mask &= kpos >= lengths[:, None] - window
        mask = mask[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    mx = jnp.max(s, axis=-1)
    pr = jnp.exp(s - mx[..., None])
    l = jnp.sum(pr, axis=-1)
    ctx = jnp.einsum("bhts,bsr->bthr", pr / jnp.maximum(l, 1e-30)[..., None],
                     c_kv.astype(jnp.float32))                       # [B,T,H,r]
    wuv = p["wuv"]["w"].reshape(m.kv_lora_rank, H, m.v_dim)
    out = jnp.einsum("bthr,rhd->bthd", ctx, wuv.astype(jnp.float32))
    lse = (mx + jnp.log(jnp.maximum(l, 1e-30)))[:, None, :, :]  # [B,1,H,T]
    return out, lse


def _mla_absorbed_attend(p, x, cfg, q_nope, q_rope, c_kv, k_rope, lengths,
                         rns):
    """:func:`_mla_absorbed_ctx` + the output projection.  Returns
    (y [B,T,d], lse [B,1,H,T])."""
    B = x.shape[0]
    out, lse = _mla_absorbed_ctx(p, cfg, q_nope, q_rope, c_kv, k_rope,
                                 lengths, window=cfg.attn_window)
    T = out.shape[1]
    y = linear(p["wo"], out.reshape(B, T, -1).astype(x.dtype), rns)
    return y, lse


def mla_decode(p, x, cfg, cache, *, rns=None):
    """Absorbed-matrix MLA decode (DeepSeek-V2's deployment form).

    cache: {"c_kv" [B,S,r], "k_rope" [B,S,dr], "lengths" [B]} — the latent
    cache is (r + dr) per token instead of 2*H*D: the paper's compression.

    Returns (y [B,1,d], c_kv_cache, k_rope_cache, lse) — lse has shape
    [B,1(Hk),H(G),1] for sequence-sharded combination.
    """
    B = x.shape[0]
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_decode_proj(
        p, x, cfg, cache["lengths"], rns)
    idx = jnp.arange(B)
    c_kv = cache["c_kv"].at[idx, cache["lengths"]].set(
        c_kv_t[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[idx, cache["lengths"]].set(
        k_rope_t[:, 0].astype(cache["k_rope"].dtype))
    y, lse = _mla_absorbed_attend(
        p, x, cfg, q_nope, q_rope, c_kv, k_rope, cache["lengths"] + 1, rns)
    return y, c_kv, k_rope, lse


def mla_decode_paged(p, x, cfg, cache, *, rns=None):
    """MLA decode against a paged latent cache (continuous batching).

    cache: {"ckv_pages" [P,bs,r], "krope_pages" [P,bs,dr], "block_table"
    [R,nb], "lengths" [R]}.  Returns (y, ckv_pages, krope_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_token

    q_nope, q_rope, c_kv_t, k_rope_t = _mla_decode_proj(
        p, x, cfg, cache["lengths"], rns)
    ckv_pages = write_token(cache["ckv_pages"], cache["block_table"],
                            cache["lengths"], c_kv_t[:, 0])
    krope_pages = write_token(cache["krope_pages"], cache["block_table"],
                              cache["lengths"], k_rope_t[:, 0])
    c_kv = gather_pages(ckv_pages, cache["block_table"])
    k_rope = gather_pages(krope_pages, cache["block_table"])
    y, _lse = _mla_absorbed_attend(
        p, x, cfg, q_nope, q_rope, c_kv, k_rope, cache["lengths"] + 1, rns)
    return y, ckv_pages, krope_pages


def mla_decode_paged_window(p, x, cfg, cache, *, rns=None):
    """W-token speculative-verify MLA decode against a paged latent cache.

    x [R, W, d]; all W window tokens' latents are scattered at positions
    lengths..lengths+W-1, then the absorbed attention runs with per-query
    causal masks (query ``i`` sees ``lengths + i + 1`` latents — its own
    window predecessors included, later/rejected positions excluded).
    ``lengths`` is advanced by the engine after accept/reject, not here.

    Returns (y [R, W, d], ckv_pages, krope_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_token_window

    W = x.shape[1]
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_decode_proj(
        p, x, cfg, cache["lengths"], rns)
    ckv_pages = write_token_window(cache["ckv_pages"], cache["block_table"],
                                   cache["lengths"], c_kv_t)
    krope_pages = write_token_window(cache["krope_pages"],
                                     cache["block_table"],
                                     cache["lengths"], k_rope_t)
    c_kv = gather_pages(ckv_pages, cache["block_table"])
    k_rope = gather_pages(krope_pages, cache["block_table"])
    qlen = cache["lengths"][:, None] + 1 + jnp.arange(W)[None]   # [R, W]
    y, _lse = _mla_absorbed_attend(
        p, x, cfg, q_nope, q_rope, c_kv, k_rope, qlen, rns)
    return y, ckv_pages, krope_pages


def mla_decode_packed(p, x, cfg, cache, seg, pos, dec, *, rns=None):
    """Packed mixed-phase MLA step against a paged latent cache.

    Same packed layout as :func:`gqa_decode_packed` (``x`` [1, N, d],
    per-token ``seg``/``pos``), plus a per-token kind mask ``dec`` [N]
    bool.  MLA's two deployment forms are NOT bitwise interchangeable —
    solo prefill runs *expanded* attention (latents up-projected through
    ``wuk``/``wuv``, one dot over dn+dr) while solo decode runs
    *absorbed* attention (two latent-space einsums summed) — so the
    packed step computes BOTH contexts over the gathered latents and
    selects per token: absorbed where ``dec`` (decode rows), expanded
    where not (prefill-chunk tokens).  Re-expanding the *gathered*
    latents is exact because the latent cache is float32 and the
    expansion matmul treats every (token, position) row independently.

    The expansion's ``rns`` grid cannot be reproduced for gathered
    latents (the solo per-token grid info is gone), so the engine
    rejects chunked MLA with ``rns_targets="all"``; with attention off
    the RNS path (``rns is None`` here) both kinds are bitwise solo.

    Returns (y [1, N, d], ckv_pages, krope_pages).
    """
    from repro.serve.kv_cache import gather_pages, write_packed_tokens

    m = cfg.mla
    N = x.shape[1]
    H = cfg.n_heads
    q_nope, q_rope, c_kv_t, k_rope_t = _mla_proj_at(p, x, cfg, pos[None],
                                                    rns)
    ckv_pages = write_packed_tokens(cache["ckv_pages"], cache["block_table"],
                                    seg, pos, c_kv_t[0])
    krope_pages = write_packed_tokens(cache["krope_pages"],
                                      cache["block_table"],
                                      seg, pos, k_rope_t[0])
    R = cache["block_table"].shape[0]
    segc = jnp.clip(seg, 0, R - 1)
    c_kv = gather_pages(ckv_pages, cache["block_table"])[segc]      # [N,S,r]
    k_rope = gather_pages(krope_pages, cache["block_table"])[segc]  # [N,S,dr]
    qn = q_nope[0][:, None]                                     # [N,1,H,dn]
    qr = q_rope[0][:, None]
    # absorbed context: bitwise the solo decode math per row
    abs_out, _ = _mla_absorbed_ctx(p, cfg, qn, qr, c_kv, k_rope, pos + 1,
                                   window=cfg.attn_window)
    # expanded context: bitwise the solo prefill math per chunk token
    S = c_kv.shape[1]
    k_nope, v = _multi_proj(c_kv, (p["wuk"], p["wuv"]), rns)
    k_nope = k_nope.reshape(N, S, H, m.qk_nope_dim)
    v = v.reshape(N, S, H, m.v_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (N, S, H, m.qk_rope_dim))], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    exp_out, _lse = decode_attention(q, k, v, pos + 1,
                                     window=cfg.attn_window)    # [N,1,H,vd]
    out = jnp.where(dec[:, None, None, None], abs_out,
                    exp_out.astype(jnp.float32))
    y = linear(p["wo"], out.reshape(1, N, -1).astype(x.dtype), rns)
    return y, ckv_pages, krope_pages
