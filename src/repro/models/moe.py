"""Mixture-of-Experts: top-k router + capacity dispatch + shared experts.

Dispatch strategy (GSPMD-friendly, no global sort):
  * top-k and the token->(expert, slot) permutation are computed PER BATCH
    ROW (vmapped argsort over S*k entries), so the sort is local to the
    data shard that owns the row — no cross-chip sort.
  * expert buffers [B, E, C, d] are then contracted against expert weights
    sharded over the `model` axis on E (expert parallelism); XLA lowers the
    B-sharded -> E-sharded re-layout to the canonical MoE all-to-all.
  * tokens beyond capacity C = ceil(S*k/E * capacity_factor) are dropped
    (GShard semantics); the combine scatter weights by router probs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp, mlp, init_linear, linear


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # always-on shared experts (DeepSeek-style)
    capacity_factor: float = 1.25
    router_norm_topk: bool = True   # renormalize top-k probs to sum to 1
    aux_loss_weight: float = 0.01
    # "scatter": first-cut dispatch — scatter [B,S,k,d] into buffers
    #            (materializes the k-fold activation broadcast; kept for the
    #            recorded §Dry-run baseline).
    # "gather":  slot->token index plumbing, activations move only at
    #            [B,E,C,d] granularity — 18x less wire on deepseek train
    #            (EXPERIMENTS.md §Perf); the production default.
    dispatch: str = "gather"


def init_moe(key, d_model, cfg: MoEConfig, *, act="silu", dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["router"], s["router"] = init_linear(
        ks[0], d_model, cfg.n_experts, axes=("embed", "expert_vec"), dtype=dtype)
    E, F = cfg.n_experts, cfg.d_ff_expert
    scale = float(1.0 / d_model**0.5)
    p["wi"] = jax.random.normal(ks[1], (E, d_model, F), dtype) * scale
    p["wg"] = jax.random.normal(ks[2], (E, d_model, F), dtype) * scale
    p["wo"] = jax.random.normal(ks[3], (E, F, d_model), dtype) * float(1.0 / F**0.5)
    s["wi"] = ("expert", "embed", "mlp")
    s["wg"] = ("expert", "embed", "mlp")
    s["wo"] = ("expert", "mlp", "embed")
    if cfg.n_shared:
        p["shared"], s["shared"] = init_mlp(
            jax.random.fold_in(key, 7), d_model, F * cfg.n_shared,
            gated=True, act=act, dtype=dtype)
    return p, s


def _dispatch_one_row(gates_idx, S, E, C, k):
    """Per-sequence permutation: (expert id, slot) for each of S*k entries.

    gates_idx: [S, k] int32 expert ids.  Returns (expert, slot, keep) each
    [S, k]: slot is the entry's rank within its expert's arrivals.
    """
    flat = gates_idx.reshape(-1)                      # [S*k]
    order = jnp.argsort(flat, stable=True)            # local sort
    sorted_e = flat[order]
    # rank within expert group = position - first position of that expert
    pos = jnp.arange(S * k, dtype=jnp.int32)
    seg_start = jnp.full((E,), S * k, jnp.int32).at[sorted_e].min(pos)
    rank_sorted = pos - seg_start[sorted_e]
    # unsort back to [S*k]
    rank = jnp.zeros(S * k, jnp.int32).at[order].set(rank_sorted.astype(jnp.int32))
    keep = rank < C
    return flat.reshape(S, k), rank.reshape(S, k), keep.reshape(S, k)


def moe_ffn(p, x, cfg: MoEConfig, *, act="silu", rns=None):
    """x [B, S, d] -> (y [B, S, d], aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(S * k / E * cfg.capacity_factor))

    # router matmul in the activation dtype; only the E-wide LOGITS go f32
    # (an f32 copy of x makes every backward activation collective f32)
    logits = linear(p["router"], x).astype(jnp.float32)           # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # [B,S,k]
    if cfg.router_norm_topk:
        top_p = top_p / jnp.maximum(
            jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    # fe via scatter-add counts, NOT one_hot (a [B,S,k,E] f32 one-hot is a
    # multi-TB tensor at 1M tokens x 160 experts)
    me = jnp.mean(probs, axis=(0, 1))                             # [E]
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    fe = counts / (B * S)
    aux = cfg.aux_loss_weight * E * jnp.sum(me * fe)

    expert, slot, keep = jax.vmap(
        lambda gi: _dispatch_one_row(gi, S, E, C, k))(top_i)      # [B,S,k]

    from repro.distributed.sharding import constrain

    if cfg.dispatch == "gather":
        # ---- index plumbing: slot -> (token, prob), all [B, E*C] int/f32 ---
        slot_g = expert * C + jnp.minimum(slot, C - 1)            # [B,S,k]
        slot_g = jnp.where(keep, slot_g, E * C)                   # sentinel
        tok_ids = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, k))
        # vmap over the batch row => gather/scatter carry explicit batching
        # dims, which GSPMD partitions batch-parallel (an arange-indexed
        # gather all-gathers the whole operand instead; see §Perf)
        token_for_slot = jax.vmap(
            lambda sg, ti: jnp.full((E * C + 1,), S, jnp.int32).at[sg].set(ti)
        )(slot_g, tok_ids)
        prob_for_slot = jax.vmap(
            lambda sg, tp: jnp.zeros((E * C + 1,), jnp.float32).at[sg].set(tp)
        )(slot_g, top_p)
        token_for_slot = token_for_slot[:, :-1].reshape(B, E, C)
        prob_for_slot = prob_for_slot[:, :-1].reshape(B, E, C)
        # ---- gather activations straight into [B, E, C, d] ----------------
        x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
        buf = jax.vmap(lambda xr, t: xr[t])(x_pad, token_for_slot)
        buf = constrain(buf, ("batch", "model", None, None))
        h_in = jnp.einsum("becd,edf->becf", buf, p["wi"])
        h_g = jnp.einsum("becd,edf->becf", buf, p["wg"])
        h = jax.nn.silu(h_g) * h_in if act == "silu" else jax.nn.gelu(h_g) * h_in
        out = jnp.einsum("becf,efd->becd", h, p["wo"])            # [B,E,C,d]
        out = out * prob_for_slot[..., None].astype(out.dtype)
        # ---- combine: scatter-add per slot (no [B,S,k,d] broadcast) --------
        y = jax.vmap(
            lambda o, t: jnp.zeros((S + 1, d), o.dtype).at[t].add(o)
        )(out, token_for_slot)[:, :S]
        y = constrain(y, ("batch", None, None))
    else:
        # scatter tokens into expert buffers [B, E, C, d]
        buf = jnp.zeros((B, E, C, d), x.dtype)
        bidx = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, S, k))
        slot_c = jnp.minimum(slot, C - 1)
        xk = jnp.broadcast_to(x[:, :, None, :], (B, S, k, d))
        xk = jnp.where(keep[..., None], xk, 0)
        buf = buf.at[bidx, expert, slot_c].add(xk)
        # expert parallelism: buffers live expert-sharded (B->dp, E->model);
        # the reshard from token-sharded x is the canonical MoE all-to-all
        buf = constrain(buf, ("batch", "model", None, None))
        h_in = jnp.einsum("becd,edf->becf", buf, p["wi"])
        h_g = jnp.einsum("becd,edf->becf", buf, p["wg"])
        h = jax.nn.silu(h_g) * h_in if act == "silu" else jax.nn.gelu(h_g) * h_in
        out = jnp.einsum("becf,efd->becd", h, p["wo"])            # [B,E,C,d]
        got = out[bidx, expert, slot_c]                           # [B,S,k,d]
        got = jnp.where(keep[..., None], got, 0)
        y = jnp.sum(got * top_p[..., None].astype(got.dtype), axis=2)

    if cfg.n_shared:
        y = y + mlp(p["shared"], x, gated=True, act=act, rns=rns)
    return y.astype(x.dtype), aux
