"""Transformer stacks: decoder-only, encoder-decoder, hybrid (SSM/MoE).

Layers are grouped into the smallest periodic pattern (cfg.period) and
scanned over periods — params are stacked pytrees with a leading
``n_periods`` dim, which keeps HLO size and compile time bounded for
64-layer archs, and gives FSDP a natural per-iteration all-gather point.

Modes:
  train   -> dense attention, full remat per period (policy: save nothing)
  prefill -> chunked (online-softmax) attention, returns a decode cache
  decode  -> one token through per-layer caches (attn KV / MLA latent /
             mamba state / rwkv state)

``cfg.attn_window`` tightens every causal mode to a sliding window
(query q attends keys [q - window + 1, q], exact-zero masking outside)
without touching this file's control flow — the gqa/mla wrappers in
models/attention.py read it and thread ``window=`` through all three
attention modes and every decode/paged/packed variant, so train,
prefill, decode and the serving engines all see the same receptive
field (docs/serving.md).

RNS execution: ``cfg.rns`` selects the digit-sliced datapath per target
(attn/mlp/all).  Inside a block the projections share forward conversions
(models/attention.py) and, with ``cfg.rns.defer``, the MLP's
wi -> gate -> wo chain runs residues-in/residues-out with one MRC
normalization on the main path (models/layers.py) — blocks exchange
floats only at the residual stream.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    embed,
    init_embedding,
    init_linear,
    init_mlp,
    init_norm,
    linear,
    mlp,
    norm,
    sinusoidal_positions,
)


def _rns_for(cfg, target: str):
    if cfg.rns is None:
        return None
    if cfg.rns_targets == "all" or cfg.rns_targets == target:
        return cfg.rns
    return None


# ------------------------------------------------------------ layer init ---
def _init_layer(key, cfg, layer_type: str, mlp_type: str, dtype):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if layer_type == "attn":
        p["attn"], s["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    elif layer_type == "mla":
        p["attn"], s["attn"] = attn.init_mla(ks[0], cfg, dtype)
    elif layer_type == "mamba":
        p["mamba"], s["mamba"] = ssm_lib.init_mamba(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif layer_type == "rwkv":
        p["rwkv"], s["rwkv"] = ssm_lib.init_rwkv6(
            ks[0], cfg.d_model, cfg.ssm, cfg.d_ff, dtype)
    else:
        raise ValueError(layer_type)
    if cfg.enc_dec and layer_type == "attn" and mlp_type != "__enc__":
        p["lnx"], s["lnx"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["xattn"], s["xattn"] = attn.init_gqa(ks[2], cfg, dtype)
    if mlp_type in ("dense", "__enc__"):
        p["ln2"], s["ln2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        rns_mlp = _rns_for(cfg, "mlp") is not None
        p["mlp"], s["mlp"] = init_mlp(
            ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp, act=cfg.act,
            dtype=dtype,
            down_axes=((None, "mlp") if rns_mlp else ("mlp", "embed")))
    elif mlp_type == "moe":
        p["ln2"], s["ln2"] = init_norm(cfg.d_model, cfg.norm, dtype)
        p["moe"], s["moe"] = moe_lib.init_moe(
            ks[1], cfg.d_model, cfg.moe, act=cfg.act, dtype=dtype)
    # rwkv channel-mix lives inside the rwkv param dict; "none" adds nothing
    return p, s


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def init_blocks(key, cfg, *, enc: bool = False):
    """Init (stacked params, specs-with-'layers'-axis-prepended)."""
    dtype = jnp.dtype(cfg.param_dtype)
    L = cfg.n_enc_layers if enc else cfg.n_layers
    ltypes = ("attn",) * L if enc else cfg.layer_types
    mtypes = ("__enc__",) * L if enc else cfg.mlp_types
    p = cfg.period if not enc else 1
    n_periods = L // p
    periods, specs = [], None
    for per in range(n_periods):
        pp = {}
        for j in range(p):
            li = per * p + j
            lp, ls = _init_layer(
                jax.random.fold_in(key, li), cfg, ltypes[li], mtypes[li], dtype)
            pp[f"l{j}"] = lp
            if per == 0:
                specs = specs or {}
                specs[f"l{j}"] = ls
        periods.append(pp)
    stacked = _stack(periods)
    specs = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), specs,
        is_leaf=lambda x: isinstance(x, tuple))
    return stacked, specs


# ---------------------------------------------------------- layer apply ----
def _apply_layer(lp, h, cfg, layer_type, mlp_type, *, mode, positions,
                 kv_mask, enc_out, cache, chunk=1024, packed=None):
    """Returns (h, new_cache_entry, prefill_kv, aux).

    ``packed`` (decode mode, paged caches only): a ``(seg, pos, dec)``
    triple of [N] arrays giving every token of the [1, N, d] stream its
    own row, absolute position, and phase (decode vs prefill chunk) —
    the mixed chunked-prefill/decode step's layout.
    """
    rns_a = _rns_for(cfg, "attn")
    rns_m = _rns_for(cfg, "mlp")
    aux = jnp.zeros((), jnp.float32)
    new_cache, prefill_kv = None, None
    use_rope = cfg.pos_emb == "rope"

    if layer_type in ("attn", "mla"):
        hn = norm(lp["ln1"], h, cfg.norm)
        if mode == "decode":
            # paged caches (continuous batching) are recognized by their
            # page-pool keys; the dense layout stays the default.  A
            # multi-token query ([R, W] speculative-verify window) only
            # exists on the paged path.
            window = hn.shape[1] > 1
            if packed is not None:
                if "k_pages" not in cache and "ckv_pages" not in cache:
                    raise NotImplementedError(
                        "packed mixed steps need the paged cache layout")
                seg, pos, dec = packed
                if layer_type == "attn":
                    y, kp, vp = attn.gqa_decode_packed(
                        lp["attn"], hn, cfg, cache, seg, pos, rns=rns_a,
                        use_rope=use_rope)
                    new_cache = dict(cache, k_pages=kp, v_pages=vp)
                else:
                    y, cp, kp = attn.mla_decode_packed(
                        lp["attn"], hn, cfg, cache, seg, pos, dec, rns=rns_a)
                    new_cache = dict(cache, ckv_pages=cp, krope_pages=kp)
            elif window and "k_pages" not in cache and "ckv_pages" not in cache:
                raise NotImplementedError(
                    "multi-token decode windows (speculative verify) need "
                    "the paged cache layout")
            elif layer_type == "attn":
                if "k_pages" in cache:
                    fn = (attn.gqa_decode_paged_window if window
                          else attn.gqa_decode_paged)
                    y, kp, vp = fn(lp["attn"], hn, cfg, cache, rns=rns_a,
                                   use_rope=use_rope)
                    new_cache = dict(cache, k_pages=kp, v_pages=vp)
                else:
                    y, kc, vc = attn.gqa_decode(
                        lp["attn"], hn, cfg, cache, rns=rns_a,
                        use_rope=use_rope)
                    new_cache = dict(cache, k=kc, v=vc)
            else:
                if "ckv_pages" in cache:
                    fn = (attn.mla_decode_paged_window if window
                          else attn.mla_decode_paged)
                    y, cp, kp = fn(lp["attn"], hn, cfg, cache, rns=rns_a)
                    new_cache = dict(cache, ckv_pages=cp, krope_pages=kp)
                else:
                    y, ckv, krope, _lse = attn.mla_decode(
                        lp["attn"], hn, cfg, cache, rns=rns_a)
                    new_cache = dict(cache, c_kv=ckv, k_rope=krope)
        else:
            T = hn.shape[1]
            if mode == "train":
                amode = "dense" if T <= cfg.attn_dense_max else "flash"
            else:
                amode = "chunked" if T <= cfg.attn_dense_max else "flash"
            if layer_type == "attn":
                y, kv = attn.gqa_attend(
                    lp["attn"], hn, cfg, mode=amode, positions=positions,
                    kv_mask=kv_mask, rns=rns_a, use_rope=use_rope, chunk=chunk)
            else:
                y, kv = attn.mla_attend(
                    lp["attn"], hn, cfg, mode=amode, positions=positions,
                    kv_mask=kv_mask, rns=rns_a, chunk=chunk)
            prefill_kv = kv
        h = h + y
        if "xattn" in lp:  # enc-dec decoder cross-attention
            hx = norm(lp["lnx"], h, cfg.norm)
            if mode == "decode":
                y = attn.cross_decode(lp["xattn"], hx, cfg, cache["cross"],
                                      rns=rns_a)
            else:
                y, xkv = attn.gqa_attend(
                    lp["xattn"], hx, cfg, mode="dense", xkv=enc_out, rns=rns_a)
                prefill_kv = (prefill_kv, xkv)
            h = h + y
    elif layer_type == "mamba":
        hn = norm(lp["ln1"], h, cfg.norm)
        state = (cache["h"], cache["conv"]) if mode == "decode" else None
        y, new_state = ssm_lib.mamba_seq(
            lp["mamba"], hn, cfg.ssm, rns=rns_m,
            h0=None if state is None else state[0],
            conv0=None if state is None else state[1])
        if mode == "decode":
            new_cache = dict(cache, h=new_state[0], conv=new_state[1])
        else:
            prefill_kv = new_state
        h = h + y
    elif layer_type == "rwkv":
        hn = norm(lp["ln1"], h, cfg.norm)
        state = (cache["S"], cache["x_tm"]) if mode == "decode" else None
        y, new_state = ssm_lib.rwkv6_timemix(
            lp["rwkv"], hn, cfg.ssm, rns=rns_m, state=state)
        if mode == "decode":
            new_cache = dict(cache, S=new_state[0], x_tm=new_state[1])
        else:
            prefill_kv = new_state
        h = h + y

    if mlp_type in ("dense", "__enc__"):
        hn = norm(lp["ln2"], h, cfg.norm)
        h = h + mlp(lp["mlp"], hn, gated=cfg.gated_mlp, act=cfg.act, rns=rns_m)
    elif mlp_type == "moe":
        hn = norm(lp["ln2"], h, cfg.norm)
        y, aux = moe_lib.moe_ffn(lp["moe"], hn, cfg.moe, act=cfg.act, rns=rns_m)
        h = h + y
    elif layer_type == "rwkv":  # channel-mix (uses rwkv params)
        cm_state = cache["x_cm"] if mode == "decode" else None
        hn = norm(lp["rwkv"]["ln_cm"], h, cfg.norm) if "ln_cm" in lp["rwkv"] else h
        y, x_cm = ssm_lib.rwkv6_channelmix(lp["rwkv"], hn, rns=rns_m,
                                           state=cm_state)
        if mode == "decode":
            new_cache = dict(new_cache, x_cm=x_cm)
        else:
            prefill_kv = (prefill_kv, x_cm)
        h = h + y
    return h, new_cache, prefill_kv, aux


# ------------------------------------------------------------- the stack ---
def apply_blocks(blocks, h, cfg, *, mode, positions=None, kv_mask=None,
                 enc_out=None, cache=None, enc: bool = False, chunk=1024,
                 packed=None):
    """Scan the stacked periods.  Returns (h, new_cache_or_prefill, aux).

    ``packed``: optional ``(seg, pos, dec)`` per-token coordinates for
    the mixed chunked-prefill/decode step (decode mode, paged caches).
    """
    L = cfg.n_enc_layers if enc else cfg.n_layers
    ltypes = ("attn",) * L if enc else cfg.layer_types
    mtypes = ("__enc__",) * L if enc else cfg.mlp_types
    p = cfg.period if not enc else 1
    enc_dec_dec = cfg.enc_dec and not enc

    def period_body(carry, xs):
        h, aux = carry
        from repro.distributed.sharding import constrain

        h = constrain(h, ("batch", None, None))
        bp = xs["params"]
        cslice = xs.get("cache")
        new_cs, pkvs = {}, {}
        for j in range(p):
            lt, mt = ltypes[j], mtypes[j]
            c_j = cslice[f"l{j}"] if cslice is not None else None
            h, nc, pkv, a = _apply_layer(
                bp[f"l{j}"], h, cfg, lt, mt, mode=mode, positions=positions,
                kv_mask=kv_mask, enc_out=enc_out, cache=c_j, chunk=chunk,
                packed=packed)
            aux = aux + a
            if nc is not None:
                new_cs[f"l{j}"] = nc
            if pkv is not None:
                pkvs[f"l{j}"] = pkv
        out = new_cs if mode == "decode" else pkvs
        return (h, aux), out

    if cfg.remat == "full" and mode == "train":
        period_body = jax.checkpoint(period_body)

    xs = {"params": blocks}
    if cache is not None:
        xs["cache"] = cache
    (h, aux), ys = jax.lax.scan(period_body, (h, jnp.zeros((), jnp.float32)), xs)
    return h, ys, aux
