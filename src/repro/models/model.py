"""Public model API: init / train forward / loss / prefill / decode.

All ten assigned architectures flow through these five functions; the
config's layer program decides what happens inside.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf
from repro.models.layers import (
    embed,
    init_embedding,
    init_linear,
    init_norm,
    linear,
    norm,
    sinusoidal_positions,
    unembed,
)


# ---------------------------------------------------------------- init -----
def init_model(key, cfg):
    """Returns (params, specs) — specs mirror params with logical axes."""
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["embed"], s["embed"] = init_embedding(ks[0], cfg.vocab, cfg.d_model, dtype)
    p["blocks"], s["blocks"] = tf.init_blocks(ks[1], cfg)
    p["final_norm"], s["final_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"], s["lm_head"] = init_linear(
            ks[2], cfg.d_model, cfg.vocab, axes=("embed", "vocab"), dtype=dtype)
    if cfg.enc_dec:
        p["enc_blocks"], s["enc_blocks"] = tf.init_blocks(ks[3], cfg, enc=True)
        p["enc_norm"], s["enc_norm"] = init_norm(cfg.d_model, cfg.norm, dtype)
    return p, s


# ------------------------------------------------------------- helpers -----
def _embed_tokens(params, cfg, tokens):
    from repro.distributed.sharding import constrain

    h = embed(params["embed"], tokens)
    if cfg.emb_scale:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), h.dtype)
    return constrain(h, ("batch", None, None))


def _add_abs_pos(cfg, h, offset=0):
    if cfg.pos_emb == "sinusoidal":
        pos = sinusoidal_positions(h.shape[1] + offset, cfg.d_model, h.dtype)
        h = h + pos[offset : offset + h.shape[1]][None]
    return h


def _logits(params, cfg, h):
    from repro.distributed.sharding import constrain

    h = constrain(h, ("batch", None, None))
    h = norm(params["final_norm"], h, cfg.norm)
    out = (unembed(params["embed"], h) if cfg.tie_embeddings
           else linear(params["lm_head"], h))
    return constrain(out, ("batch", None, "model"))


def _encode(params, cfg, frames):
    """Encoder pass (enc-dec archs); frames [B, Te, d] from the stub."""
    h = _add_abs_pos(cfg, frames)
    h, _, _ = tf.apply_blocks(params["enc_blocks"], h, cfg, mode="train",
                              enc=True)
    return norm(params["enc_norm"], h, cfg.norm)


# ------------------------------------------------------------- forward -----
def forward_train(params, cfg, batch):
    """batch: tokens [B,T] (+ 'frontend' [B,F,d] for audio/vlm stubs).

    Returns (logits [B, T(+F), V], aux_loss scalar).
    """
    tokens = batch["tokens"]
    h = _embed_tokens(params, cfg, tokens)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(params, cfg, batch["frontend"])
    elif cfg.frontend is not None and "frontend" in batch:
        h = jnp.concatenate([batch["frontend"].astype(h.dtype), h], axis=1)
    h = _add_abs_pos(cfg, h)
    h, _, aux = tf.apply_blocks(params["blocks"], h, cfg, mode="train",
                                enc_out=enc_out)
    return _logits(params, cfg, h), aux


def loss_fn(params, cfg, batch):
    """Next-token CE (+ MoE aux).  Frontend positions are excluded."""
    logits, aux = forward_train(params, cfg, batch)
    F = 0
    if cfg.frontend is not None and not cfg.enc_dec and "frontend" in batch:
        F = batch["frontend"].shape[1]
    tokens = batch["tokens"]
    lg = logits[:, F:-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None else jnp.ones(
        tg.shape, jnp.float32)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# -------------------------------------------------------------- caches -----
def _layer_cache_shape(cfg, lt, B, S, dtype):
    if lt == "attn":
        kv = (B, S, cfg.n_kv_heads, cfg.d_head)
        c = {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    elif lt == "mla":
        m = cfg.mla
        c = {
            "c_kv": jnp.zeros((B, S, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, S, m.qk_rope_dim), dtype),
        }
    elif lt == "mamba":
        d_in = cfg.ssm.expand * cfg.d_model
        c = {
            "h": jnp.zeros((B, d_in, cfg.ssm.d_state), jnp.float32),
            "conv": jnp.zeros((B, cfg.ssm.d_conv - 1, d_in), dtype),
        }
    elif lt == "rwkv":
        H = cfg.d_model // cfg.ssm.head_dim
        c = {
            "S": jnp.zeros((B, H, cfg.ssm.head_dim, cfg.ssm.head_dim), jnp.float32),
            "x_tm": jnp.zeros((B, 1, cfg.d_model), dtype),
            "x_cm": jnp.zeros((B, 1, cfg.d_model), dtype),
        }
    else:
        raise ValueError(lt)
    return c


def make_cache(cfg, B, S_max, *, lengths=None, dtype=jnp.bfloat16,
               enc_frames: int | None = None):
    """Zero decode cache (dry-run / pre-prefill).  Stacked [n_periods, ...]."""
    p = cfg.period
    n_periods = cfg.n_layers // p
    lengths = lengths if lengths is not None else jnp.zeros((B,), jnp.int32)

    def one_period():
        per = {}
        for j in range(p):
            lt = cfg.layer_types[j]
            c = _layer_cache_shape(cfg, lt, B, S_max, dtype)
            c["lengths"] = lengths
            if cfg.enc_dec and lt == "attn":
                Te = enc_frames or cfg.n_frontend_tokens
                c["cross"] = {
                    "k": jnp.zeros((B, Te, cfg.n_kv_heads, cfg.d_head), dtype),
                    "v": jnp.zeros((B, Te, cfg.n_kv_heads, cfg.d_head), dtype),
                    "lengths": jnp.full((B,), Te, jnp.int32),
                }
            per[f"l{j}"] = c
        return per

    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape),
        one_period())


def set_cache_lengths(cache, lengths):
    """Overwrite every layer's lengths ([B] int32) without touching cross."""

    def walk(d):
        out = {}
        for k, v in d.items():
            if k == "lengths":
                out[k] = jnp.broadcast_to(
                    lengths[None], (v.shape[0],) + lengths.shape
                ) if v.ndim == 2 else lengths
            elif k == "cross":
                out[k] = v
            elif isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = v
        return out

    return walk(cache)


# -------------------------------------------------------------- prefill ----
def prefill(params, cfg, batch, S_max, *, cache_dtype=jnp.bfloat16):
    """Run the prompt (equal lengths per batch), build the decode cache.

    batch: tokens [B,T] (+ frontend).  Returns (last_logits [B,V], cache).

    RNS grids: decoder-only text prompts install an all-ones *per-token*
    quantization mask, so every prompt token gets its own absmax grid —
    the same grid :func:`prefill_ragged` and chunked prefill
    (:func:`mixed_step`) compute for that token, which is what keeps all
    three prefill paths token-identical.  Frontend/enc-dec prompts mix
    non-token positions into the stack and keep the legacy whole-tensor
    grid (the continuous engine rejects them anyway).
    """
    from repro.core.quantize import token_mask

    tokens = batch["tokens"]
    B, T = tokens.shape
    enc_out = None
    F = 0
    mixes_frontend = cfg.enc_dec or (cfg.frontend is not None
                                     and "frontend" in batch)
    mask = (jnp.ones((B, T), bool)
            if cfg.rns is not None and not mixes_frontend else None)
    with token_mask(mask, per_token=True):
        h = _embed_tokens(params, cfg, tokens)
        if cfg.enc_dec:
            enc_out = _encode(params, cfg, batch["frontend"])
        elif cfg.frontend is not None and "frontend" in batch:
            F = batch["frontend"].shape[1]
            h = jnp.concatenate([batch["frontend"].astype(h.dtype), h],
                                axis=1)
        h = _add_abs_pos(cfg, h)
        h, ys, _aux = tf.apply_blocks(params["blocks"], h, cfg,
                                      mode="prefill", enc_out=enc_out)
        logits_last = _logits(params, cfg, h[:, -1:])[:, 0]

    Tc = T + F
    lengths = jnp.full((B,), Tc, jnp.int32)
    cache = make_cache(cfg, B, S_max, lengths=lengths, dtype=cache_dtype,
                       enc_frames=None if not cfg.enc_dec
                       else batch["frontend"].shape[1])

    # write prefill KV/state into the zero cache
    p = cfg.period
    new_cache = {}
    for j in range(p):
        lt = cfg.layer_types[j]
        z = dict(cache[f"l{j}"])
        y = ys[f"l{j}"]
        if lt == "attn":
            if cfg.enc_dec:
                (k, v), (xk, xv) = y
                z["cross"] = dict(z["cross"], k=xk.astype(cache_dtype),
                                  v=xv.astype(cache_dtype))
            else:
                k, v = y
            z["k"] = jax.lax.dynamic_update_slice_in_dim(
                z["k"], k.astype(cache_dtype), 0, axis=2)
            z["v"] = jax.lax.dynamic_update_slice_in_dim(
                z["v"], v.astype(cache_dtype), 0, axis=2)
        elif lt == "mla":
            ckv, krope = y
            z["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
                z["c_kv"], ckv.astype(cache_dtype), 0, axis=2)
            z["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
                z["k_rope"], krope.astype(cache_dtype), 0, axis=2)
        elif lt == "mamba":
            h_last, conv_tail = y
            z["h"], z["conv"] = h_last, conv_tail.astype(z["conv"].dtype)
        elif lt == "rwkv":
            (S_last, x_tm), x_cm = y
            z["S"], z["x_tm"] = S_last, x_tm.astype(z["x_tm"].dtype)
            z["x_cm"] = x_cm.astype(z["x_cm"].dtype)
        new_cache[f"l{j}"] = z
    return logits_last, new_cache


# ------------------------------------------------------- ragged prefill ----
def prefill_ragged(params, cfg, batch, lengths):
    """Mixed-length prefill for continuous batching (paged caches).

    ``batch["tokens"]`` [B, Tpad] right-padded (pad id is irrelevant —
    causal masking keeps pad positions out of every valid position's
    receptive field, and positionwise ops never mix rows/positions), with
    per-row prompt ``lengths`` [B].  One compilation serves *every*
    prompt length <= Tpad.

    Returns (logits at each row's last prompt token [B, V], ys) where
    ``ys`` are the raw per-layer prefill outputs ([n_periods, B, Tpad,
    ...] KV planes) for the caller to blit into its paged cache — see
    ``serve/kv_cache.write_prompt_pages``.

    RNS exactness under padding: a per-tensor absmax grid over the padded
    activations would couple each row's quantization to pad garbage, so a
    :class:`~repro.core.quantize.token_mask` context is installed for the
    whole stack.  The mask is ``per_token``: every prompt token quantizes
    on its own (row, token) absmax grid, which is invariant to padding,
    to batch composition, *and* to how the prompt is split into chunks —
    the property chunked prefill (``mixed_step``) needs to stay
    token-identical to a whole-prompt run.  The bucketed :func:`prefill`
    installs the same per-token grid, so both prefill paths agree
    bit-for-bit.  The float path never consults the mask.

    Sliding windows (``cfg.attn_window``) narrow the in-prompt receptive
    field here exactly as they do at decode: the window mask rides the
    causal mask inside models/attention.py, so a windowed prefill +
    windowed paged decode agree with a windowed solo run even after the
    serving scheduler has recycled the evicted positions' pages.

    Decoder-only, causal, no frontend (the continuous engine validates).
    """
    from repro.core.quantize import token_mask

    tokens = batch["tokens"]
    B, Tpad = tokens.shape
    valid = jnp.arange(Tpad)[None, :] < lengths[:, None]
    with token_mask(valid if cfg.rns is not None else None, per_token=True):
        h = _embed_tokens(params, cfg, tokens)
        h = _add_abs_pos(cfg, h)
        h, ys, _aux = tf.apply_blocks(params["blocks"], h, cfg,
                                      mode="prefill")
        h_last = h[jnp.arange(B), lengths - 1][:, None]    # [B, 1, d]
        return _logits(params, cfg, h_last)[:, 0], ys


# --------------------------------------------------------------- decode ----
def decode_step(params, cfg, token, cache, active=None):
    """token [B,1] int32 -> (logits [B,V], updated cache).

    ``active`` [B] bool (continuous batching): inactive rows keep their
    ``lengths`` frozen — their compute is garbage the engine discards,
    and their cache writes land on the paged pool's trash page.  On the
    RNS path ``active`` doubles as the quantization token-mask, so each
    row's fixed-point grid is its own (a batched decode step is then
    bit-identical per row to a solo decode — same guarantee as
    :func:`prefill_ragged`).
    """
    from repro.core.quantize import token_mask

    mask = active[:, None] if (active is not None
                               and cfg.rns is not None) else None
    with token_mask(mask):
        h = _embed_tokens(params, cfg, token)
        # absolute-pos archs gather the position embedding at `lengths`
        if cfg.pos_emb == "sinusoidal":
            lengths = _cache_lengths(cache)
            table = sinusoidal_positions(_cache_smax(cfg, cache), cfg.d_model,
                                         h.dtype)
            h = h + table[lengths][:, None]
        h, ys, _ = tf.apply_blocks(params["blocks"], h, cfg, mode="decode",
                                   cache=cache)
        logits = _logits(params, cfg, h)[:, 0]
    step = 1 if active is None else active.astype(jnp.int32)
    new_cache = set_cache_lengths(ys, _cache_lengths(cache) + step)
    return logits, new_cache


def decode_window(params, cfg, tokens, cache, active=None):
    """Speculative-verify window: tokens [R, W] -> (logits [R, W, V], ys).

    The window is [last_token, draft_1, ..., draft_{W-1}] per row; all W
    tokens' KV is written at positions lengths..lengths+W-1 (paged caches
    only) and ``logits[:, i]`` is the model's next-token distribution
    after consuming window position ``i`` — exactly what W consecutive
    :func:`decode_step` calls would produce, so a greedy accept/reject
    over these logits keeps the emitted stream token-identical to vanilla
    decode.  Cache ``lengths`` are NOT advanced here: the caller sets
    them to ``length + accepted + 1`` once it knows the accept counts
    (``ys`` is the raw per-layer cache with the window KV scattered in).

    On the RNS path the token mask is installed ``per_token``: each
    window position quantizes on its own (row, token) absmax grid — the
    same grid its solo decode step would compute — instead of a grid
    coupled to its window neighbours (see core/quantize.token_mask).
    """
    from repro.core.quantize import token_mask

    R, W = tokens.shape
    mask = None
    if active is not None and cfg.rns is not None:
        mask = jnp.broadcast_to(active[:, None], (R, W))
    with token_mask(mask, per_token=True):
        h = _embed_tokens(params, cfg, tokens)
        if cfg.pos_emb == "sinusoidal":
            lengths = _cache_lengths(cache)
            table = sinusoidal_positions(_cache_smax(cfg, cache), cfg.d_model,
                                         h.dtype)
            h = h + table[lengths[:, None] + jnp.arange(W)[None]]
        h, ys, _ = tf.apply_blocks(params["blocks"], h, cfg, mode="decode",
                                   cache=cache)
        logits = _logits(params, cfg, h)
    return logits, ys


def mixed_step(params, cfg, tokens, seg, pos, dec, valid, cache):
    """ONE packed chunked-prefill + decode step (paged caches only).

    ``tokens``/``seg``/``pos`` [N] int32, ``dec``/``valid`` [N] bool:
    lane i carries the token for row ``seg[i]`` at absolute position
    ``pos[i]`` — a decode row's next token (``dec``) or one token of a
    prefill chunk (``~dec``).  Pad lanes (``~valid``) carry ``seg = -1``:
    their KV lands on the trash page and their logits are garbage the
    engine discards.  N is the engine's fixed ``token_budget``, so ONE
    compilation serves every prefill/decode mix.

    Returns (logits [N, V], updated cache).  ``logits[i]`` is the
    next-token distribution after consuming lane i — meaningful for
    decode lanes and for each chunk's last token (TTFT!).  Cache
    ``lengths`` are not advanced; the engine owns them host-side and
    pushes fresh tables before every step.

    Token identity: per-token quantization grids (see
    :func:`prefill_ragged`), write-then-gather packed attention
    (models/attention.py ``*_decode_packed``), and a float32 page pool
    make each lane's math bitwise its solo bucketed counterpart.
    """
    from repro.core.quantize import token_mask

    mask = valid[None] if cfg.rns is not None else None
    with token_mask(mask, per_token=True):
        h = _embed_tokens(params, cfg, tokens[None])
        if cfg.pos_emb == "sinusoidal":
            table = sinusoidal_positions(_cache_smax(cfg, cache), cfg.d_model,
                                         h.dtype)
            h = h + table[pos][None]
        h, ys, _ = tf.apply_blocks(params["blocks"], h, cfg, mode="decode",
                                   cache=cache, packed=(seg, pos, dec))
        logits = _logits(params, cfg, h)[0]
    return logits, ys


def _cache_lengths(cache):
    first = cache[next(iter(cache))]
    return first["lengths"][0]


def _cache_smax(cfg, cache):
    first = cache[next(iter(cache))]
    if "block_table" in first:      # paged: capacity = max_blocks * page_size
        for k, v in first.items():
            if k.endswith("_pages"):
                return first["block_table"].shape[-1] * v.shape[2]
    for k, v in first.items():
        if k in ("k", "c_kv"):
            return v.shape[2]
    return 1 << 20


# ------------------------------------------------------------ counting -----
def count_params(cfg):
    """(total, active) param counts via eval_shape (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_model(k, cfg)[0],
                            jax.random.PRNGKey(0))
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        if "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
            routed += n
    active = total
    if cfg.moe is not None and routed:
        frac = cfg.moe.top_k / cfg.moe.n_experts
        active = total - routed + int(routed * frac)
    return total, active


def count_params_analytic(cfg, active_only: bool = False):
    total, active = count_params(cfg)
    return active if active_only else total
