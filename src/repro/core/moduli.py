"""Moduli selection for the RNS datapath.

The paper encodes each RNS digit in an 8-bit word so that the digit-slice
matmul array can reuse the TPU's 8x8-bit multipliers (Fig. 5).  On TPU the
8-bit datapath is the signed-int8 MXU, so the default moduli are chosen
<= 128: residues lie in [0, 127] and fit int8 exactly, with products
<= 127**2 < 2**14, allowing ~2**17 int32 accumulations between modular
reductions ("lazy reduction").  A <=256 ("u8") family is also provided for
the pure-jnp path.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import numpy as np

__all__ = [
    "greedy_coprime_moduli",
    "RnsProfile",
    "get_profile",
    "PROFILES",
    "narrowest_profile",
    "required_digits",
]


def greedy_coprime_moduli(limit: int, count: int) -> tuple[int, ...]:
    """Largest-first greedy pairwise-coprime moduli <= ``limit``."""
    chosen: list[int] = []
    cand = limit
    while len(chosen) < count and cand >= 2:
        if all(math.gcd(cand, m) == 1 for m in chosen):
            chosen.append(cand)
        cand -= 1
    if len(chosen) < count:
        raise ValueError(f"cannot find {count} coprime moduli <= {limit}")
    return tuple(chosen)


@dataclasses.dataclass(frozen=True)
class RnsProfile:
    """A static description of an RNS working register.

    Attributes:
      name: profile id.
      moduli: pairwise-coprime digit moduli (descending).
      frac_digits: how many leading moduli form the fractional base M_f
        (Olsen's fractional RNS: value v is represented as round(v * M_f)).
    """

    name: str
    moduli: tuple[int, ...]
    frac_digits: int = 2

    def __post_init__(self):
        ms = self.moduli
        if not ms:
            raise ValueError(f"profile {self.name!r}: empty moduli set")
        for m in ms:
            if m < 2:
                raise ValueError(
                    f"profile {self.name!r}: modulus {m} < 2 (a unit modulus "
                    "contributes no range and breaks the CRT basis)")
        seen = set()
        for m in ms:
            if m in seen:
                raise ValueError(
                    f"profile {self.name!r}: duplicate modulus {m} (the CRT "
                    "map is only a bijection for pairwise-coprime moduli — "
                    "a duplicated digit would silently corrupt MRC)")
            seen.add(m)
        for i in range(len(ms)):
            for j in range(i + 1, len(ms)):
                if math.gcd(ms[i], ms[j]) != 1:
                    raise ValueError(
                        f"profile {self.name!r}: moduli not coprime: "
                        f"{ms[i]}, {ms[j]}")
        if not (0 < self.frac_digits < len(ms)):
            raise ValueError("frac_digits must be in (0, n_digits)")

    # ---- exact (python-int) derived quantities -------------------------
    @property
    def n_digits(self) -> int:
        return len(self.moduli)

    @functools.cached_property
    def M(self) -> int:
        """Full dynamic range (product of all moduli)."""
        out = 1
        for m in self.moduli:
            out *= m
        return out

    @functools.cached_property
    def M_f(self) -> int:
        """Fractional base: product of the first ``frac_digits`` moduli."""
        out = 1
        for m in self.moduli[: self.frac_digits]:
            out *= m
        return out

    @property
    def range_bits(self) -> float:
        return math.log2(self.M)

    @property
    def signed_bits(self) -> int:
        """Guaranteed exact signed-magnitude bits (|X| < M/2)."""
        return int(math.floor(self.range_bits)) - 1

    @property
    def max_digit(self) -> int:
        return max(self.moduli)

    @property
    def lazy_chunk(self) -> int:
        """Max #terms accumulable in int32 between modular reductions."""
        return (2**31 - 1) // (self.max_digit - 1) ** 2

    @property
    def int8_safe(self) -> bool:
        """Residues fit signed int8 (required by the Pallas MXU kernel)."""
        return self.max_digit <= 128

    def dot_capacity(self, qa: int, qw: int) -> int:
        """Max #terms n of an exact signed dot product of qa x qw-bit operands.

        Operands are signed fixed point: |a| <= 2**(qa-1), |w| <= 2**(qw-1),
        so |sum| <= n * 2**(qa+qw-2); exactness needs that < M/2.
        """
        return self.M // (2 ** (qa + qw - 1))


def _mk(name: str, n: int, frac: int, limit: int = 128) -> RnsProfile:
    return RnsProfile(name, greedy_coprime_moduli(limit, n), frac)


# Default family: <=128 moduli (int8 MXU-safe, the TPU adaptation of the
# paper's "8-bit word per digit").  Bit widths are log2(M).
PROFILES: dict[str, RnsProfile] = {
    # ~34.8 bits: the "Google-TPU-equivalent-plus" register (int8 operand dots)
    "rns5": _mk("rns5", 5, 1),
    # ~41.9 bits: right-sized for 16x16-bit dots up to ~2k terms
    "rns6": _mk("rns6", 6, 1),
    # ~48.9 bits: 16x16-bit dots up to ~245k terms (every assigned arch's
    # contraction fits — the "precision scales by slices" knob, downward)
    "rns7": _mk("rns7", 7, 1),
    # ~55.3 bits: 16x16-bit dots up to ~2**24 terms — covers the 1M-token
    # weight-gradient contraction of train_4k (the capacity guard rejects
    # rns7 for exactly that matmul)
    "rns8": _mk("rns8", 8, 1),
    # ~62.0 bits: Rez-9/18-class working register (default for model matmuls)
    "rns9": _mk("rns9", 9, 2),
    # ~108.9 bits, 16 digits: matches a 16-wide model axis exactly — the
    # paper's digit-slice-per-unit layout as a sharding strategy (each chip
    # owns one slice; digits meet only at normalization)
    "rns16": _mk("rns16", 16, 4),
    # ~82.0 bits
    "rns12": _mk("rns12", 12, 3),
    # ~124.4 bits: deep-precision register (Mandelbrot beyond-float64 demo)
    "rns18": _mk("rns18", 18, 8),
    # ~142.8 bits
    "rns21": _mk("rns21", 21, 8),
    # u8 family (moduli <= 256): jnp-path only, matches the paper's byte-wide
    # digits most literally; residues do NOT fit signed int8.
    "rns8_u8": RnsProfile("rns8_u8", greedy_coprime_moduli(256, 8), 2),
}


def get_profile(name: str) -> RnsProfile:
    try:
        return PROFILES[name]
    except KeyError:
        raise KeyError(f"unknown RNS profile {name!r}; have {sorted(PROFILES)}")


def narrowest_profile(min_signed_bits: float,
                      cap: str | RnsProfile = "rns9") -> RnsProfile:
    """Narrowest registered profile whose exact signed range covers
    ``min_signed_bits``, never wider than ``cap``.

    Used by the resident-weight encoder (models/resident.py) to pick
    per-layer moduli profiles: a layer whose magnitude-ledger requirement
    (from its weights' quantized column-sum statistics) fits a smaller
    moduli set runs on fewer digit slices — fewer residue planes moved
    and multiplied, same exact integers.  Candidates are the registered
    ``PROFILES`` only (so :class:`RnsTensor`'s by-name profile lookup
    round-trips) and keep the Pallas ``int8_safe`` property of ``cap``;
    if nothing narrower suffices, ``cap`` itself is returned.
    """
    cap = get_profile(cap) if isinstance(cap, str) else cap
    cands = sorted(
        (p for p in PROFILES.values()
         if (p.int8_safe or not cap.int8_safe)
         and p.range_bits <= cap.range_bits),
        key=lambda p: p.range_bits)
    for p in cands:
        if p.signed_bits >= min_signed_bits:
            return p
    return cap


def required_digits(n_terms: int, qa: int, qw: int, limit: int = 128) -> int:
    """Napkin-math helper: #digit slices for an exact n-term qa x qw dot."""
    need_bits = (qa + qw - 1) + math.log2(max(n_terms, 1))
    moduli = greedy_coprime_moduli(limit, 24)
    bits = 0.0
    for k, m in enumerate(moduli, start=1):
        bits += math.log2(m)
        if bits > need_bits:
            return k
    raise ValueError("need more than 32 digits")
