"""Digit-sliced RNS matmul with deferred normalization — the paper's core.

Pipeline (Fig. 5 of the paper, TPU-adapted):

  float x ──quantize──> int32 ──forward-convert──> residues [K, ..., D]
  float w ──quantize──> int32 ──forward-convert──> residues [K, D, N]
      per-slice int8 matmul (MXU), int32 accumulate, LAZY mod reduction
      (one reduction per <=lazy_chunk-term block, not per MAC)
  residues [K, ..., N] ──MRC normalize (ONE slow op per output)──> float y

Exactness contract: with D <= profile.dot_capacity(qx, qw), the decoded
integer equals the infinite-precision dot product of the quantized operands
(verified against a python-int oracle in tests).

Training: custom_vjp — backward matmuls ALSO run through RNS (the paper's
motivation is wide-precision *training*), with straight-through gradients
for the quantizer.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import mrc
from repro.core.moduli import get_profile
from repro.core.quantize import quantize
from repro.core.rns import encode_int32, tables

__all__ = ["RnsDotConfig", "rns_matmul_res", "rns_dot", "rns_dot_fwd_only"]


@dataclasses.dataclass(frozen=True)
class RnsDotConfig:
    profile: str = "rns9"
    qx: int = 16            # activation fixed-point bits
    qw: int = 16            # weight fixed-point bits
    qg: int = 16            # gradient fixed-point bits (backward)
    use_pallas: bool = False
    backward_rns: bool = True   # paper-faithful: grads through RNS too
    # shard the digit-slice axis over the model mesh axis (paper Fig. 5:
    # one slice per compute unit; digits only meet at normalization).
    # Requires n_digits % model_axis == 0 (e.g. profile rns16 on a 16-wide
    # model axis).
    slice_parallel: bool = False


def _check_capacity(cfg: RnsDotConfig, contract_dim: int, qa: int, qb: int):
    p = get_profile(cfg.profile)
    cap = p.dot_capacity(qa, qb)
    if contract_dim > cap:
        raise ValueError(
            f"RNS profile {p.name} ({p.range_bits:.1f} bits) cannot hold an "
            f"exact {contract_dim}-term {qa}x{qb}-bit dot product "
            f"(capacity {cap}); use a wider profile or fewer bits"
        )


def rns_matmul_res(profile, a_res, b_res):
    """Per-digit-slice modular matmul.

    a_res: [K, ..., M, D] int8/int32 residues; b_res: [K, D, N].
    Returns [K, ..., M, N] int32 residues of the exact product-sum mod m_s.

    Lazy reduction: residues < 128 => products < 2**14 => up to
    ``lazy_chunk`` (~131k) terms accumulate in int32 between reductions.
    """
    p = get_profile(profile) if isinstance(profile, str) else profile
    t = tables(p)
    chunk = p.lazy_chunk
    D = a_res.shape[-1]
    # output is [K, ..., M, N]: same rank as a_res
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (a_res.ndim - 1))
    if D <= chunk:
        acc = jnp.einsum(
            "s...md,sdn->s...mn", a_res, b_res,
            preferred_element_type=jnp.int32,
        )
        return jnp.remainder(acc, m)
    # chunked accumulation with a modular reduction per chunk
    n_chunks = -(-D // chunk)
    acc = None
    for c in range(n_chunks):
        sl = slice(c * chunk, min((c + 1) * chunk, D))
        part = jnp.einsum(
            "s...md,sdn->s...mn", a_res[..., sl], b_res[:, sl, :],
            preferred_element_type=jnp.int32,
        )
        part = jnp.remainder(part, m)
        acc = part if acc is None else jnp.remainder(acc + part, m)
    return acc


def _encode_operand(cfg: RnsDotConfig, x, bits: int):
    v, s = quantize(x, bits)
    res = encode_int32(cfg.profile, v)
    p = get_profile(cfg.profile)
    if p.int8_safe:
        # residues < 128 by construction: int8 storage means any collective
        # that touches encoded operands moves 9x1B, not 9x4B (§Perf rns)
        res = res.astype(jnp.int8)
    return res, s


def _rns_matmul_float(cfg: RnsDotConfig, x, w, qa: int, qb: int):
    """Non-differentiable float->float RNS matmul core."""
    _check_capacity(cfg, x.shape[-1], qa, qb)
    # NOTE §Perf rns iter 6: pinning the residue sharding (so reshards land
    # on the bf16 encode input) made XLA fully replicate the widest residue
    # planes instead — refuted, reverted.  Moving residues off the wire
    # entirely needs shard_map + the fused Pallas conversion (kernels/
    # rns_convert), where residues live only in VMEM — the software analogue
    # of the paper's Fig. 5 edge-of-array conversion pipelines.
    a_res, sx = _encode_operand(cfg, x, qa)
    b_res, sw = _encode_operand(cfg, w, qb)
    if cfg.slice_parallel:
        from repro.distributed.sharding import constrain

        spec = lambda t: ("model",) + ("batch",) + (None,) * (t.ndim - 2)
        a_res = constrain(a_res, spec(a_res))
        b_res = constrain(b_res, ("model",) + (None,) * (b_res.ndim - 1))
    if cfg.use_pallas:
        from repro.kernels.rns_matmul import ops as _kops

        y_res = _kops.rns_matmul(cfg.profile, a_res, b_res)
    else:
        y_res = rns_matmul_res(cfg.profile, a_res, b_res)
    if cfg.slice_parallel:
        from repro.distributed.sharding import constrain

        y_res = constrain(
            y_res, ("model", "batch") + (None,) * (y_res.ndim - 2))
    # deferred normalization: ONE MRC per output element (the only point
    # where slice-parallel digits communicate — paper Fig. 5)
    y = mrc.decode_float(cfg.profile, y_res)
    return y * (1.0 / (sx * sw))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rns_dot(x, w, cfg: RnsDotConfig):
    """y = x @ w through the RNS digit-sliced datapath.

    x: [..., D] float; w: [D, N] float.  Differentiable (STE quantizer,
    RNS backward matmuls when cfg.backward_rns).
    """
    return _rns_matmul_float(cfg, x, w, cfg.qx, cfg.qw)


def _rns_dot_fwd(x, w, cfg: RnsDotConfig):
    return rns_dot(x, w, cfg), (x, w)


def _rns_dot_bwd(cfg: RnsDotConfig, resids, g):
    x, w = resids
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])            # [T, D]
    gf = g.reshape(-1, g.shape[-1])            # [T, N]
    if cfg.backward_rns:
        gx = _rns_matmul_float(cfg, gf, w.T, cfg.qg, cfg.qw)      # [T, D]
        gw = _rns_matmul_float(cfg, xf.T, gf, cfg.qx, cfg.qg)     # [D, N]
    else:
        gx = gf @ w.T
        gw = xf.T @ gf
    return gx.reshape(*lead, x.shape[-1]).astype(x.dtype), gw.astype(w.dtype)


rns_dot.defvjp(_rns_dot_fwd, _rns_dot_bwd)


def rns_dot_fwd_only(x, w, cfg: RnsDotConfig):
    """Inference-path entry (no vjp machinery)."""
    return _rns_matmul_float(cfg, x, w, cfg.qx, cfg.qw)
