"""Digit-sliced RNS matmul with deferred normalization — the paper's core.

Pipeline (Fig. 5 of the paper, TPU-adapted):

  float x ──quantize──> int32 ──forward-convert──> residues [K, ..., D]
  float w ──quantize──> int32 ──forward-convert──> residues [K, D, N]
      per-slice int8 matmul (MXU), int32 accumulate, LAZY mod reduction
      (one reduction per <=lazy_chunk-term block, not per MAC)
  residues [K, ..., N] ──MRC normalize (ONE slow op per output)──> float y

Exactness contract: with D <= profile.dot_capacity(qx, qw), the decoded
integer equals the infinite-precision dot product of the quantized operands
(verified against a python-int oracle in tests).

Backend selection (reference jnp vs Pallas kernels) is owned by
``core/dispatch.py``; this module only says *what* to compute.  For
residue-domain chaining across ops (one normalization per chain instead of
per matmul) see ``core/tensor.py`` — this module's float->float entry
points are the single-op degenerate case of that API.

Training: custom_vjp — backward matmuls ALSO run through RNS (the paper's
motivation is wide-precision *training*), with straight-through gradients
for the quantizer.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.moduli import get_profile
from repro.core.quantize import absmax_scale
from repro.core.rns import tables

__all__ = [
    "RnsDotConfig",
    "modular_matmul",
    "rns_matmul_res",
    "rns_dot",
    "rns_dot_fwd_only",
    "rns_multi_dot",
    "rns_resident_dot",
    "rns_resident_multi_dot",
]


@dataclasses.dataclass(frozen=True)
class RnsDotConfig:
    profile: str = "rns9"
    qx: int = 16            # activation fixed-point bits
    qw: int = 16            # weight fixed-point bits
    qg: int = 16            # gradient fixed-point bits (backward)
    # execution backend for all three primitives (see core/dispatch.py):
    # "auto" | "reference" | "pallas" | "pallas_interpret" |
    # "pallas_fused" | "pallas_fused_interpret".  None defers
    # to the use_pallas flag (reference unless use_pallas); an explicit
    # value always wins, so overrides can force the reference oracle even
    # on configs built with use_pallas=True.
    backend: str | None = None
    use_pallas: bool = False    # legacy alias for backend="pallas"
    backward_rns: bool = True   # paper-faithful: grads through RNS too
    # residue-domain chaining: let consecutive linear ops consume/produce
    # RnsTensor and defer the slow MRC normalization to the end of the
    # chain (models/layers.py uses this for the MLP block datapath).
    defer: bool = False
    # shard the digit-slice axis over the model mesh axis (paper Fig. 5:
    # one slice per compute unit; digits only meet at normalization).
    # Requires n_digits % model_axis == 0 (e.g. profile rns16 on a 16-wide
    # model axis).
    slice_parallel: bool = False

    def resolved_backend(self) -> str:
        if self.backend is not None:
            return self.backend
        return "pallas" if self.use_pallas else "reference"


def _check_capacity(cfg: RnsDotConfig, contract_dim: int, qa: int, qb: int):
    p = get_profile(cfg.profile)
    cap = p.dot_capacity(qa, qb)
    if contract_dim > cap:
        raise ValueError(
            f"RNS profile {p.name} ({p.range_bits:.1f} bits) cannot hold an "
            f"exact {contract_dim}-term {qa}x{qb}-bit dot product "
            f"(capacity {cap}); use a wider profile or fewer bits"
        )


def modular_matmul(a_res, b_res, mvec, chunk: int):
    """Digit-batched einsum with lazy modular reduction — THE schedule.

    ``mvec``: moduli broadcast to ``(K', 1, ..., 1)`` (any digit subset —
    the digit-sharded dispatch path passes each device's local group);
    ``chunk``: max #terms accumulable in int32 between reductions
    (``profile.lazy_chunk``; depends only on max(moduli), so it is
    identical for every digit shard).  Single source of truth for the
    overflow-critical chunking used by both the reference and the
    sharded path.
    """
    D = a_res.shape[-1]
    if D <= chunk:
        acc = jnp.einsum(
            "s...md,sdn->s...mn", a_res, b_res,
            preferred_element_type=jnp.int32,
        )
        return jnp.remainder(acc, mvec)
    # chunked accumulation with a modular reduction per chunk
    n_chunks = -(-D // chunk)
    acc = None
    for c in range(n_chunks):
        sl = slice(c * chunk, min((c + 1) * chunk, D))
        part = jnp.einsum(
            "s...md,sdn->s...mn", a_res[..., sl], b_res[:, sl, :],
            preferred_element_type=jnp.int32,
        )
        part = jnp.remainder(part, mvec)
        acc = part if acc is None else jnp.remainder(acc + part, mvec)
    return acc


def rns_matmul_res(profile, a_res, b_res):
    """Per-digit-slice modular matmul (the jnp reference implementation).

    a_res: [K, ..., M, D] int8/int32 residues; b_res: [K, D, N].
    Returns [K, ..., M, N] int32 residues of the exact product-sum mod m_s.

    Lazy reduction: residues < 128 => products < 2**14 => up to
    ``lazy_chunk`` (~131k) terms accumulate in int32 between reductions.
    """
    p = get_profile(profile) if isinstance(profile, str) else profile
    t = tables(p)
    # output is [K, ..., M, N]: same rank as a_res
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (a_res.ndim - 1))
    return modular_matmul(a_res, b_res, m, p.lazy_chunk)


def _encode_operand(cfg: RnsDotConfig, x, bits: int, backend: str,
                    weight: bool = False):
    # residues < 128 by construction for int8-safe profiles: int8 storage
    # means any collective that touches encoded operands moves 9x1B, not
    # 9x4B (§Perf rns)
    s = absmax_scale(x, bits)
    res = dispatch.convert(cfg.profile, x, s, bits=bits, backend=backend,
                           weight=weight)
    return res, s


def _sp_constrain(cfg: RnsDotConfig, res, kind: str):
    """Slice-parallel sharding constraint (paper Fig. 5: one digit slice
    per compute unit).  kind: "act" for [K, batch, ...] activations and
    outputs, "w" for [K, D, N] weights."""
    if not cfg.slice_parallel:
        return res
    from repro.distributed.sharding import constrain

    if kind == "act":
        return constrain(res, ("model", "batch") + (None,) * (res.ndim - 2))
    return constrain(res, ("model",) + (None,) * (res.ndim - 1))


def _res_matmul(cfg: RnsDotConfig, be: str, a_res, b_res):
    """Digit-sliced matmul on residues, with slice-parallel constraints."""
    a_res = _sp_constrain(cfg, a_res, "act")
    b_res = _sp_constrain(cfg, b_res, "w")
    y_res = dispatch.matmul(cfg.profile, a_res, b_res, backend=be)
    return _sp_constrain(cfg, y_res, "act")


def _fused_path(cfg: RnsDotConfig, be: str) -> bool:
    # the fused kernels don't emit slice-parallel sharding constraints
    # (residues never leave VMEM, so there is nothing to constrain) —
    # slice_parallel configs keep the per-primitive path, and so does a
    # digit-sharded mesh context (shard_map owns that layout; keeping
    # the unfused structure preserves the shared conversions there)
    return dispatch.fusion_active(cfg.profile, be) and not cfg.slice_parallel


def _rns_matmul_float(cfg: RnsDotConfig, x, w, qa: int, qb: int,
                      w_static: bool = True):
    """Non-differentiable float->float RNS matmul core.

    ``w_static``: whether ``w`` is a model weight (tally bookkeeping for
    the resident-weight comparison; the backward's activation-gradient
    contraction passes False for its cotangent operand)."""
    _check_capacity(cfg, x.shape[-1], qa, qb)
    be = cfg.resolved_backend()
    if _fused_path(cfg, be):
        # ONE kernel: encode -> digit matmul -> MRC normalize; activation
        # residues and the int32 accumulator never round-trip HBM
        sx = absmax_scale(x, qa)
        b_res, sw = _encode_operand(cfg, w, qb, be, weight=w_static)
        y = dispatch.fused_dot(cfg.profile, x, sx, b_res, bits=qa, backend=be)
        return y * (1.0 / (sx * sw))
    # NOTE §Perf rns iter 6: pinning the residue sharding (so reshards land
    # on the bf16 encode input) made XLA fully replicate the widest residue
    # planes instead — refuted, reverted.  Moving residues off the wire
    # entirely needs shard_map + the fused Pallas conversion (kernels/
    # rns_convert), where residues live only in VMEM — the software analogue
    # of the paper's Fig. 5 edge-of-array conversion pipelines.
    a_res, sx = _encode_operand(cfg, x, qa, be)
    b_res, sw = _encode_operand(cfg, w, qb, be, weight=w_static)
    y_res = _res_matmul(cfg, be, a_res, b_res)
    # deferred normalization: ONE MRC per output element (the only point
    # where slice-parallel digits communicate — paper Fig. 5)
    y = dispatch.normalize(cfg.profile, y_res, backend=be)
    return y * (1.0 / (sx * sw))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rns_dot(x, w, cfg: RnsDotConfig):
    """y = x @ w through the RNS digit-sliced datapath.

    x: [..., D] float; w: [D, N] float.  Differentiable (STE quantizer,
    RNS backward matmuls when cfg.backward_rns).
    """
    return _rns_matmul_float(cfg, x, w, cfg.qx, cfg.qw)


def _rns_dot_fwd(x, w, cfg: RnsDotConfig):
    return rns_dot(x, w, cfg), (x, w)


def _rns_dot_bwd(cfg: RnsDotConfig, resids, g):
    x, w = resids
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])            # [T, D]
    gf = g.reshape(-1, g.shape[-1])            # [T, N]
    if cfg.backward_rns:
        gx = _rns_matmul_float(cfg, gf, w.T, cfg.qg, cfg.qw)      # [T, D]
        gw = _rns_matmul_float(cfg, xf.T, gf, cfg.qx, cfg.qg,
                               w_static=False)                    # [D, N]
    else:
        gx = gf @ w.T
        gw = xf.T @ gf
    return gx.reshape(*lead, x.shape[-1]).astype(x.dtype), gw.astype(w.dtype)


rns_dot.defvjp(_rns_dot_fwd, _rns_dot_bwd)


def rns_dot_fwd_only(x, w, cfg: RnsDotConfig):
    """Inference-path entry (no vjp machinery)."""
    return _rns_matmul_float(cfg, x, w, cfg.qx, cfg.qw)


# ------------------------------------------------- shared-operand fan-out --
def _rns_multi_impl(cfg: RnsDotConfig, x, ws):
    """Encode ``x`` ONCE, run one digit-sliced matmul per weight.

    The QKV / gated-MLP projections all consume the same activation: the
    forward conversion (quantize + per-digit reduction) is paid once per
    block instead of once per matmul.  Numerics are identical to separate
    ``rns_dot`` calls (same absmax grid).
    """
    be = cfg.resolved_backend()
    _check_capacity(cfg, x.shape[-1], cfg.qx, cfg.qw)
    if _fused_path(cfg, be):
        # the shared grid survives fusion: every weight's kernel re-derives
        # the SAME absmax scale (XLA CSEs the reduction), so numerics are
        # identical to the shared-conversion path while the activation
        # residues stay in VMEM.  shared_encode keeps the structural
        # converts tally at one per block, like the unfused path.
        sx = absmax_scale(x, cfg.qx)
        outs = []
        for i, w in enumerate(ws):
            b_res, sw = _encode_operand(cfg, w, cfg.qw, be, weight=True)
            y = dispatch.fused_dot(cfg.profile, x, sx, b_res, bits=cfg.qx,
                                   backend=be, shared_encode=i > 0)
            outs.append(y * (1.0 / (sx * sw)))
        return tuple(outs)
    a_res, sx = _encode_operand(cfg, x, cfg.qx, be)
    outs = []
    for w in ws:
        b_res, sw = _encode_operand(cfg, w, cfg.qw, be, weight=True)
        y_res = _res_matmul(cfg, be, a_res, b_res)
        y = dispatch.normalize(cfg.profile, y_res, backend=be)
        outs.append(y * (1.0 / (sx * sw)))
    return tuple(outs)


# --------------------------------------------- resident-weight forwards ----
def _for_resident(cfg: RnsDotConfig, w_res) -> RnsDotConfig:
    """Align cfg.profile with the resident weight's (possibly narrower,
    per-layer-selected) profile so every helper below sees ONE profile."""
    if cfg.profile != w_res.profile:
        cfg = dataclasses.replace(cfg, profile=w_res.profile)
    return cfg


def rns_resident_dot(x, w_res, cfg: RnsDotConfig, *, bits: int | None = None):
    """y = x @ w_res for a pre-encoded resident weight (forward-only).

    Mirrors :func:`rns_dot`'s forward arithmetic exactly — same
    quantization grids, same primitive schedule, same scale algebra
    (``y * (1.0 / (sx * sw))``) — with the weight conversion already paid
    at build time, so the trace tallies zero ``weight_converts``.  The
    exactness guard is the magnitude ledger (``w_res.mag_bits``), which
    admits per-layer narrow profiles the generic capacity formula would
    reject.  Differentiation is the caller's job (models/layers.py wraps
    this in the STE custom_vjps); ``w_res.digits`` are integers, so no
    gradient ever flows through them.
    """
    from repro.core.tensor import _annotate, _encode_out_bits

    cfg = _for_resident(cfg, w_res)
    qa = cfg.qx if bits is None else bits
    p = get_profile(cfg.profile)
    _encode_out_bits(p, qa, w_res, x.shape[-1])     # raises on overflow
    _annotate(w_res, "weight")
    be = cfg.resolved_backend()
    sx = absmax_scale(x, qa)
    if _fused_path(cfg, be):
        y = dispatch.fused_dot(cfg.profile, x, sx, w_res.digits, bits=qa,
                               backend=be)
        return y * (1.0 / (sx * w_res.scale))
    a_res = dispatch.convert(cfg.profile, x, sx, bits=qa, backend=be)
    y_res = _res_matmul(cfg, be, a_res, w_res.digits)
    y = dispatch.normalize(cfg.profile, y_res, backend=be)
    return y * (1.0 / (sx * w_res.scale))


def rns_resident_multi_dot(x, ws_res: tuple, cfg: RnsDotConfig):
    """(x @ w for w in ws_res) with one shared forward conversion of x.

    The resident mirror of :func:`rns_multi_dot`'s forward: identical
    grids and scale algebra, zero weight conversions.  Forward-only, like
    :func:`rns_resident_dot`.
    """
    from repro.core.tensor import _annotate, _encode_out_bits

    cfg = _for_resident(cfg, ws_res[0])
    p = get_profile(cfg.profile)
    for w_res in ws_res:
        if w_res.profile != cfg.profile:
            raise ValueError("resident fan-out weights must share a profile "
                             "(one shared conversion of x feeds them all)")
        _encode_out_bits(p, cfg.qx, w_res, x.shape[-1])
        _annotate(w_res, "weight")
    be = cfg.resolved_backend()
    sx = absmax_scale(x, cfg.qx)
    if _fused_path(cfg, be):
        outs = []
        for i, w_res in enumerate(ws_res):
            y = dispatch.fused_dot(cfg.profile, x, sx, w_res.digits,
                                   bits=cfg.qx, backend=be, shared_encode=i > 0)
            outs.append(y * (1.0 / (sx * w_res.scale)))
        return tuple(outs)
    a_res = dispatch.convert(cfg.profile, x, sx, bits=cfg.qx, backend=be)
    outs = []
    for w_res in ws_res:
        y_res = _res_matmul(cfg, be, a_res, w_res.digits)
        y = dispatch.normalize(cfg.profile, y_res, backend=be)
        outs.append(y * (1.0 / (sx * w_res.scale)))
    return tuple(outs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rns_multi_dot(x, ws: tuple, cfg: RnsDotConfig):
    """(x @ w for w in ws) with one shared forward conversion of x.

    x: [..., D] float; ws: tuple of [D, N_i] floats.  Differentiable with
    the same STE/RNS-backward contract as :func:`rns_dot`.
    """
    return _rns_multi_impl(cfg, x, ws)


def _rns_multi_fwd(x, ws, cfg: RnsDotConfig):
    return rns_multi_dot(x, ws, cfg), (x, ws)


def _rns_multi_bwd(cfg: RnsDotConfig, resids, gs):
    x, ws = resids
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])                      # [T, D]
    be = cfg.resolved_backend()
    gx = jnp.zeros(xf.shape, jnp.float32)
    gws = []
    if cfg.backward_rns:
        # share conversions like the forward: encode x^T once for all
        # weight grads, and each cotangent once for both of its matmuls
        _check_capacity(cfg, xf.shape[0], cfg.qx, cfg.qg)
        xt_res, sxt = _encode_operand(cfg, xf.T, cfg.qx, be)   # [K, D, T]
    for w, g in zip(ws, gs):
        gf = g.reshape(-1, g.shape[-1])                  # [T, N_i]
        if cfg.backward_rns:
            _check_capacity(cfg, gf.shape[-1], cfg.qg, cfg.qw)
            g_res, sg = _encode_operand(cfg, gf, cfg.qg, be)   # [K, T, N]
            wt_res, sw = _encode_operand(cfg, w.T, cfg.qw, be,
                                         weight=True)       # [K, N, D]
            gx_i = dispatch.normalize(
                cfg.profile, _res_matmul(cfg, be, g_res, wt_res), backend=be
            ) * (1.0 / (sg * sw))
            gw = dispatch.normalize(
                cfg.profile, _res_matmul(cfg, be, xt_res, g_res), backend=be
            ) * (1.0 / (sxt * sg))
        else:
            gx_i = gf @ w.T
            gw = xf.T @ gf
        gx = gx + gx_i
        gws.append(gw.astype(w.dtype))
    return gx.reshape(*lead, x.shape[-1]).astype(x.dtype), tuple(gws)


rns_multi_dot.defvjp(_rns_multi_fwd, _rns_multi_bwd)
