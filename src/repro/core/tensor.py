"""First-class residue-domain tensor with cross-op deferred normalization.

An :class:`RnsTensor` carries a value tensor entirely in the residue
domain:

  ``value = X / (scale * M_f**frac_exp)``  with  ``X`` the signed integer
  encoded by ``digits`` ([K, *shape] residue planes of the profile).

* ``scale`` is a traced scalar (the fixed-point quantization scale —
  data-dependent via absmax), so RnsTensor round-trips through jit/vmap.
* ``frac_exp`` is *static* bookkeeping of pending Olsen M_f powers: every
  fractional multiply raises it by one instead of paying the slow
  normalization.  Keeping it static lets decode fold ``M_f**-frac_exp``
  into exact host-side float64 weights (M_f powers overflow float32 fast).
* ``mag_bits`` is a static worst-case bound on ``log2|X|``.  It is the
  deferral ledger: chained PAC ops (matmul, elementwise multiply, add)
  grow it, and :func:`rt_matmul` / :func:`rt_mul` consult it to decide
  when a renormalization is *actually required* — one slow MRC op per
  chain/block instead of one per op, the paper's central claim.

All heavy lifting routes through :mod:`repro.core.dispatch`, so an
RnsTensor program runs unchanged on the jnp reference path, the Pallas
kernels, or — with a ``distributed.sharding.use_digit_sharding`` context
installed — digit-sharded over a device mesh: the leading ``[K, ...]``
digit axis is partitioned over the ``model`` axis (one group of moduli
per device), every PAC op stays device-local, and the single MRC decode
is the only point where digits are gathered.  :func:`rt_device_put`
places an already-encoded tensor into that layout.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.core.moduli import RnsProfile, get_profile
from repro.core.quantize import absmax_scale

__all__ = [
    "RnsTensor",
    "rt_encode",
    "rt_encode_int",
    "rt_decode",
    "rt_matmul",
    "rt_mul",
    "rt_add",
    "rt_renormalize",
    "rt_device_put",
    "rt_digit_sharding",
    "rt_stack",
    "rt_encode_matmul",
    "rt_matmul_decode",
    "rt_dot",
    "matmul_out_bits",
    "needs_renormalize",
    "ledger_limit_bits",
    "dot_out_bits",
]

#: headroom (bits) kept below the profile's guaranteed signed range when
#: deciding whether a deferred op still fits exactly.
_SAFETY_BITS = 1.0


def ledger_limit_bits(profile) -> float:
    """THE overflow threshold: every ledger decision in the repo — runtime
    (``needs_renormalize``, ``_matmul_ledger``, ``headroom_bits``) and
    static (``repro.analysis.ledger_audit``) — compares ``log2|X|`` bounds
    against this one number, ``signed_bits - _SAFETY_BITS``."""
    p = get_profile(profile) if isinstance(profile, str) else profile
    return p.signed_bits - _SAFETY_BITS


def dot_out_bits(a_bits: float, w_bits: float, contract_dim: int) -> float:
    """Worst-case ``log2|X|`` of a ``contract_dim``-term product summation
    of ``a_bits``- and ``w_bits``-bit operands — the ONE growth formula
    shared by the runtime ledger and the static auditor."""
    return a_bits + w_bits + math.log2(max(contract_dim, 1))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RnsTensor:
    """Residues + profile + scale-exponent bookkeeping (a jax pytree).

    ``digits``: [K, *shape] int8/int32 residue planes (leaf).
    ``scale``:  scalar array, value = X / (scale * M_f**frac_exp) (leaf).
    ``profile``: RNS profile name (static).
    ``mag_bits``: static bound on log2|X| (deferral ledger).
    ``frac_exp``: static count of deferred M_f normalizations.
    """

    digits: jax.Array
    scale: jax.Array
    profile: str
    mag_bits: float
    frac_exp: int = 0

    # ------------------------------------------------------------ pytree --
    def tree_flatten(self):
        return (self.digits, self.scale), (
            self.profile, self.mag_bits, self.frac_exp)

    @classmethod
    def tree_unflatten(cls, aux, children):
        digits, scale = children
        profile, mag_bits, frac_exp = aux
        return cls(digits, scale, profile, mag_bits, frac_exp)

    # ------------------------------------------------------- conveniences --
    @property
    def shape(self) -> tuple[int, ...]:
        return self.digits.shape[1:]

    @property
    def ndim(self) -> int:
        return self.digits.ndim - 1

    @property
    def rns_profile(self) -> RnsProfile:
        return get_profile(self.profile)

    def headroom_bits(self) -> float:
        """Exactness margin left before |X| could exceed M/2."""
        return ledger_limit_bits(self.rns_profile) - self.mag_bits

    def astype_digits(self, dtype):
        return dataclasses.replace(self, digits=self.digits.astype(dtype))


def _digits32(rt: RnsTensor) -> jax.Array:
    return rt.digits.astype(jnp.int32)


def _annotate(rt: RnsTensor, role: str, arr=None, base=None):
    """Report ``rt``'s ledger state to an installed analysis recorder
    (no-op otherwise).  ``arr`` overrides the annotated array when the
    digits were cast/viewed on the way into dispatch; ``base`` links the
    cast back to the original digits object for dataflow chaining."""
    if not dispatch.recording():
        return
    extra = {} if base is None else {"base": base}
    dispatch.annotate_digits(arr if arr is not None else rt.digits,
                             profile=rt.profile, mag_bits=rt.mag_bits,
                             frac_exp=rt.frac_exp, role=role, **extra)


# ------------------------------------------------------------ mesh layout --
def rt_digit_sharding(rt: RnsTensor, *, digit_axis: int = 0):
    """The NamedSharding the installed digit mesh assigns to ``rt.digits``
    ([K, ...] partitioned over the ``model`` axis), or None when no digit
    context is installed / the profile doesn't divide the axis.

    ``digit_axis``: position of the K digit axis in ``rt.digits`` — 0 for
    the plain layout, 1 for period-major stacked resident weights
    (``[P, K, ...]``, see :func:`rt_stack`)."""
    from repro.distributed.sharding import digit_sharding

    ds = digit_sharding()
    if ds is None or not ds.shards(rt.rns_profile.n_digits):
        return None
    return ds.digit_sharding(rt.digits.ndim, axis_pos=digit_axis)


def rt_device_put(rt: RnsTensor, *, digit_axis: int = 0) -> RnsTensor:
    """Place an encoded tensor into the digit-sharded layout (host->mesh).

    Tensors *produced* under the digit context already carry this layout
    (dispatch's shard_map outputs); this is for pre-encoded operands —
    e.g. weights encoded once at engine build time — so the per-step jit
    consumes them without a layout change.
    """
    sh = rt_digit_sharding(rt, digit_axis=digit_axis)
    if sh is None:
        return rt
    return dataclasses.replace(rt, digits=jax.device_put(rt.digits, sh))


def rt_stack(rts) -> RnsTensor:
    """Stack per-period tensors period-MAJOR: digits [P, K, ...], scale [P].

    The period axis leads (not the digit axis) so a ``lax.scan`` over the
    stacked pytree slices out one valid RnsTensor per period — scan
    consumes leading axes of *leaves*, and an RnsTensor's leaves are
    exactly (digits, scale) while (profile, mag_bits, frac_exp) stay
    static aux shared by every period.  This is the layout resident
    weights live in inside the scanned transformer stack.
    """
    rts = list(rts)
    p0, fe0 = rts[0].profile, rts[0].frac_exp
    if any(r.profile != p0 or r.frac_exp != fe0 for r in rts):
        raise ValueError("rt_stack needs one shared profile and frac_exp "
                         "(they are static aux — scan shares them)")
    return RnsTensor(
        jnp.stack([r.digits for r in rts], axis=0),
        jnp.stack([jnp.reshape(r.scale, ()) for r in rts], axis=0),
        p0, max(r.mag_bits for r in rts), fe0)


# ------------------------------------------------------------- encoding ---
def rt_encode(x, profile, *, bits: int = 16, scale=None,
              backend: str | None = None, weight: bool = False) -> RnsTensor:
    """Quantize a float tensor and forward-convert it (cheap PAC work).

    ``scale`` defaults to the per-tensor absmax scale for ``bits``; pass an
    explicit scale to pin the fixed-point grid (e.g. for exact oracles).
    ``weight=True`` marks a static-weight conversion in the op tallies
    (see :class:`~repro.core.dispatch.OpCounts.weight_converts`).
    """
    p = get_profile(profile) if isinstance(profile, str) else profile
    if scale is None:
        scale = absmax_scale(x, bits)
    digits = dispatch.convert(p, x, scale, bits=bits, backend=backend,
                              weight=weight)
    return RnsTensor(digits, jnp.asarray(scale, jnp.float32), p.name,
                     float(bits - 1))


def _concrete_int_mag_bits(v) -> float | None:
    """``log2(max|v|)`` when ``v`` is concrete (python int / numpy scalar
    or array / committed jax array), None when traced."""
    if isinstance(v, jax.core.Tracer):
        return None
    try:
        m = int(np.max(np.abs(np.asarray(v))))
    except (TypeError, ValueError, jax.errors.TracerArrayConversionError):
        return None
    return math.log2(m) if m > 1 else 0.0


def rt_encode_int(v, profile, *, mag_bits: float | None = None) -> RnsTensor:
    """Encode an int32 tensor exactly (scale 1; oracle-friendly).

    The ledger entry (``mag_bits``) defaults to the *actual* bound
    ``log2(max|v|)`` when ``v`` is concrete — not a blanket int32 worst
    case — and raises if that bound escapes the profile's guaranteed
    signed range (the old default silently encoded unrepresentable
    values as garbage residues).  Traced ``v`` falls back to the int32
    payload bound clamped to the profile.
    """
    from repro.core.rns import encode_int32

    p = get_profile(profile) if isinstance(profile, str) else profile
    if mag_bits is None:
        mag_bits = _concrete_int_mag_bits(v)
        if mag_bits is None:
            mag_bits = min(31.0, float(p.signed_bits))
        elif mag_bits > p.signed_bits:
            raise ValueError(
                f"profile {p.name} cannot represent max|v| = 2^"
                f"{mag_bits:.1f} exactly (signed range is "
                f"{p.signed_bits:.1f} bits); use a wider profile")
    digits = encode_int32(p, v)
    if p.int8_safe:
        digits = digits.astype(jnp.int8)
    return RnsTensor(digits, jnp.float32(1.0), p.name, float(mag_bits))


# ------------------------------------------------------------- decoding ---
def rt_decode(rt: RnsTensor, *, backend: str | None = None,
              dtype=jnp.float32):
    """Back to floats: exactly ONE MRC normalization, whatever the chain
    of deferred ops that produced ``rt``."""
    p = rt.rns_profile
    inv = 1.0 / float(p.M_f) ** rt.frac_exp if rt.frac_exp else 1.0
    d32 = _digits32(rt)
    _annotate(rt, "decode_in", arr=d32, base=rt.digits)
    y = dispatch.normalize(p.name, d32, inv_scale=inv,
                           backend=backend, dtype=dtype)
    return y / rt.scale.astype(dtype)


# ------------------------------------------------------- deferral ledger --
def matmul_out_bits(a: RnsTensor, w: RnsTensor, contract_dim: int) -> float:
    """Worst-case log2|X| of a product summation of ``a`` and ``w``."""
    return dot_out_bits(a.mag_bits, w.mag_bits, contract_dim)


def needs_renormalize(a: RnsTensor, extra_bits: float) -> bool:
    """Would growing ``a`` by ``extra_bits`` overflow the exact range?"""
    return a.mag_bits + extra_bits > ledger_limit_bits(a.rns_profile)


def rt_renormalize(rt: RnsTensor, *, bits: int = 16,
                   backend: str | None = None) -> RnsTensor:
    """THE slow op: MRC-decode and re-encode on a fresh ``bits`` grid.

    Inserted automatically by :func:`rt_matmul` / :func:`rt_mul` only when
    the magnitude ledger says the next PAC op would overflow — this is the
    "bookkeeping decides when normalization is actually required" point.
    """
    dispatch.record_op("renormalize", None, (rt.digits,), profile=rt.profile,
                       in_bits=rt.mag_bits, bits=bits, tallies={})
    y = rt_decode(rt, backend=backend)
    return rt_encode(y, rt.profile, bits=bits, backend=backend)


# ---------------------------------------------------------------- PAC ops -
def rt_matmul(a: RnsTensor, w: RnsTensor, *, backend: str | None = None,
              renorm_bits: int = 16) -> RnsTensor:
    """Residues-in/residues-out matmul along the last dim of ``a``.

    Stays entirely in the residue domain (no normalization).  If the
    magnitude ledger proves the exact range would overflow, the
    *activation* operand is renormalized first (one slow op), then the
    chain continues deferred.
    """
    if a.profile != w.profile:
        raise ValueError(f"profile mismatch: {a.profile} vs {w.profile}")
    a = _matmul_ledger(a, w, backend=backend, renorm_bits=renorm_bits)
    D = a.shape[-1]
    _annotate(a, "activation")
    _annotate(w, "weight")
    digits = dispatch.matmul(a.profile, a.digits, w.digits, backend=backend)
    out = RnsTensor(digits, a.scale * w.scale, a.profile,
                    matmul_out_bits(a, w, D), a.frac_exp + w.frac_exp)
    _annotate(out, "out", arr=digits)
    return out


def _matmul_ledger(a: RnsTensor, w: RnsTensor, *, backend, renorm_bits):
    """The shared pre-matmul overflow check: renormalize ``a`` once if the
    product summation would escape the exact range, raise if even that
    cannot fit."""
    D = a.shape[-1]
    lim = ledger_limit_bits(a.rns_profile)
    if matmul_out_bits(a, w, D) > lim:
        a = rt_renormalize(a, bits=renorm_bits, backend=backend)
        if matmul_out_bits(a, w, D) > lim:
            raise ValueError(
                f"profile {a.profile} cannot hold an exact {D}-term product "
                f"summation of {a.mag_bits:.0f}+{w.mag_bits:.0f}-bit operands "
                f"even after renormalization; use a wider profile")
    return a


# ------------------------------------------------------- fused entries ---
def _encode_out_bits(p, bits: int, w: RnsTensor, D: int) -> float:
    """Ledger bound of encode(x, bits) @ w — ONE home for the check the
    fused entry points share (same formula as matmul_out_bits on a fresh
    ``bits``-grid encode).  Raises if the exact range would overflow."""
    out_bits = dot_out_bits(float(bits - 1), w.mag_bits, D)
    if out_bits > ledger_limit_bits(p):
        raise ValueError(
            f"profile {p.name} cannot hold an exact {D}-term product "
            f"summation of {bits - 1}+{w.mag_bits:.0f}-bit operands; use a "
            f"wider profile or fewer bits")
    return out_bits


def rt_encode_matmul(x, w: RnsTensor, *, bits: int = 16, scale=None,
                     backend: str | None = None) -> RnsTensor:
    """Fused head of a chain: forward conversion + digit matmul.

    Identical numerics and ledger bookkeeping to ``rt_matmul(rt_encode(x),
    w)``; with a fused backend the activation residues never reach HBM
    (the paper's edge-of-array converter feeding the PAC array).  Other
    backends decompose inside dispatch, so call sites stay uniform.
    """
    p = get_profile(w.profile)
    if scale is None:
        scale = absmax_scale(x, bits)
    out_bits = _encode_out_bits(p, bits, w, x.shape[-1])
    _annotate(w, "weight")
    digits = dispatch.fused_encode_matmul(p.name, x, scale, w.digits,
                                          bits=bits, backend=backend)
    out = RnsTensor(digits, jnp.asarray(scale, jnp.float32) * w.scale,
                    p.name, out_bits, w.frac_exp)
    _annotate(out, "out", arr=digits)
    return out


def rt_matmul_decode(a: RnsTensor, w: RnsTensor, *, backend: str | None = None,
                     renorm_bits: int = 16, dtype=jnp.float32):
    """Fused tail of a chain: digit matmul + THE one MRC normalization.

    Bit-identical to ``rt_decode(rt_matmul(a, w))``; with a fused backend
    the [K, ..., N] product residues never reach HBM — the MRC runs on
    the accumulator tile while it is still in VMEM.
    """
    if a.profile != w.profile:
        raise ValueError(f"profile mismatch: {a.profile} vs {w.profile}")
    a = _matmul_ledger(a, w, backend=backend, renorm_bits=renorm_bits)
    _annotate(a, "activation")
    _annotate(w, "weight")
    p = a.rns_profile
    fe = a.frac_exp + w.frac_exp
    inv = 1.0 / float(p.M_f) ** fe if fe else 1.0
    y = dispatch.fused_matmul_normalize(a.profile, a.digits, w.digits,
                                        inv_scale=inv, backend=backend,
                                        dtype=dtype)
    return y / (a.scale * w.scale).astype(dtype)


def rt_dot(x, w: RnsTensor, *, bits: int = 16, scale=None,
           backend: str | None = None, dtype=jnp.float32,
           shared_encode: bool = False):
    """Single-op fused pipeline: encode -> digit matmul -> normalize.

    Float activations in, float values out; the residues only ever exist
    in VMEM on a fused backend.  Equivalent to
    ``rt_decode(rt_matmul(rt_encode(x), w))`` for capacity-safe chains.
    ``shared_encode`` forwards to :func:`dispatch.fused_dot` — pass True
    when ``x``'s conversion was already tallied by a sibling composite.
    """
    p = get_profile(w.profile)
    if scale is None:
        scale = absmax_scale(x, bits)
    _encode_out_bits(p, bits, w, x.shape[-1])   # raises on overflow
    _annotate(w, "weight")
    inv = 1.0 / float(p.M_f) ** w.frac_exp if w.frac_exp else 1.0
    y = dispatch.fused_dot(p.name, x, scale, w.digits, bits=bits,
                           inv_scale=inv, backend=backend, dtype=dtype,
                           shared_encode=shared_encode)
    return y / (jnp.asarray(scale, jnp.float32) * w.scale).astype(dtype)


def rt_mul(a: RnsTensor, b: RnsTensor, *, backend: str | None = None,
           renorm_bits: int = 16) -> RnsTensor:
    """Elementwise PAC product (deferred — no normalization)."""
    from repro.core.rns import rns_mul

    if a.profile != b.profile:
        raise ValueError(f"profile mismatch: {a.profile} vs {b.profile}")
    if needs_renormalize(a, b.mag_bits):
        a = rt_renormalize(a, bits=renorm_bits, backend=backend)
        if needs_renormalize(a, b.mag_bits):
            raise ValueError(
                f"profile {a.profile} cannot hold an exact elementwise "
                f"product of {a.mag_bits:.0f}+{b.mag_bits:.0f}-bit operands")
    da, db = _digits32(a), _digits32(b)
    _annotate(a, "mul_in", arr=da, base=a.digits)
    _annotate(b, "mul_in", arr=db, base=b.digits)
    digits = rns_mul(a.profile, da, db)
    out = RnsTensor(digits, a.scale * b.scale, a.profile,
                    a.mag_bits + b.mag_bits, a.frac_exp + b.frac_exp)
    dispatch.record_op("pac_mul", digits, (da, db), profile=a.profile,
                       tallies={})
    _annotate(out, "out", arr=digits)
    return out


def rt_add(a: RnsTensor, b: RnsTensor) -> RnsTensor:
    """Elementwise PAC sum.  Operands must share one fixed-point grid
    (same scale provenance and frac_exp) — adding across grids needs a
    renormalization, which the caller should do explicitly."""
    from repro.core.rns import rns_add

    if a.profile != b.profile or a.frac_exp != b.frac_exp:
        raise ValueError("rt_add operands must share profile and frac_exp")
    da, db = _digits32(a), _digits32(b)
    _annotate(a, "add_in", arr=da, base=a.digits)
    _annotate(b, "add_in", arr=db, base=b.digits)
    digits = rns_add(a.profile, da, db)
    out = RnsTensor(digits, a.scale, a.profile,
                    max(a.mag_bits, b.mag_bits) + 1.0, a.frac_exp)
    dispatch.record_op("pac_add", digits, (da, db), profile=a.profile,
                       tallies={})
    _annotate(out, "out", arr=digits)
    return out
