"""Olsen fractional RNS (US20130311532): v is carried as X = round(v * M_f).

* add/sub: PAC (single digit-parallel op).
* multiply: PAC digit product (scale M_f^2) + "slow" normalization
  (scale_signed divides by M_f with rounding).
* product summation: all multiplies/accumulates are PAC at scale M_f^2;
  ONE normalization at the end — the deferred-normalization claim.
"""

from __future__ import annotations

import numpy as np

from repro.core import mrc
from repro.core.moduli import get_profile, RnsProfile
from repro.core.rns import (
    encode_int32,
    encode_exact,
    decode_exact,
    rns_add,
    rns_sub,
    rns_neg,
    rns_mul,
    rns_scale_const,
    tables,
)

__all__ = [
    "fr_encode",
    "fr_encode_exact",
    "fr_decode",
    "fr_decode_exact",
    "fr_add",
    "fr_sub",
    "fr_neg",
    "fr_mul",
    "fr_mul_raw",
    "fr_normalize",
    "fr_from_int",
    "fr_ge_const",
    "fr_dot_deferred",
]


def _p(profile) -> RnsProfile:
    return get_profile(profile) if isinstance(profile, str) else profile


def fr_encode(profile, x):
    """Encode float tensor as fractional RNS (device path, |x|*M_f < 2**31)."""
    p = _p(profile)
    if p.M_f >= 2**31:
        raise ValueError("M_f too large for device float encode; use fr_encode_exact")
    import jax.numpy as jnp

    v = jnp.round(jnp.asarray(x, jnp.float32) * np.float32(p.M_f)).astype(jnp.int32)
    return encode_int32(p, v)


def fr_encode_exact(profile, values) -> np.ndarray:
    """Host-side exact encode from floats/Fractions via python ints."""
    from fractions import Fraction

    p = _p(profile)
    vals = np.asarray(values, dtype=object).reshape(-1)
    ints = [
        int(round(Fraction(v) * p.M_f)) if not isinstance(v, int) else v * p.M_f
        for v in vals
    ]
    out = encode_exact(p, np.asarray(ints, dtype=object))
    return out.reshape((p.n_digits,) + np.asarray(values, dtype=object).shape)


def fr_decode(profile, res, dtype=None):
    import jax.numpy as jnp

    p = _p(profile)
    return mrc.decode_float(p, res, inv_scale=1.0 / p.M_f, dtype=dtype or jnp.float32)


def fr_decode_exact(profile, res):
    """Host-side exact decode to Fractions."""
    from fractions import Fraction

    p = _p(profile)
    ints = decode_exact(p, res, signed=True)
    flat = np.asarray(ints, dtype=object).reshape(-1)
    out = np.asarray([Fraction(int(v), p.M_f) for v in flat], dtype=object)
    return out.reshape(np.asarray(ints, dtype=object).shape)


def fr_add(profile, x, y):
    return rns_add(_p(profile), x, y)


def fr_sub(profile, x, y):
    return rns_sub(_p(profile), x, y)


def fr_neg(profile, x):
    return rns_neg(_p(profile), x)


def fr_mul_raw(profile, x, y):
    """PAC product at scale M_f^2 (deferred normalization)."""
    return rns_mul(_p(profile), x, y)


def fr_normalize(profile, raw):
    """Divide a raw (M_f^2-scaled) value by M_f with rounding — the slow op."""
    return mrc.scale_signed(_p(profile), raw, rounded=True)


def fr_mul(profile, x, y):
    return fr_normalize(profile, fr_mul_raw(profile, x, y))


def fr_from_int(profile, n):
    """Exact fractional encode of an integer tensor (PAC scale by M_f)."""
    p = _p(profile)
    return rns_scale_const(p, encode_int32(p, n), p.M_f)


def fr_ge_const(profile, res, c: float, *, raw: bool = False):
    """value >= c.  ``raw=True`` compares an M_f^2-scaled (unnormalized) value."""
    from fractions import Fraction

    p = _p(profile)
    scale = p.M_f * p.M_f if raw else p.M_f
    cint = int(round(Fraction(c) * scale))
    return mrc.compare_ge_const(p, res, cint)


def fr_dot_deferred(profile, xs, ys):
    """Product summation: PAC MACs at scale M_f^2, ONE final normalization.

    xs, ys: (n, K, ...) stacked fractional residues.  Returns fractional
    residues of sum_i xs[i]*ys[i].  Exactness requires n * max|x*y| * M_f^2
    < M/2.

    The accumulation is a vectorized lazy-reduction fold: per-element
    products are < max_digit**2, so up to ``lazy_chunk`` terms sum
    exactly in int32 with a single modular reduction per chunk — the
    trace is O(n / lazy_chunk) ops (effectively O(1)), not O(n).
    """
    import jax.numpy as jnp

    p = _p(profile)
    t = tables(p)
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (xs.ndim - 2))
    n = xs.shape[0]
    chunk = p.lazy_chunk
    acc = jnp.zeros(xs.shape[1:], jnp.int32)
    for s in range(0, n, chunk):
        part = jnp.sum(
            (xs[s:s + chunk] * ys[s:s + chunk]).astype(jnp.int32), axis=0)
        part = jnp.remainder(part, m)       # one lazy reduction per chunk
        acc = jnp.remainder(acc + part, m)
    return fr_normalize(p, acc)
