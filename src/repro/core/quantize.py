"""Symmetric fixed-point quantization feeding the RNS conversion pipeline."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["absmax_scale", "quantize", "dequantize"]


def absmax_scale(x, bits: int, axis=None, eps: float = 1e-12):
    """Scale s such that round(x*s) uses <= ``bits`` signed bits.

    axis=None -> per-tensor scalar; otherwise the scale is reduced over
    ``axis`` (per-channel).  The scale is stop-gradient'ed (STE).
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True
    )
    s = qmax / jnp.maximum(amax, eps)
    return jax.lax.stop_gradient(s)


def quantize(x, bits: int, axis=None):
    """Returns (int32 values, scale).  v = clip(round(x*s))."""
    s = absmax_scale(x, bits, axis=axis)
    qmax = 2 ** (bits - 1) - 1
    v = jnp.clip(jnp.round(x * s), -qmax, qmax).astype(jnp.int32)
    return v, s


def dequantize(v, s):
    return v.astype(jnp.float32) / s
