"""Symmetric fixed-point quantization feeding the RNS conversion pipeline."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["absmax_scale", "quantize", "quantize_with_scale", "dequantize"]


def absmax_scale(x, bits: int, axis=None, eps: float = 1e-12):
    """Scale s such that round(x*s) uses <= ``bits`` signed bits.

    axis=None -> per-tensor scalar; otherwise the scale is reduced over
    ``axis`` (per-channel).  The scale is stop-gradient'ed (STE).
    """
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x)) if axis is None else jnp.max(
        jnp.abs(x), axis=axis, keepdims=True
    )
    s = qmax / jnp.maximum(amax, eps)
    return jax.lax.stop_gradient(s)


def quantize_with_scale(x, s, bits: int):
    """v = clip(round(x*s)) on a caller-chosen scale — THE fixed-point
    rule; the Pallas conversion kernel mirrors it and is tested against
    this reference."""
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) * s),
                    -qmax, qmax).astype(jnp.int32)


def quantize(x, bits: int, axis=None):
    """Returns (int32 values, scale).  v = clip(round(x*s))."""
    s = absmax_scale(x, bits, axis=axis)
    return quantize_with_scale(x, s, bits), s


def dequantize(v, s):
    return v.astype(jnp.float32) / s
