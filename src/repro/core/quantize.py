"""Symmetric fixed-point quantization feeding the RNS conversion pipeline.

Two grid policies:

* **per-tensor** (default): one absmax scale for the whole tensor — the
  cheapest grid, used everywhere shapes are dense.
* **per-sequence** (mask-aware): padded ragged batches compute each row's
  scale over its REAL tokens only.  A per-tensor scale over a padded
  ``[B, Tpad, d]`` activation couples rows through the pad garbage, which
  is why the RNS path used to lose bit-exactness under continuous
  batching; with a :class:`token_mask` context installed (see
  ``models/model.prefill_ragged`` / ``decode_step``) every sequence gets
  the same grid a solo run would compute, making padded prefill and
  batched decode token-identical to solo runs (asserted in
  tests/test_serve_continuous.py).

Degenerate inputs: an all-zero (or sub-``eps``) block used to produce
``~qmax/eps ≈ 9e15`` scales whose products overflow float32 after a few
chained ops; blocks whose absmax sits below ``eps`` now flush to the
unit grid (quantizing to exact zeros), which keeps chained scale
products bounded.
"""

from __future__ import annotations

import threading

import jax
import jax.numpy as jnp

__all__ = [
    "absmax_scale",
    "quantize",
    "quantize_with_scale",
    "dequantize",
    "token_mask",
    "current_token_mask",
]

_state = threading.local()          # trace-time token-mask stack


def _masks() -> list:
    if not hasattr(_state, "masks"):
        _state.masks = []
    return _state.masks


class token_mask:
    """Install a ``[B, T]`` validity mask for per-sequence quantization.

    Inside the context, :func:`absmax_scale` computes PER-ROW scales over
    positions where the mask is True, for activations whose leading dims
    match the mask (``[B, T, ...]``).  Weights and other shapes keep the
    per-tensor grid.  ``mask=None`` is a no-op.  The mask may be a traced
    array: install it inside the traced function (the jitted prefill /
    decode step), not around the jit call.

    ``per_token=True`` tightens the grid to one scale per (row, token)
    instead of one per row: the reduction then runs over the feature dims
    only, yielding a ``[B, T, 1, ...]`` scale.  This is what makes a
    ``[R, W]`` speculative-verify window bit-identical per position to W
    consecutive one-token decode steps — each window position gets
    exactly the grid its own solo decode step would have computed,
    instead of a grid coupled to its window neighbours.
    """

    def __init__(self, mask, per_token: bool = False):
        self.mask = mask
        self.per_token = per_token

    def __enter__(self):
        if self.mask is not None:
            _masks().append((self.mask, self.per_token))
        return self

    def __exit__(self, *exc):
        # pop by position, not value: the mask may be a tracer, and
        # list.remove would force a traced __eq__ into a python bool
        if self.mask is not None:
            _masks().pop()
        return False


def current_token_mask():
    """The innermost installed (mask, per_token) pair, or None."""
    ms = _masks()
    return ms[-1] if ms else None


def _context_mask_for(x):
    """The installed (mask, per_token) if ``x`` looks like a [B, T, ...]
    activation matching the mask's leading dims."""
    ctx = current_token_mask()
    if ctx is None:
        return None
    mask, per_token = ctx
    if x.ndim == mask.ndim + 1 and x.shape[: mask.ndim] == mask.shape:
        return mask, per_token
    return None


def absmax_scale(x, bits: int, axis=None, eps: float = 1e-12, mask=None,
                 per_token: bool = False):
    """Scale s such that round(x*s) uses <= ``bits`` signed bits.

    axis=None -> per-tensor scalar; otherwise the scale is reduced over
    ``axis`` (per-channel).  With ``mask`` (explicit ``[B, T]``, or
    installed via :class:`token_mask`) the reduction runs per row over
    unmasked positions only (per-sequence grids for ragged batches);
    ``per_token`` additionally keeps the token axis, one grid per
    (row, token) — see :class:`token_mask`.  All-zero (or fully masked)
    inputs get scale 1.0 — see module docstring.  The scale is
    stop-gradient'ed (STE).
    """
    qmax = float(2 ** (bits - 1) - 1)
    x = jnp.asarray(x)
    if mask is None and axis is None:
        ctx = _context_mask_for(x)
        if ctx is not None:
            mask, per_token = ctx
    if mask is not None:
        m = jnp.asarray(mask, bool)
        mask_ndim = m.ndim
        m = m.reshape(m.shape + (1,) * (x.ndim - m.ndim))
        red = (tuple(range(mask_ndim, x.ndim)) if per_token
               else tuple(range(1, x.ndim)))
        amax = jnp.max(jnp.where(m, jnp.abs(x), 0.0), axis=red, keepdims=True)
    elif axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    # eps is the denormal floor: blocks whose absmax sits below it flush
    # to the unit grid (quantizing to exact zeros) instead of receiving a
    # ~qmax/eps scale — those scales are what overflow chained float32
    # scale products.  Clamping only exact zero would leave amax in
    # (0, eps) on the overflow path.
    s = jnp.where(amax >= eps, qmax / amax, 1.0)
    return jax.lax.stop_gradient(s)


def quantize_with_scale(x, s, bits: int):
    """v = clip(round(x*s)) on a caller-chosen scale — THE fixed-point
    rule; the Pallas conversion kernel mirrors it and is tested against
    this reference."""
    qmax = 2 ** (bits - 1) - 1
    return jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) * s),
                    -qmax, qmax).astype(jnp.int32)


def quantize(x, bits: int, axis=None):
    """Returns (int32 values, scale).  v = clip(round(x*s))."""
    s = absmax_scale(x, bits, axis=axis)
    return quantize_with_scale(x, s, bits), s


def dequantize(v, s):
    return v.astype(jnp.float32) / s
