"""RNS codec and PAC (parallel-array-computation) ops.

Residue layout convention: a value tensor of shape ``(...,)`` is represented
by a residue tensor of shape ``(K, ...)`` with int32 digits, where K is the
number of moduli of the profile.  Every PAC op is one elementwise modular op
per digit, all digits independent — the paper's carry-free property.

Exact (python-int) encode/decode helpers live here too; they are the test
oracles for everything downstream.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

from repro.core.moduli import RnsProfile, get_profile

__all__ = [
    "Tables",
    "tables",
    "encode_int32",
    "encode_float",
    "encode_exact",
    "decode_exact",
    "rns_add",
    "rns_sub",
    "rns_neg",
    "rns_mul",
    "rns_scale_const",
    "rns_add_const",
    "to_int8",
    "from_int8",
]


class Tables:
    """Precomputed constant tables for a profile (host numpy; jit constants)."""

    def __init__(self, p: RnsProfile):
        self.profile = p
        K = p.n_digits
        ms = p.moduli
        self.moduli = np.asarray(ms, np.int32)
        # mrc_inv[i, j] = (m_i)^-1 mod m_j   (only used for j > i)
        inv = np.ones((K, K), np.int64)
        for i in range(K):
            for j in range(K):
                if j > i:
                    inv[i, j] = pow(ms[i], -1, ms[j])
        self.mrc_inv = inv.astype(np.int32)
        # W_j = prod_{i<j} m_i (python ints, exact)
        self.W: list[int] = [1] * K
        for j in range(1, K):
            self.W[j] = self.W[j - 1] * ms[j - 1]
        # base-extension table: ext[j, k] = W_j mod m_k
        self.ext = np.asarray(
            [[w % m for m in ms] for w in self.W], np.int32
        )
        # scaled-weight table for scale-by-M_f: Wf_j = W_j // M_f for j >= f
        f = p.frac_digits
        self.Wf: list[int] = [self.W[j] // p.M_f for j in range(f, K)]
        self.ext_scaled = np.asarray(
            [[w % m for m in ms] for w in self.Wf], np.int32
        )
        # W_j mod 2**32 for exact int32 reconstruction (wrap arithmetic)
        def _wrap32(x: int) -> int:
            x %= 1 << 32
            return x - (1 << 32) if x >= (1 << 31) else x

        self.W_mod32 = np.asarray([_wrap32(w) for w in self.W], np.int32)
        self.M_mod32 = np.int32(_wrap32(p.M))
        # MRC digits of M//2 (for sign detection: X negative iff X >= M/2)
        self.half_digits = np.asarray(_int_to_mrc(p.M // 2, ms), np.int32)
        # float reconstruction weights (float64, divided at use-site by scale)
        self.W_f64 = np.asarray([float(w) for w in self.W], np.float64)
        self.M_f64 = float(p.M)


def _int_to_mrc(x: int, ms: tuple[int, ...]) -> list[int]:
    """Exact mixed-radix digits of x (python ints)."""
    out = []
    for m in ms:
        out.append(x % m)
        x //= m
    return out


@functools.lru_cache(maxsize=None)
def tables(profile: RnsProfile | str) -> Tables:
    if isinstance(profile, str):
        profile = get_profile(profile)
    return Tables(profile)


def _mvec(t: Tables, ndim: int):
    """Moduli broadcast to (K, 1, 1, ...) for a (K, ...) residue tensor."""
    return jnp.asarray(t.moduli).reshape((-1,) + (1,) * (ndim - 1))


# ----------------------------------------------------------------- codec ---
def encode_int32(profile: RnsProfile | str, v):
    """Residues of an int32 tensor (negatives map to M - |v|)."""
    t = tables(profile)
    v = jnp.asarray(v, jnp.int32)
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * v.ndim)
    return jnp.remainder(v[None], m).astype(jnp.int32)


def encode_float(profile: RnsProfile | str, x, scale: float):
    """Quantize float tensor to round(x*scale) and encode. |x*scale|<2**31."""
    v = jnp.round(jnp.asarray(x, jnp.float32) * jnp.float32(scale))
    v = jnp.clip(v, -(2.0**31 - 1), 2.0**31 - 1).astype(jnp.int32)
    return encode_int32(profile, v)


def encode_exact(profile: RnsProfile | str, values) -> np.ndarray:
    """Host-side exact encode of arbitrary-size python ints (test oracle)."""
    t = tables(profile)
    vals = np.asarray(values, dtype=object)
    flat = vals.reshape(-1)
    K = t.profile.n_digits
    out = np.empty((K, flat.size), np.int32)
    for j, m in enumerate(t.profile.moduli):
        out[j] = [int(int(v) % m) for v in flat]
    return out.reshape((K,) + vals.shape)


def decode_exact(profile: RnsProfile | str, res, signed: bool = True):
    """Host-side exact CRT decode to python ints (test oracle)."""
    t = tables(profile)
    p = t.profile
    res = np.asarray(res)
    K = p.n_digits
    flat = res.reshape(K, -1)
    # Garner / MRC with python ints
    out = []
    for col in range(flat.shape[1]):
        r = [int(flat[j, col]) for j in range(K)]
        x = 0
        for j in range(K):
            d = (r[j] - x) * pow(t.W[j] % p.moduli[j], -1, p.moduli[j]) % p.moduli[j]
            x += d * t.W[j]
        if signed and x >= p.M // 2:
            x -= p.M
        out.append(x)
    arr = np.asarray(out, dtype=object).reshape(res.shape[1:])
    return arr


# -------------------------------------------------------------- PAC ops ---
def rns_add(profile, x, y):
    t = tables(profile)
    return jnp.remainder(x + y, _mvec(t, x.ndim))


def rns_sub(profile, x, y):
    t = tables(profile)
    m = _mvec(t, x.ndim)
    return jnp.remainder(x - y + m, m)


def rns_neg(profile, x):
    t = tables(profile)
    m = _mvec(t, x.ndim)
    return jnp.remainder(m - x, m)


def rns_mul(profile, x, y):
    t = tables(profile)
    return jnp.remainder(x * y, _mvec(t, x.ndim))


def rns_scale_const(profile, x, c: int):
    """PAC scaling: multiply by a (possibly huge) integer constant, exactly."""
    t = tables(profile)
    cres = jnp.asarray(
        np.asarray([int(c) % m for m in t.profile.moduli], np.int32)
    ).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.remainder(x * cres, _mvec(t, x.ndim))


def rns_add_const(profile, x, c: int):
    t = tables(profile)
    cres = jnp.asarray(
        np.asarray([int(c) % m for m in t.profile.moduli], np.int32)
    ).reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.remainder(x + cres, _mvec(t, x.ndim))


# ------------------------------------------------------------- storage ----
def to_int8(profile, res):
    t = tables(profile)
    if not t.profile.int8_safe:
        raise ValueError(f"profile {t.profile.name} residues exceed int8")
    return res.astype(jnp.int8)


def from_int8(res8):
    return res8.astype(jnp.int32)
