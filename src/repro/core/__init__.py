"""Core RNS arithmetic — the paper's contribution as a composable JAX module."""

from repro.core import dispatch
from repro.core.moduli import RnsProfile, get_profile, PROFILES, required_digits
from repro.core.rns_matmul import (
    RnsDotConfig,
    rns_dot,
    rns_dot_fwd_only,
    rns_multi_dot,
)
from repro.core.tensor import (
    RnsTensor,
    rt_add,
    rt_decode,
    rt_encode,
    rt_encode_int,
    rt_matmul,
    rt_mul,
    rt_renormalize,
)

__all__ = [
    "RnsProfile",
    "get_profile",
    "PROFILES",
    "required_digits",
    "RnsDotConfig",
    "rns_dot",
    "rns_dot_fwd_only",
    "rns_multi_dot",
    "RnsTensor",
    "rt_add",
    "rt_decode",
    "rt_encode",
    "rt_encode_int",
    "rt_matmul",
    "rt_mul",
    "rt_renormalize",
    "dispatch",
]
