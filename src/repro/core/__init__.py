"""Core RNS arithmetic — the paper's contribution as a composable JAX module."""

from repro.core.moduli import RnsProfile, get_profile, PROFILES, required_digits
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_dot_fwd_only

__all__ = [
    "RnsProfile",
    "get_profile",
    "PROFILES",
    "required_digits",
    "RnsDotConfig",
    "rns_dot",
    "rns_dot_fwd_only",
]
