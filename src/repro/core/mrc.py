"""Mixed-radix conversion (MRC), base extension, sign/compare, scaling.

These are the paper's "slow" operations: O(K) sequential digit steps,
O(K^2) digit ops total (the Rez-9's "18 clocks").  In the RNS-TPU design
they run ONCE per product summation (deferred normalization) instead of
once per multiply — the paper's central claim.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.rns import tables, rns_neg, rns_add_const

__all__ = [
    "mrc_digits",
    "is_negative",
    "is_negative_digits",
    "compare_ge_const",
    "rns_sign",
    "base_extend",
    "scale_signed",
    "decode_float",
    "decode_int32",
]


def mrc_digits(profile, res):
    """Mixed-radix digits d with X = sum_j d_j * prod_{i<j} m_i.

    Sequential in K (unrolled; K <= ~21), vectorized over trailing dims.
    """
    t = tables(profile)
    K = t.profile.n_digits
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (res.ndim - 1))
    r = res
    digits = []
    for i in range(K):
        d = r[i]
        digits.append(d)
        if i + 1 < K:
            inv = jnp.asarray(t.mrc_inv[i]).reshape(
                (-1,) + (1,) * (res.ndim - 1)
            )
            # (r - d) may be negative: remainder() keeps it in [0, m)
            r = jnp.remainder((r - d[None]) * inv, m)
    return jnp.stack(digits, axis=0)


def _lex_ge(digits, ref):
    """Vectorized lexicographic (most-significant-last) digits >= ref."""
    K = digits.shape[0]
    ge = jnp.zeros(digits.shape[1:], bool)
    eq = jnp.ones(digits.shape[1:], bool)
    for j in range(K - 1, -1, -1):
        ge = ge | (eq & (digits[j] > ref[j]))
        eq = eq & (digits[j] == ref[j])
    return ge | eq


def is_negative_digits(profile, digits):
    t = tables(profile)
    ref = [jnp.int32(int(h)) for h in t.half_digits]
    return _lex_ge(digits, ref)


def is_negative(profile, res):
    return is_negative_digits(profile, mrc_digits(profile, res))


def compare_ge_const(profile, res, c: int):
    """X_signed >= c, for |X|,|c| < M/2.  One MRC pass."""
    t = tables(profile)
    p = t.profile
    # shift both by +c so the comparison becomes a sign test of X - c
    shifted = rns_add_const(profile, res, (-int(c)) % p.M)
    return ~is_negative(profile, shifted) if c != 0 else ~is_negative(profile, res)


def rns_sign(profile, res):
    """-1 / 0 / +1 of the signed value."""
    digits = mrc_digits(profile, res)
    neg = is_negative_digits(profile, digits)
    zero = jnp.all(digits == 0, axis=0)
    return jnp.where(zero, 0, jnp.where(neg, -1, 1)).astype(jnp.int32)


def base_extend(profile, digits, n_src: int):
    """Residues (all K moduli) of X = sum_{j<n_src} d_j W_j from MRC digits."""
    t = tables(profile)
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (digits.ndim - 1))
    acc = jnp.zeros((t.profile.n_digits,) + digits.shape[1:], jnp.int32)
    for j in range(n_src):
        wj = jnp.asarray(t.ext[j]).reshape((-1,) + (1,) * (digits.ndim - 1))
        acc = jnp.remainder(acc + digits[j][None] * wj, m)
    return acc


def scale_signed(profile, res, rounded: bool = True):
    """round(X_signed / M_f) as residues — Olsen's fractional normalization.

    Two MRC passes: one for sign detection, one on the magnitude (with a
    +M_f/2 rounding bias).  The scaled magnitude is re-extended to the full
    base via the precomputed (W_j / M_f mod m_k) table.
    """
    t = tables(profile)
    p = t.profile
    f = p.frac_digits
    neg = is_negative(profile, res)
    mag = jnp.where(neg[None], rns_neg(profile, res), res)
    if rounded:
        mag = rns_add_const(profile, mag, p.M_f // 2)
    d = mrc_digits(profile, mag)
    m = jnp.asarray(t.moduli).reshape((-1,) + (1,) * (res.ndim - 1))
    acc = jnp.zeros_like(res)
    for j in range(f, p.n_digits):
        wj = jnp.asarray(t.ext_scaled[j - f]).reshape(
            (-1,) + (1,) * (res.ndim - 1)
        )
        acc = jnp.remainder(acc + d[j][None] * wj, m)
    return jnp.where(neg[None], rns_neg(profile, acc), acc)


def decode_float(profile, res, inv_scale: float = 1.0, dtype=jnp.float32):
    """Signed float reconstruction: value * inv_scale.

    Negative values are negated to their magnitude BEFORE reconstruction
    (decoding M - |X| and subtracting M would cancel catastrophically in
    f32 since M is huge).  Constants are prepared in float64 on host.
    """
    t = tables(profile)
    neg = is_negative(profile, res)
    mag = jnp.where(neg[None], rns_neg(profile, res), res)
    d = mrc_digits(profile, mag)
    w = (t.W_f64 * float(inv_scale)).astype(np.float64)
    acc = jnp.zeros(res.shape[1:], dtype)
    for j in range(t.profile.n_digits):
        acc = acc + d[j].astype(dtype) * jnp.asarray(w[j], dtype)
    return jnp.where(neg, -acc, acc)


def decode_int32(profile, res):
    """Exact int32 decode for values with |X| < 2**31 (wrap arithmetic)."""
    t = tables(profile)
    d = mrc_digits(profile, res)
    neg = is_negative_digits(profile, d)
    acc = jnp.zeros(res.shape[1:], jnp.int32)
    for j in range(t.profile.n_digits):
        acc = acc + d[j] * jnp.int32(t.W_mod32[j])  # int32 wrap == mod 2**32
    return acc - neg.astype(jnp.int32) * jnp.int32(t.M_mod32)
