"""Backend dispatch for the three RNS execution primitives.

Every residue-domain computation in the repo reduces to three primitives
(the paper's Fig. 5 blocks):

  * ``convert``   — forward conversion: fixed-point quantize + per-digit
                    modular reduction (cheap, O(K) PAC work per element).
  * ``matmul``    — digit-sliced modular matmul (the carry-free PAC array).
  * ``normalize`` — MRC normalization to signed values (the ONE slow
                    O(K^2) op; everything above defers to it).

This module is the single place that decides *which implementation* runs:
the pure-jnp reference, the compiled Pallas TPU kernels, or the Pallas
interpreter (CPU-testable).  It replaces the ``use_pallas`` / per-wrapper
``interpret`` flag plumbing that used to be scattered across
``core/rns_matmul.py`` and the four ``kernels/*/ops.py`` wrappers.

It also owns the op counters behind the deferred-normalization claim:
``count_ops()`` tallies primitive invocations at trace time, so tests and
benchmarks can assert "one normalize per chain" structurally instead of
timing it.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
import jax.numpy as jnp

__all__ = [
    "BACKENDS",
    "resolve_backend",
    "set_default_backend",
    "default_interpret",
    "OpCounts",
    "count_ops",
    "trace_op_counts",
    "convert",
    "matmul",
    "normalize",
]

#: reference        — pure jnp (works everywhere; exactness oracle)
#: pallas           — compiled Pallas TPU kernels (interpret auto on CPU)
#: pallas_interpret — Pallas kernels forced through the interpreter
BACKENDS = ("reference", "pallas", "pallas_interpret")

_state = threading.local()      # per-thread op-counter stacks
_default_backend = "auto"       # process-wide (module global)


def _default() -> str:
    return _default_backend


def set_default_backend(name: str | None):
    """Process-wide default for ``backend=None``/"auto" call sites."""
    global _default_backend
    if name is not None and name != "auto" and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    _default_backend = name or "auto"


def resolve_backend(name: str | None = None) -> str:
    """Map None/"auto" to the hardware-appropriate backend."""
    name = name or _default()
    if name == "auto":
        name = _default()
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    return name


def default_interpret() -> bool:
    """Whether a Pallas kernel should run in interpret mode by default.

    The single source of truth for the decision the four kernel wrappers
    used to each make on their own.
    """
    return jax.default_backend() == "cpu"


def _interpret_for(backend: str) -> bool | None:
    # "pallas" lets the wrapper consult default_interpret(); the forced
    # variant pins the interpreter regardless of platform.
    return True if backend == "pallas_interpret" else None


# ------------------------------------------------------------ counters ----
@dataclasses.dataclass(eq=False)  # identity semantics: counters nest
class OpCounts:
    """Primitive tallies (trace-time; one per call site reached)."""

    converts: int = 0
    matmuls: int = 0
    normalizes: int = 0

    @property
    def normalizes_per_matmul(self) -> float:
        return self.normalizes / max(self.matmuls, 1)


def _counters() -> list[OpCounts]:
    if not hasattr(_state, "counters"):
        _state.counters = []
    return _state.counters


def _tally(field: str):
    for c in _counters():
        setattr(c, field, getattr(c, field) + 1)


@contextlib.contextmanager
def count_ops():
    """Count primitive invocations (including inside jit *tracing*)."""
    c = OpCounts()
    _counters().append(c)
    try:
        yield c
    finally:
        _counters().remove(c)


def trace_op_counts(fn, *args, **kwargs) -> OpCounts:
    """Counts for one abstract evaluation of ``fn`` (no FLOPs spent)."""
    with count_ops() as c:
        jax.eval_shape(fn, *args, **kwargs)
    return c


# ---------------------------------------------------------- primitives ----
def convert(profile, x, scale, *, bits: int = 16, backend: str | None = None):
    """Quantize ``x`` by ``scale`` and encode to residues [K, ...].

    Returns int8 digit planes when the profile is int8-safe (the Pallas
    matmul kernel's operand dtype), else int32.
    """
    from repro.core.moduli import get_profile

    _tally("converts")
    be = resolve_backend(backend)
    p = get_profile(profile) if isinstance(profile, str) else profile
    if be == "reference":
        from repro.core.quantize import quantize_with_scale
        from repro.core.rns import encode_int32

        res = encode_int32(p, quantize_with_scale(x, scale, bits))
        return res.astype(jnp.int8) if p.int8_safe else res
    from repro.kernels.rns_convert.ops import rns_convert

    out_dtype = jnp.int8 if p.int8_safe else jnp.int32
    return rns_convert(p.name, x, scale, bits=bits,
                       interpret=_interpret_for(be), out_dtype=out_dtype)


def matmul(profile, a_res, b_res, *, backend: str | None = None):
    """Digit-sliced modular matmul: [K,...,M,D] @ [K,D,N] -> [K,...,M,N]."""
    _tally("matmuls")
    be = resolve_backend(backend)
    if be == "reference":
        from repro.core.rns_matmul import rns_matmul_res

        return rns_matmul_res(profile, a_res, b_res)
    from repro.kernels.rns_matmul.ops import rns_matmul

    return rns_matmul(profile, a_res, b_res, interpret=_interpret_for(be))


def normalize(profile, res, *, inv_scale: float = 1.0,
              backend: str | None = None, dtype=jnp.float32):
    """MRC-normalize residues to signed floats times ``inv_scale``.

    THE slow op (O(K^2) sequential digit steps).  ``inv_scale`` must be a
    static python float: the reference path folds it into the host-side
    float64 reconstruction weights, which keeps huge scale factors (e.g.
    M_f powers beyond float32 range) exact.  Traced scale factors must be
    multiplied in by the caller afterwards.
    """
    _tally("normalizes")
    be = resolve_backend(backend)
    # the Pallas kernel reconstructs unscaled values; scales outside the
    # float32 range (deep M_f^frac_exp deferral) would under/overflow the
    # post-multiply, so those decodes take the reference path regardless
    if be != "reference" and inv_scale != 1.0 and not (
            2.0**-126 <= abs(inv_scale) <= 2.0**127):
        be = "reference"
    if be == "reference":
        from repro.core import mrc

        return mrc.decode_float(profile, res, inv_scale=inv_scale, dtype=dtype)
    from repro.kernels.rns_normalize.ops import rns_normalize

    out = rns_normalize(profile, res, interpret=_interpret_for(be))
    if inv_scale != 1.0:
        out = out * jnp.asarray(inv_scale, out.dtype)
    return out.astype(dtype)
