"""Backend dispatch for the three RNS execution primitives.

Every residue-domain computation in the repo reduces to three primitives
(the paper's Fig. 5 blocks):

  * ``convert``   — forward conversion: fixed-point quantize + per-digit
                    modular reduction (cheap, O(K) PAC work per element).
  * ``matmul``    — digit-sliced modular matmul (the carry-free PAC array).
  * ``normalize`` — MRC normalization to signed values (the ONE slow
                    O(K^2) op; everything above defers to it).

This module is the single place that decides *which implementation* runs:
the pure-jnp reference, the compiled Pallas TPU kernels, or the Pallas
interpreter (CPU-testable).  It replaces the ``use_pallas`` / per-wrapper
``interpret`` flag plumbing that used to be scattered across
``core/rns_matmul.py`` and the four ``kernels/*/ops.py`` wrappers.

It also owns the op counters behind the deferred-normalization claim:
``count_ops()`` tallies primitive invocations at trace time, so tests and
benchmarks can assert "one normalize per chain" structurally instead of
timing it.

Fused composites (``pallas_fused`` / ``pallas_fused_interpret``): the
paper's Fig. 5 datapath is one wired pipeline, and
``fused_encode_matmul`` / ``fused_matmul_normalize`` / ``fused_dot``
run it as single Pallas kernels (kernels/rns_fused) — bit-identical to
the three-stage chain, without the [K, ..., D] residue-plane and
[K, ..., N] accumulator round-trips through HBM.  On non-fused backends
(or under a digit-sharding context, or for non-row-foldable scales) the
composites decompose into the primitives, so call sites stay uniform;
visible downgrades tally ``fallbacks``.  See docs/kernels.md.

Mesh-aware path (residue-channel sharding): when a
``distributed.sharding.use_digit_sharding`` context is installed and the
profile's digit count divides the digit mesh axis, the three primitives
route through per-device ``shard_map`` bodies instead.  Each device owns
``K / n`` moduli; ``convert`` and ``matmul`` then compile to strictly
local work — the HLO of a residue segment contains ZERO cross-device
collectives (asserted in tests/test_distributed_rns.py) because RNS
digits never exchange carries.  Digits meet exactly once, inside
``normalize``: its body all-gathers the digit axis and runs the MRC
replicated.  The sharded bodies use the reference math (fusing the Pallas
kernels into per-device digit slices needs per-slice constant tables and
is future work), so an explicit ``backend=`` still wins only off-mesh.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "BACKENDS",
    "resolve_backend",
    "set_default_backend",
    "default_interpret",
    "is_fused",
    "OpCounts",
    "count_ops",
    "trace_op_counts",
    "record_ops",
    "recording",
    "record_op",
    "annotate_digits",
    "convert",
    "matmul",
    "normalize",
    "fused_encode_matmul",
    "fused_matmul_normalize",
    "fused_dot",
]

#: reference              — pure jnp (works everywhere; exactness oracle)
#: pallas                 — compiled Pallas TPU kernels (interpret auto on CPU)
#: pallas_interpret       — Pallas kernels forced through the interpreter
#: pallas_fused           — pallas + the fused composite kernels
#:                          (kernels/rns_fused) at the fused_* call sites
#: pallas_fused_interpret — same, forced through the interpreter
BACKENDS = ("reference", "pallas", "pallas_interpret", "pallas_fused",
            "pallas_fused_interpret")

#: the per-primitive (convert/matmul/normalize) behaviour of a fused
#: backend is its unfused pallas equivalent; only the fused_* composite
#: entry points below change what actually runs.
_FUSED_TO_UNFUSED = {"pallas_fused": "pallas",
                     "pallas_fused_interpret": "pallas_interpret"}

_state = threading.local()      # per-thread op-counter stacks
_default_backend = "auto"       # process-wide (module global)


def _default() -> str:
    return _default_backend


def set_default_backend(name: str | None):
    """Process-wide default for ``backend=None``/"auto" call sites."""
    global _default_backend
    if name is not None and name != "auto" and name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    _default_backend = name or "auto"


def resolve_backend(name: str | None = None) -> str:
    """Map None/"auto" to the hardware-appropriate backend."""
    name = name or _default()
    if name == "auto":
        name = _default()
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "reference"
    if name not in BACKENDS:
        raise ValueError(f"unknown backend {name!r}; have {BACKENDS}")
    return name


def default_interpret() -> bool:
    """Whether a Pallas kernel should run in interpret mode by default.

    The single source of truth for the decision the four kernel wrappers
    used to each make on their own.
    """
    return jax.default_backend() == "cpu"


def is_fused(name: str | None = None) -> bool:
    """Whether the (resolved) backend routes composites through the
    fused kernels."""
    return resolve_backend(name) in _FUSED_TO_UNFUSED


def fusion_active(profile, backend: str | None = None) -> bool:
    """Would the composites actually launch fused kernels here?

    False under a digit-sharding context that splits this profile: the
    shard_map bodies own the layout there, so callers should keep their
    unfused structure (e.g. ``rns_multi_dot``'s shared conversion)
    instead of asking a composite that would only decompose."""
    if not is_fused(backend):
        return False
    ds, _ = _digit_ctx(profile)
    return ds is None


def _interpret_for(backend: str) -> bool | None:
    # "pallas" lets the wrapper consult default_interpret(); the forced
    # variants pin the interpreter regardless of platform.
    if backend in ("pallas_interpret", "pallas_fused_interpret"):
        return True
    return None


# ------------------------------------------------------------ counters ----
@dataclasses.dataclass(eq=False)  # identity semantics: counters nest
class OpCounts:
    """Primitive tallies (trace-time; one per call site reached).

    A fused composite tallies its constituent logical ops (a fused
    encode+matmul is still one convert and one matmul — the structural
    deferred-normalization claims stay backend-independent) PLUS one
    ``fused`` entry per composite kernel launch.  ``fallbacks`` counts
    requested-backend downgrades (e.g. a normalize whose inv_scale
    escapes float32 range), which used to masquerade as pallas ops.

    ``weight_converts`` is the subset of ``converts`` spent re-encoding
    *static weights* (call sites pass ``convert(..., weight=True)``).
    On the resident-weight path it is zero — weights are encoded once at
    build time — so "resident equals re-encode minus weight converts" is
    a structural assertion: compare ``activation_converts`` across paths.

    ``fallback_sites`` refines the ``fallbacks`` counter into a per-site
    tally keyed by ``(site, reason)`` — ``site`` is the nearest caller
    frame outside this module (``"core/tensor.py:rt_decode"``-style) —
    so the auditor and the backend matrix can assert *which* downgrades
    happened, not just how many.  The int counter is preserved and always
    equals ``sum(fallback_sites.values())``.
    """

    converts: int = 0
    matmuls: int = 0
    normalizes: int = 0
    fused: int = 0
    fallbacks: int = 0
    weight_converts: int = 0
    fallback_sites: dict = dataclasses.field(default_factory=dict)

    @property
    def normalizes_per_matmul(self) -> float:
        return self.normalizes / max(self.matmuls, 1)

    @property
    def activation_converts(self) -> int:
        return self.converts - self.weight_converts

    def add(self, other: "OpCounts", times: int = 1) -> "OpCounts":
        """New OpCounts = self + times * other (per-site tallies merged)."""
        out = OpCounts(**{f: getattr(self, f) + times * getattr(other, f)
                          for f in ("converts", "matmuls", "normalizes",
                                    "fused", "fallbacks", "weight_converts")})
        out.fallback_sites = dict(self.fallback_sites)
        for k, n in other.fallback_sites.items():
            out.fallback_sites[k] = out.fallback_sites.get(k, 0) + times * n
        return out


def _counters() -> list[OpCounts]:
    if not hasattr(_state, "counters"):
        _state.counters = []
    return _state.counters


def _tally(field: str):
    for c in _counters():
        setattr(c, field, getattr(c, field) + 1)


@contextlib.contextmanager
def count_ops():
    """Count primitive invocations (including inside jit *tracing*)."""
    c = OpCounts()
    _counters().append(c)
    try:
        yield c
    finally:
        _counters().remove(c)


def trace_op_counts(fn, *args, **kwargs) -> OpCounts:
    """Counts for one abstract evaluation of ``fn`` (no FLOPs spent)."""
    with count_ops() as c:
        jax.eval_shape(fn, *args, **kwargs)
    return c


# ----------------------------------------------------------- recorders ----
# The abstract-interpretation shim behind repro.analysis: while a recorder
# is installed (record_ops), every primitive/composite call reports the
# operand and output *objects* (tracers under jax.eval_shape) plus static
# metadata (profile, quantize bits, contraction dim, resolved backend,
# what it tallied).  Recorders link operands to producers by object
# identity and keep the objects alive so ids stay unique; ledger-level
# call sites (core/tensor.py) add tensor annotations — ground-truth
# mag_bits for digit arrays whose producer the shim cannot see (resident
# weights, dtype casts).  Recording costs nothing when no recorder is
# installed and never changes what executes.

def _recorders() -> list:
    if not hasattr(_state, "recorders"):
        _state.recorders = []
    return _state.recorders


def recording() -> bool:
    """Whether an analysis recorder is installed on this thread."""
    return bool(_recorders())


@contextlib.contextmanager
def record_ops(recorder):
    """Install ``recorder`` (``.record(...)``/``.annotate(...)`` duck
    type; see ``repro.analysis.graph.GraphRecorder``) for the dynamic
    extent, nested like ``count_ops``."""
    _recorders().append(recorder)
    try:
        yield recorder
    finally:
        _recorders().remove(recorder)


_THIS_FILE = __file__


def _call_site() -> str:
    """Nearest repro frame outside this module, plus the nearest frame
    outside ``core/`` when that differs — ``"models/layers.py:mlp ->
    core/tensor.py:rt_decode"``-style, stable across traces."""
    f = sys._getframe(1)
    inner = outer = None
    while f is not None:
        fname = f.f_code.co_filename.replace("\\", "/")
        if "/repro/" in fname and fname != _THIS_FILE:
            rel = fname.rsplit("/repro/", 1)[1]
            label = f"{rel}:{f.f_code.co_name}"
            if inner is None:
                inner = label
            if not rel.startswith("core/"):
                outer = label
                break
        f = f.f_back
    if inner is None:
        return "<external>"
    if outer is not None and outer != inner:
        return f"{outer} -> {inner}"
    return inner


def record_op(kind: str, out, ins: tuple = (), **meta):
    """Report one recorded op to the installed recorders (no-op without
    one).  Ledger-level call sites use this for ops that do not route
    through the primitives below (``rns_mul``/``rns_add``, forced
    renormalizes)."""
    rs = _recorders()
    if not rs:
        return
    site = meta.pop("site", None) or _call_site()
    for r in rs:
        r.record(kind, out, ins, site=site, **meta)


def annotate_digits(arr, **meta):
    """Attach ground-truth ledger facts (``mag_bits``, ``profile``,
    ``frac_exp``, ``role``, optional ``base`` array whose ledger state
    ``arr`` aliases) to a digit array object for the installed
    recorders."""
    rs = _recorders()
    if not rs:
        return
    for r in rs:
        r.annotate(arr, **meta)


def _tally_fallback(reason: str):
    """A visible backend downgrade: bump the counters (total + per-site)
    and report a ``fallback`` event to the recorders."""
    cs, rs = _counters(), _recorders()
    if not cs and not rs:
        return
    site = _call_site()
    for c in cs:
        c.fallbacks += 1
        key = (site, reason)
        c.fallback_sites[key] = c.fallback_sites.get(key, 0) + 1
    for r in rs:
        r.record("fallback", None, (), site=site, reason=reason,
                 tallies={"fallbacks": 1})


def _prof_name(profile) -> str:
    return profile if isinstance(profile, str) else profile.name


def _emit(kind: str, out, ins: tuple, **meta):
    rs = _recorders()
    if not rs:
        return
    site = _call_site()
    for r in rs:
        r.record(kind, out, ins, site=site, **meta)


# ------------------------------------------------- digit-sharded bodies ----
def _digit_ctx(profile):
    """The installed DigitSharding if it actually splits this profile."""
    from repro.core.moduli import get_profile
    from repro.distributed.sharding import digit_sharding

    ds = digit_sharding()
    if ds is None:
        return None, None
    p = get_profile(profile) if isinstance(profile, str) else profile
    return (ds, p) if ds.shards(p.n_digits) else (None, p)


def _moduli_arr(p) -> jax.Array:
    return jnp.asarray(np.asarray(p.moduli, np.int32))


def _jit_shard_map(f, ds, in_specs, out_specs):
    from jax.experimental.shard_map import shard_map

    mapped = shard_map(f, ds.mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=False, auto=ds.auto_axes())
    # shard_map with auto (GSPMD-managed) axes only exists under jit; the
    # wrapper keeps eager call sites working and inlines under outer jits
    return jax.jit(mapped)


# The builders below are lru_cached on their static parameters (the
# frozen DigitSharding — Mesh is hashable — and the frozen RnsProfile,
# so unregistered profile objects work exactly as on the unsharded
# paths): a fresh closure per call would defeat jit's function-identity
# cache and recompile every eager invocation.

@functools.lru_cache(maxsize=512)
def _sharded_convert_fn(ds, p, bits, xndim, sndim):
    """Forward conversion, one digit group per device, zero collectives.

    The local moduli arrive as a digit-sharded operand, so each device
    quantizes ``x`` (replicated over the digit axis — DP axes stay auto)
    and reduces by ITS moduli only.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.quantize import quantize_with_scale

    def body(xb, sb, m_local):
        q = quantize_with_scale(xb, sb, bits)
        mv = m_local.reshape((-1,) + (1,) * q.ndim)
        res = jnp.remainder(q[None], mv)
        return res.astype(jnp.int8) if p.int8_safe else res

    return _jit_shard_map(
        body, ds,
        (P(*([None] * xndim)), P(*([None] * sndim)), P(ds.axis)),
        ds.digit_spec(xndim + 1))


def _sharded_convert(p, x, scale, bits, ds):
    x = jnp.asarray(x)
    scale = jnp.asarray(scale, jnp.float32)
    fn = _sharded_convert_fn(ds, p, bits, x.ndim, scale.ndim)
    return fn(x, scale, _moduli_arr(p))


@functools.lru_cache(maxsize=512)
def _sharded_matmul_fn(ds, p, andim, bndim):
    """Digit-sliced modular matmul, each device's digit group local.

    The body is ``rns_matmul_res``'s lazy-reduction schedule
    (``core/rns_matmul.modular_matmul`` — ONE source of truth for the
    overflow-critical chunking; the bound depends only on max(moduli),
    identical for every shard) with the moduli as a sharded operand.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.rns_matmul import modular_matmul

    chunk = p.lazy_chunk

    def body(a, b, m_local):
        mv = m_local.reshape((-1,) + (1,) * (a.ndim - 1))
        return modular_matmul(a, b, mv, chunk)

    return _jit_shard_map(
        body, ds,
        (ds.digit_spec(andim), ds.digit_spec(bndim), P(ds.axis)),
        ds.digit_spec(andim))


def _sharded_matmul(p, a_res, b_res, ds):
    fn = _sharded_matmul_fn(ds, p, a_res.ndim, b_res.ndim)
    return fn(a_res, b_res, _moduli_arr(p))


@functools.lru_cache(maxsize=512)
def _sharded_normalize_fn(ds, p, ndim, inv_scale, dtype):
    """MRC normalization: THE point where digit slices communicate.

    One tiled all-gather reassembles the full ``[K, ...]`` residue tensor
    on every device, then the sequential mixed-radix conversion runs
    replicated.  This is the paper's Fig. 5 topology as collectives: the
    PAC array never talks, the normalization unit is the meeting point.
    (Scattering the MRC over batch via all-to-all is a future refinement;
    it trades the replicated O(K^2) work for divisibility constraints.)

    On a mesh with a real (size > 1) auto axis — the DP x digit
    composition — the all-gather cannot live inside shard_map: XLA's
    SPMD partitioner (0.4.x) hard-crashes on manual-subgroup collectives
    mixed with auto axes.  There the digit gather is expressed as a
    GSPMD replication constraint on the digit axis instead (other dims
    unconstrained, so the MRC itself stays data-parallel over the batch).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import mrc

    if any(ds.mesh.shape[a] > 1 for a in ds.auto_axes()):
        def gather_and_decode(r):
            full = jax.lax.with_sharding_constraint(
                r, NamedSharding(
                    ds.mesh, P(None, *([P.UNCONSTRAINED] * (ndim - 1)))))
            return mrc.decode_float(p, full, inv_scale=inv_scale,
                                    dtype=dtype)

        return jax.jit(gather_and_decode)

    def body(r):
        full = jax.lax.all_gather(r, ds.axis, axis=0, tiled=True)
        return mrc.decode_float(p, full, inv_scale=inv_scale, dtype=dtype)

    return _jit_shard_map(body, ds, ds.digit_spec(ndim),
                          P(*([None] * (ndim - 1))))


def _sharded_normalize(p, res, inv_scale, dtype, ds):
    fn = _sharded_normalize_fn(ds, p, res.ndim, float(inv_scale),
                               jnp.dtype(dtype))
    return fn(res)


# ---------------------------------------------------------- primitives ----
def convert(profile, x, scale, *, bits: int = 16, backend: str | None = None,
            weight: bool = False):
    """Quantize ``x`` by ``scale`` and encode to residues [K, ...].

    Returns int8 digit planes when the profile is int8-safe (the Pallas
    matmul kernel's operand dtype), else int32.  ``weight=True`` marks
    the conversion of a static weight operand (tally bookkeeping only —
    the computation is identical); the resident-weight path eliminates
    exactly these.
    """
    from repro.core.moduli import get_profile

    _tally("converts")
    if weight:
        _tally("weight_converts")
    be = resolve_backend(backend)
    be = _FUSED_TO_UNFUSED.get(be, be)
    ds, p = _digit_ctx(profile)
    if p is None:
        p = get_profile(profile) if isinstance(profile, str) else profile
    if ds is not None:
        out = _sharded_convert(p, x, scale, bits, ds)
    elif be == "reference":
        # per-sequence grids (mask-aware absmax, non-scalar scales) run
        # through the Pallas kernel too since the scale became a streamed
        # operand — the old silent reference fallback is gone
        from repro.core.quantize import quantize_with_scale
        from repro.core.rns import encode_int32

        res = encode_int32(p, quantize_with_scale(x, scale, bits))
        out = res.astype(jnp.int8) if p.int8_safe else res
    else:
        from repro.kernels.rns_convert.ops import rns_convert

        out_dtype = jnp.int8 if p.int8_safe else jnp.int32
        out = rns_convert(p.name, x, scale, bits=bits,
                          interpret=_interpret_for(be), out_dtype=out_dtype)
    _emit("convert", out, (x,), profile=p.name, bits=bits, weight=weight,
          backend=be, sharded=ds is not None,
          tallies={"converts": 1, "weight_converts": int(weight)})
    return out


def matmul(profile, a_res, b_res, *, backend: str | None = None):
    """Digit-sliced modular matmul: [K,...,M,D] @ [K,D,N] -> [K,...,M,N]."""
    _tally("matmuls")
    be = resolve_backend(backend)
    be = _FUSED_TO_UNFUSED.get(be, be)
    ds, p = _digit_ctx(profile)
    if ds is not None:
        out = _sharded_matmul(p, a_res, b_res, ds)
    elif be == "reference":
        from repro.core.rns_matmul import rns_matmul_res

        out = rns_matmul_res(profile, a_res, b_res)
    else:
        from repro.kernels.rns_matmul.ops import rns_matmul

        out = rns_matmul(profile, a_res, b_res, interpret=_interpret_for(be))
    _emit("matmul", out, (a_res, b_res), profile=_prof_name(profile),
          contract_dim=int(jnp.shape(a_res)[-1]), backend=be,
          sharded=ds is not None, tallies={"matmuls": 1})
    return out


def normalize(profile, res, *, inv_scale: float = 1.0,
              backend: str | None = None, dtype=jnp.float32):
    """MRC-normalize residues to signed floats times ``inv_scale``.

    THE slow op (O(K^2) sequential digit steps).  ``inv_scale`` must be a
    static python float: the reference path folds it into the host-side
    float64 reconstruction weights, which keeps huge scale factors (e.g.
    M_f powers beyond float32 range) exact.  Traced scale factors must be
    multiplied in by the caller afterwards.
    """
    _tally("normalizes")
    be = resolve_backend(backend)
    be = _FUSED_TO_UNFUSED.get(be, be)
    ds, p = _digit_ctx(profile)
    if ds is not None:
        out = _sharded_normalize(p, res, inv_scale, dtype, ds)
    else:
        # the Pallas kernel reconstructs unscaled values; scales outside
        # the float32 range (deep M_f^frac_exp deferral) would under/
        # overflow the post-multiply, so those decodes take the reference
        # path — visibly (the fallback counter), not masquerading as a
        # pallas op
        if be != "reference" and not _inv_scale_in_f32(inv_scale):
            _tally_fallback("inv_scale outside float32 range")
            be = "reference"
        if be == "reference":
            from repro.core import mrc

            out = mrc.decode_float(profile, res, inv_scale=inv_scale,
                                   dtype=dtype)
        else:
            from repro.kernels.rns_normalize.ops import rns_normalize

            out = rns_normalize(profile, res, interpret=_interpret_for(be))
            if inv_scale != 1.0:
                out = out * jnp.asarray(inv_scale, out.dtype)
            out = out.astype(dtype)
    _emit("normalize", out, (res,), profile=_prof_name(profile), backend=be,
          sharded=ds is not None, tallies={"normalizes": 1})
    return out


# ------------------------------------------------- fused composites ----
def _inv_scale_in_f32(inv_scale: float) -> bool:
    return inv_scale == 1.0 or (2.0**-126 <= abs(inv_scale) <= 2.0**127)


def _fused_scale_ok(x, scale) -> bool:
    """Fused kernels take at most one scale per activation ROW: a scalar,
    or a keepdims shape with a broadcast last dim (per-sequence grids)."""
    if jnp.ndim(scale) == 0:
        return True
    xs, ss = jnp.shape(x), jnp.shape(scale)
    return (len(ss) == len(xs) and ss[-1] == 1
            and all(a in (1, b) for a, b in zip(ss, xs)))


def _get_p(profile):
    from repro.core.moduli import get_profile

    return get_profile(profile) if isinstance(profile, str) else profile


def fused_encode_matmul(profile, x, scale, w_res, *, bits: int = 16,
                        backend: str | None = None):
    """Forward conversion fused into the digit matmul.

    ``x [..., D]`` floats + ``w_res [K, D, N]`` weight residues ->
    ``[K, ..., N]`` residues; the activation residues never materialize
    in HBM.  Tallies one convert + one matmul (the logical ops are still
    performed) plus one ``fused``.  Decomposes into the separate
    primitives when the backend is not fused, when a digit-sharding
    context routes through shard_map, or when the scale is not row-
    foldable — the latter downgrades count as ``fallbacks``.
    """
    be = resolve_backend(backend)
    ds, p = _digit_ctx(profile)
    if p is None:
        p = _get_p(profile)
    fuse = ds is None and be in _FUSED_TO_UNFUSED
    if fuse and not _fused_scale_ok(x, scale):
        _tally_fallback("non-row-foldable scale")
        fuse = False
    if not fuse:
        ub = _FUSED_TO_UNFUSED.get(be, be)
        res = convert(p, x, scale, bits=bits, backend=ub)
        return matmul(p, res, w_res, backend=ub)
    _tally("converts")
    _tally("matmuls")
    _tally("fused")
    from repro.kernels.rns_fused.ops import rns_fused_encode_matmul

    out = rns_fused_encode_matmul(p, x, scale, w_res, bits=bits,
                                  interpret=_interpret_for(be))
    _emit("fused_encode_matmul", out, (x, w_res), profile=p.name, bits=bits,
          contract_dim=int(jnp.shape(x)[-1]), backend=be,
          tallies={"converts": 1, "matmuls": 1, "fused": 1})
    return out


def fused_matmul_normalize(profile, a_res, b_res, *, inv_scale: float = 1.0,
                           backend: str | None = None, dtype=jnp.float32):
    """Digit matmul fused with THE MRC normalization.

    ``a_res [K, ..., D]`` @ ``b_res [K, D, N]`` -> ``[..., N]`` floats
    times ``inv_scale``; the [K, ..., N] int32 accumulator never reaches
    HBM.  Tallies one matmul + one normalize plus one ``fused``.
    """
    be = resolve_backend(backend)
    ds, p = _digit_ctx(profile)
    if p is None:
        p = _get_p(profile)
    fuse = ds is None and be in _FUSED_TO_UNFUSED
    # an out-of-range inv_scale decomposes WITHOUT tallying a fallback
    # here: normalize() itself records the visible downgrade
    fuse = fuse and _inv_scale_in_f32(inv_scale)
    if not fuse:
        ub = _FUSED_TO_UNFUSED.get(be, be)
        res = matmul(p, a_res, b_res, backend=ub)
        return normalize(p, res, inv_scale=inv_scale, backend=ub, dtype=dtype)
    _tally("matmuls")
    _tally("normalizes")
    _tally("fused")
    from repro.kernels.rns_fused.ops import rns_fused_matmul_normalize

    out = rns_fused_matmul_normalize(p, a_res, b_res,
                                     interpret=_interpret_for(be))
    if inv_scale != 1.0:
        out = out * jnp.asarray(inv_scale, out.dtype)
    out = out.astype(dtype)
    _emit("fused_matmul_normalize", out, (a_res, b_res), profile=p.name,
          contract_dim=int(jnp.shape(a_res)[-1]), backend=be,
          tallies={"matmuls": 1, "normalizes": 1, "fused": 1})
    return out


def fused_dot(profile, x, scale, w_res, *, bits: int = 16,
              inv_scale: float = 1.0, backend: str | None = None,
              dtype=jnp.float32, shared_encode: bool = False):
    """The whole Fig. 5 pipeline in one kernel: encode -> digit matmul ->
    MRC normalize.  Floats in, floats out (times ``inv_scale``); residues
    only ever exist in VMEM.  Tallies convert + matmul + normalize plus
    one ``fused``.

    ``shared_encode``: the activation's forward conversion is logically
    shared with a previous composite over the same ``x`` in this
    expression (``rns_multi_dot``'s one-conversion-per-block contract) —
    the kernel still re-quantizes in VMEM (free vs HBM), but the
    structural ``converts`` tally stays backend-independent."""
    be = resolve_backend(backend)
    ds, p = _digit_ctx(profile)
    if p is None:
        p = _get_p(profile)
    fuse = ds is None and be in _FUSED_TO_UNFUSED
    if fuse and not _fused_scale_ok(x, scale):
        _tally_fallback("non-row-foldable scale")
        fuse = False
    fuse = fuse and _inv_scale_in_f32(inv_scale)   # normalize() tallies
    if not fuse:
        ub = _FUSED_TO_UNFUSED.get(be, be)
        res = convert(p, x, scale, bits=bits, backend=ub)
        out = matmul(p, res, w_res, backend=ub)
        return normalize(p, out, inv_scale=inv_scale, backend=ub, dtype=dtype)
    if not shared_encode:
        _tally("converts")
    _tally("matmuls")
    _tally("normalizes")
    _tally("fused")
    from repro.kernels.rns_fused.ops import rns_fused_dot

    out = rns_fused_dot(p, x, scale, w_res, bits=bits,
                        interpret=_interpret_for(be))
    if inv_scale != 1.0:
        out = out * jnp.asarray(inv_scale, out.dtype)
    out = out.astype(dtype)
    _emit("fused_dot", out, (x, w_res), profile=p.name, bits=bits,
          contract_dim=int(jnp.shape(x)[-1]), backend=be,
          shared_encode=shared_encode,
          tallies={"converts": 0 if shared_encode else 1, "matmuls": 1,
                   "normalizes": 1, "fused": 1})
    return out
