"""Gradient compression for the slow cross-pod hop: int8 + error feedback.

At 512+ chips the intra-pod ICI all-reduce is cheap; the pod-to-pod (DCI)
hop dominates.  Quantizing that hop 4x (f32->int8) with error-feedback
keeps convergence (the residual is re-injected next step, so the scheme is
unbiased in the long run — standard EF-SGD result).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """x f32 -> (int8 values, scale).  Symmetric per-tensor."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, error_state):
    """Error-feedback compression over a gradient pytree.

    Returns (quantized tree as (q, scale) pairs, new error_state).  The
    caller all-reduces the int8 payload across pods, then decompresses.
    """
    if error_state is None:
        error_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                   grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress_int8(corrected)
        new_e = corrected - decompress_int8(q, s)
        return (q, s), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = tdef.unflatten([p[0] for p in pairs])
    etree = tdef.unflatten([p[1] for p in pairs])
    return qtree, etree


def decompress_tree(qtree):
    return jax.tree.map(
        lambda pair: decompress_int8(*pair), qtree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and not isinstance(x[0], dict),
    )
