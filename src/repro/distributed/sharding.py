"""Logical-axis -> mesh sharding rules (DP / FSDP / TP / EP / SP / digits).

Params carry logical axis names (see models/*.init_*); this module resolves
them against the production mesh:

  batch           -> ("pod","data")   data parallelism (pod = outer DP dim)
  embed           -> "data"           FSDP / ZeRO-3: d_model param dims
  mlp/heads/kv_heads/vocab/expert -> "model"   Megatron TP + expert parallel
  lora            -> "model", falling back to "data" on conflict
  digit           -> "model"          RNS residue channels (paper Fig. 5)

Resolution is SHAPE-AWARE: jit input shardings must divide dimensions
evenly, so a candidate axis is skipped when the dim isn't divisible (e.g.
granite's vocab=49155 or whisper's 51865 fall back to replicated heads of
the LM matrix, sharding the d_model dim instead), and within one param each
mesh axis is used at most once within one param (and lora ranks are never
sharded at all — they are contraction dims; §Perf deepseek iter 4).

KV caches get their own policy: batch -> DP axes when it fills them,
otherwise (long-context, batch=1) the SEQUENCE dim is sharded and partial
attention is LSE-combined (distributed flash-decoding); KV-head counts that
don't divide the model axis also fall back to sequence sharding.

Residue channels get their own policy too (:class:`DigitSharding`,
installed with :class:`use_digit_sharding`): the leading ``[K, ...]``
digit axis of every residue tensor is partitioned over the ``model`` mesh
axis.  RNS digits are carry-free and mutually independent — the paper's
central claim — so each device owns ``K / n_model`` moduli and runs the
convert/matmul/defer segments with ZERO cross-device communication; digits
meet only inside MRC normalization (``core/dispatch.normalize`` gathers
them once).  ``core/dispatch.py`` consults the installed context at trace
time and routes the three primitives through per-device ``shard_map``
bodies.
"""

from __future__ import annotations

import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# candidate mesh axes per logical axis name, in preference order
RULES: dict[str | None, tuple[str, ...]] = {
    "batch": ("data",),
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model", "data"),
    "expert": ("model",),
    # lora ranks (MLA compression dims) are never sharded: they are the
    # contraction dim of every up-projection, and sharding a contraction
    # dim turns each MLA matmul into a full-output all-reduce (§Perf,
    # deepseek iter 4 — this single rule was worth 3.7 TiB/step/device)
    "lora": (),
    # leading [K, ...] residue-channel axis of encoded RNS tensors: one
    # group of moduli per device (digit-axis sharding; see DigitSharding)
    "digit": ("model",),
    "embed_vec": (),
    "expert_vec": (),
    "layers": (),
    None: (),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def spec_for_axes(axes, shape, mesh: Mesh) -> P:
    """Resolve one param's logical axes tuple to a PartitionSpec."""
    used: set[str] = set()
    out = []
    for name, dim in zip(axes, shape):
        cands = RULES.get(name, ())
        pick = None
        for c in cands:
            if (c in mesh.axis_names and c not in used
                    and dim % mesh.shape[c] == 0 and dim >= mesh.shape[c]):
                pick = c
                break
        if pick:
            used.add(pick)
        out.append(pick)
    return P(*out)


def tree_shardings(spec_tree, shapes_tree, mesh: Mesh):
    """Map trees of (logical axes, ShapeDtypeStruct) to NamedShardings."""
    is_axes = lambda x: isinstance(x, tuple)
    return jax.tree.map(
        lambda axes, s: NamedSharding(mesh, spec_for_axes(axes, s.shape, mesh)),
        spec_tree, shapes_tree, is_leaf=is_axes,
    )


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    return _axis_size(mesh, dp_axes(mesh))


def batch_spec(mesh: Mesh) -> P:
    return P(dp_axes(mesh), None)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ------------------------------------------------ activation constraints --
# GSPMD occasionally trades batch sharding for contraction-dim sharding
# (catastrophic for memory); explicit constraints pin the layouts we mean.
# The mesh is installed for the duration of a lowering; when unset, every
# constrain() is a no-op so tests and single-device runs are untouched.
_ACT_MESH: Mesh | None = None


class use_activation_sharding:
    def __init__(self, mesh: Mesh | None):
        self.mesh = mesh

    def __enter__(self):
        global _ACT_MESH
        self._prev = _ACT_MESH
        _ACT_MESH = self.mesh
        return self

    def __exit__(self, *exc):
        global _ACT_MESH
        _ACT_MESH = self._prev
        return False


def constrain(x, logical: tuple):
    """Constrain an activation: entries are 'batch', 'model', None."""
    mesh = _ACT_MESH
    if mesh is None:
        return x
    spec = []
    for name, dim in zip(logical, x.shape):
        if name == "batch":
            axes = dp_axes(mesh)
            n = _axis_size(mesh, axes)
            spec.append(axes if n > 1 and dim % n == 0 and dim >= n else None)
        elif name == "batch_all":
            # batch over the ENTIRE mesh (attention data-parallelism: makes
            # per-head math local when head counts can't split the model
            # axis; falls back to plain DP when the batch is too small)
            axes = dp_axes(mesh) + ("model",)
            n = _axis_size(mesh, axes)
            if n > 1 and dim % n == 0 and dim >= n:
                spec.append(axes)
            else:
                dp = dp_axes(mesh)
                nd = _axis_size(mesh, dp)
                spec.append(dp if nd > 1 and dim % nd == 0 and dim >= nd
                            else None)
        elif name == "model":
            n = mesh.shape.get("model", 1)
            spec.append("model" if n > 1 and dim % n == 0 and dim >= n else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


# ------------------------------------------------- digit-axis (RNS) rules --
@dataclasses.dataclass(frozen=True)
class DigitSharding:
    """Residue-channel layout: digit axis of ``[K, ...]`` tensors -> mesh.

    ``axis`` is the mesh axis owning digit slices (one group of moduli per
    device — the paper's "one digit slice per compute unit", Fig. 5).  All
    OTHER mesh axes are left to GSPMD (``shard_map`` ``auto`` axes), so
    digit sharding composes with data parallelism: a ``("data", "model")``
    mesh runs DP over ``data`` and residue channels over ``model``.
    """

    mesh: Mesh
    axis: str = "model"

    @property
    def n_shards(self) -> int:
        return int(self.mesh.shape[self.axis])

    def shards(self, n_digits: int) -> bool:
        """Whether a K-digit profile splits evenly over the digit axis."""
        return n_digits % self.n_shards == 0

    def auto_axes(self) -> frozenset:
        return frozenset(a for a in self.mesh.axis_names if a != self.axis)

    def digit_spec(self, ndim: int, axis_pos: int = 0) -> P:
        """PartitionSpec of a residue tensor (shard_map spec: manual on
        the digit axis, replicated-per-shard elsewhere).  ``axis_pos`` is
        where the K digit axis sits: 0 for the plain ``[K, ...]`` layout,
        1 for period-major stacked resident weights (``[P, K, ...]`` —
        scan-sliceable, see core/tensor.rt_stack)."""
        spec = [None] * ndim
        spec[axis_pos] = self.axis
        return P(*spec)

    def digit_sharding(self, ndim: int, axis_pos: int = 0) -> NamedSharding:
        """NamedSharding for placing a ``[K, ...]`` residue tensor."""
        return NamedSharding(self.mesh, self.digit_spec(ndim, axis_pos))


# per-thread, like core/quantize's token-mask stack: two engines traced
# from different host threads (one sharded, one not) must not see each
# other's context — a cross-thread leak would bake the wrong layout into
# a jit cache permanently
_digit_state = threading.local()


class use_digit_sharding:
    """Install the digit-axis layout for the duration of a trace/lowering.

    ``mesh=None`` is a no-op (single-device runs and tests untouched) —
    the same pattern as :class:`use_activation_sharding`.  Contexts nest;
    the innermost wins.
    """

    def __init__(self, mesh: Mesh | None, axis: str = "model"):
        self.ds = DigitSharding(mesh, axis) if mesh is not None else None

    def __enter__(self):
        self._prev = getattr(_digit_state, "ds", None)
        if self.ds is not None:
            _digit_state.ds = self.ds
        return self.ds

    def __exit__(self, *exc):
        _digit_state.ds = self._prev
        return False


def digit_sharding() -> DigitSharding | None:
    """The installed residue-channel layout, or None."""
    return getattr(_digit_state, "ds", None)


def first_valid_spec(shape, candidates, mesh: Mesh) -> P:
    """First candidate PartitionSpec where every sharded dim divides."""
    for spec in candidates:
        ok = True
        for dim, axis in zip(shape, tuple(spec) + (None,) * len(shape)):
            n = _axis_size(mesh, axis)
            if n > 1 and (dim % n != 0 or dim < n):
                ok = False
                break
        if ok:
            return spec
    return P(*([None] * len(shape)))
