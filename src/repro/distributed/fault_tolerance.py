"""Fleet fault-tolerance: stragglers, heartbeats, preemption, elasticity.

The mechanisms are transport-agnostic (file- or callback-based) so the same
logic drives a 1000-host fleet (each host writes heartbeats to shared
storage / a KV service) and the single-process simulation in tests.
"""

from __future__ import annotations

import dataclasses
import json
import os
import signal
import statistics
import time


# ------------------------------------------------------------ stragglers ---
@dataclasses.dataclass
class StragglerMonitor:
    """Flags hosts whose step time exceeds tau x median of the fleet."""

    tau: float = 1.5
    window: int = 16
    _times: dict[str, list[float]] = dataclasses.field(default_factory=dict)

    def report(self, host: str, step_seconds: float):
        buf = self._times.setdefault(host, [])
        buf.append(step_seconds)
        if len(buf) > self.window:
            buf.pop(0)

    def medians(self) -> dict[str, float]:
        return {h: statistics.median(v) for h, v in self._times.items() if v}

    def stragglers(self) -> list[str]:
        meds = self.medians()
        if len(meds) < 2:
            return []
        fleet = statistics.median(meds.values())
        return [h for h, m in meds.items() if m > self.tau * fleet]

    def mitigation_plan(self) -> dict:
        """What the launcher should do: checkpoint-evict-restart semantics."""
        bad = self.stragglers()
        return {
            "stragglers": bad,
            "action": "checkpoint_and_evict" if bad else "none",
            "healthy": [h for h in self._times if h not in bad],
        }


# ------------------------------------------------------------ heartbeats ---
class Heartbeat:
    """File-based heartbeat (stand-in for a cluster KV service)."""

    def __init__(self, root: str, host: str, interval_s: float = 5.0):
        self.path = os.path.join(root, f"hb_{host}.json")
        self.host = host
        self.interval_s = interval_s
        os.makedirs(root, exist_ok=True)

    def beat(self, step: int, now: float | None = None):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host, "step": step,
                       "t": now if now is not None else time.time()}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def dead_hosts(root: str, timeout_s: float, now: float | None = None):
        now = now if now is not None else time.time()
        dead = []
        for f in os.listdir(root):
            if not f.startswith("hb_"):
                continue
            with open(os.path.join(root, f)) as fh:
                rec = json.load(fh)
            if now - rec["t"] > timeout_s:
                dead.append(rec["host"])
        return sorted(dead)


# ------------------------------------------------------------ preemption ---
class PreemptionHandler:
    """SIGTERM -> request a final checkpoint and a clean exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # non-main thread (tests)

    def _on_signal(self, signum, frame):
        self.requested = True

    def trigger_for_test(self):
        self.requested = True


# -------------------------------------------------------------- elastic ----
def plan_remesh(n_healthy_chips: int, *, model_parallel: int = 16,
                min_data: int = 1) -> tuple[int, int]:
    """Largest (data, model) grid on the surviving chips.

    Keeps the model axis fixed (TP degree is a property of the compiled
    program / weight layout) and shrinks the data axis — the standard
    elastic-DP policy.  Returns (data, model).
    """
    data = max(min_data, n_healthy_chips // model_parallel)
    return data, model_parallel
