"""The 10 assigned architectures (exact public configs) + reduced smoke twins.

Sources are cited per the assignment sheet; every full config is exercised
by the multi-pod dry-run, every smoke twin by tests/test_archs_smoke.py.
"""

from __future__ import annotations

from repro.configs.base import MLAConfig, ModelConfig, register
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


# ----------------------------------------------------------- dense LMs -----
def _llama_like(arch_id, family, L, d, H, Hk, dff, vocab, *, d_head=None,
                qkv_bias=False, tie=False, **kw):
    return ModelConfig(
        arch_id=arch_id, family=family, n_layers=L, d_model=d, n_heads=H,
        n_kv_heads=Hk, d_head=d_head or d // H, d_ff=dff, vocab=vocab,
        qkv_bias=qkv_bias, tie_embeddings=tie, **kw)


def qwen25_32b():
    # [hf:Qwen/Qwen2.5-32B-style scaling; QKV bias per Qwen2 family]
    return _llama_like("qwen2.5-32b", "dense", 64, 5120, 40, 8, 27648,
                       152064, d_head=128, qkv_bias=True,
                       rope_theta=1_000_000.0, attn_batch_shard=True,
                       grad_accum=4)


def smollm_135m():
    # [hf:HuggingFaceTB/SmolLM-135M]
    return _llama_like("smollm-135m", "dense", 30, 576, 9, 3, 1536, 49152,
                       d_head=64, tie=True, attn_batch_shard=True)


def tinyllama_11b():
    # [arXiv:2401.02385]
    return _llama_like("tinyllama-1.1b", "dense", 22, 2048, 32, 4, 5632,
                       32000, d_head=64, attn_batch_shard=True, grad_accum=2)


def granite_3_8b():
    # [hf:ibm-granite/granite-3.0 family]
    return _llama_like("granite-3-8b", "dense", 40, 4096, 32, 8, 12800,
                       49155, d_head=128, rope_theta=10_000_000.0,
                       attn_batch_shard=True, grad_accum=4)


# ------------------------------------------------------------- whisper -----
def whisper_medium():
    # [arXiv:2212.04356] enc-dec, 24+24 layers, conv frontend stubbed:
    # input_specs feeds precomputed 1500-frame embeddings at d_model.
    return ModelConfig(
        arch_id="whisper-medium", family="audio", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=16, d_head=64, d_ff=4096, vocab=51865,
        qkv_bias=True, pos_emb="sinusoidal", norm="layernorm", act="gelu",
        gated_mlp=False, enc_dec=True, n_enc_layers=24, frontend="audio",
        n_frontend_tokens=1500, grad_accum=2)


# ----------------------------------------------------------- paligemma -----
def paligemma_3b():
    # [arXiv:2407.07726] SigLIP stub (256 patch embeddings) + gemma backbone
    return ModelConfig(
        arch_id="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
        n_heads=8, n_kv_heads=1, d_head=256, d_ff=16384, vocab=257216,
        act="gelu_tanh", tie_embeddings=True, emb_scale=True,
        frontend="vision", n_frontend_tokens=256, attn_batch_shard=True,
        grad_accum=2)


# ----------------------------------------------------------------- MoE -----
def llama4_scout():
    # [hf:meta-llama/Llama-4-Scout-17B-16E] 16 experts top-1 + shared expert
    L = 48
    return ModelConfig(
        arch_id="llama4-scout-17b-a16e", family="moe", n_layers=L,
        d_model=5120, n_heads=40, n_kv_heads=8, d_head=128, d_ff=8192,
        vocab=202048, rope_theta=500_000.0,
        mlp_types=("moe",) * L, attn_batch_shard=True, grad_accum=8,
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192, n_shared=1))


def deepseek_v2_236b():
    # [arXiv:2405.04434] MLA kv_lora=512; 2 shared + 160 routed top-6
    L = 60
    return ModelConfig(
        arch_id="deepseek-v2-236b", family="moe", n_layers=L, d_model=5120,
        n_heads=128, n_kv_heads=128, d_head=128, d_ff=1536, vocab=102400,
        layer_types=("mla",) * L, mlp_types=("moe",) * L,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                      qk_rope_dim=64, v_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2),
        grad_accum=8)


# -------------------------------------------------------------- hybrid -----
def jamba_52b():
    # [arXiv:2403.19887] attn:mamba 1:7 (attn @ offset 4, period 8);
    # MoE every 2 layers (offset 1), 16 experts top-2.
    L = 32
    layer_types = tuple(
        "attn" if i % 8 == 4 else "mamba" for i in range(L))
    mlp_types = tuple("moe" if i % 2 == 1 else "dense" for i in range(L))
    return ModelConfig(
        arch_id="jamba-v0.1-52b", family="hybrid", n_layers=L, d_model=4096,
        n_heads=32, n_kv_heads=8, d_head=128, d_ff=14336, vocab=65536,
        layer_types=layer_types, mlp_types=mlp_types, pos_emb="none",
        ssm=SSMConfig(kind="mamba", d_state=16, d_conv=4, expand=2),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336),
        sub_quadratic=True, attn_batch_shard=True, grad_accum=8)


# ----------------------------------------------------------------- SSM -----
def rwkv6_7b():
    # [arXiv:2404.05892] Finch: data-dependent decay, attn-free
    L = 32
    return ModelConfig(
        arch_id="rwkv6-7b", family="ssm", n_layers=L, d_model=4096,
        n_heads=64, n_kv_heads=64, d_head=64, d_ff=14336, vocab=65536,
        layer_types=("rwkv",) * L, mlp_types=("channelmix",) * L,
        pos_emb="none", norm="layernorm",
        ssm=SSMConfig(kind="rwkv6", head_dim=64, impl="chunked", chunk=64),
        sub_quadratic=True, grad_accum=4)


# ------------------------------------------------------------ smoke twins --
def _smoke_of(full: ModelConfig, **over) -> ModelConfig:
    import dataclasses

    base = dict(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab=256, n_frontend_tokens=(
            8 if full.n_frontend_tokens else 0),
        n_enc_layers=2 if full.enc_dec else 0,
        param_dtype="float32", remat="none",
    )
    base.update(over)
    L = base["n_layers"]
    if full.layer_types and len(set(full.layer_types)) == 1:
        base.setdefault("layer_types", (full.layer_types[0],) * L)
    if full.mlp_types and len(set(full.mlp_types)) == 1:
        base.setdefault("mlp_types", (full.mlp_types[0],) * L)
    if full.moe:
        # capacity_factor = E/k: dropless, so decode == full forward exactly
        # (capacity dropping is non-causal by construction; the full configs
        # keep the paper-standard 1.25 for training throughput)
        base.setdefault("moe", MoEConfig(
            n_experts=4, top_k=min(2, full.moe.top_k),
            d_ff_expert=base["d_ff"], n_shared=min(1, full.moe.n_shared),
            capacity_factor=8.0))
    if full.mla:
        base.setdefault("mla", MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=16, qk_rope_dim=8,
            v_dim=16))
    if full.ssm:
        base.setdefault("ssm", SSMConfig(
            kind=full.ssm.kind, d_state=4, d_conv=4, expand=2, head_dim=16,
            chunk=32))
    keep = dict(
        arch_id=full.arch_id, family=full.family,
        qkv_bias=full.qkv_bias, pos_emb=full.pos_emb, norm=full.norm,
        act=full.act, gated_mlp=full.gated_mlp,
        tie_embeddings=full.tie_embeddings, emb_scale=full.emb_scale,
        enc_dec=full.enc_dec, frontend=full.frontend,
        sub_quadratic=full.sub_quadratic, rope_theta=full.rope_theta,
    )
    keep.update(base)
    return ModelConfig(**keep)


def _smoke_jamba():
    L = 8
    return _smoke_of(
        jamba_52b(), n_layers=L,
        layer_types=tuple("attn" if i % 4 == 2 else "mamba" for i in range(L)),
        mlp_types=tuple("moe" if i % 2 == 1 else "dense" for i in range(L)),
        n_heads=4, n_kv_heads=2)


def _smoke_rwkv():
    return _smoke_of(rwkv6_7b(), n_heads=4, n_kv_heads=4, d_head=16)


ALL = {
    "whisper-medium": (whisper_medium, lambda: _smoke_of(whisper_medium())),
    "jamba-v0.1-52b": (jamba_52b, _smoke_jamba),
    "qwen2.5-32b": (qwen25_32b, lambda: _smoke_of(qwen25_32b())),
    "smollm-135m": (smollm_135m, lambda: _smoke_of(smollm_135m())),
    "tinyllama-1.1b": (tinyllama_11b, lambda: _smoke_of(tinyllama_11b())),
    "granite-3-8b": (granite_3_8b, lambda: _smoke_of(granite_3_8b())),
    "paligemma-3b": (paligemma_3b, lambda: _smoke_of(
        paligemma_3b(), n_kv_heads=1)),
    "rwkv6-7b": (rwkv6_7b, _smoke_rwkv),
    "llama4-scout-17b-a16e": (llama4_scout, lambda: _smoke_of(llama4_scout())),
    "deepseek-v2-236b": (deepseek_v2_236b, lambda: _smoke_of(
        deepseek_v2_236b(), layer_types=("mla",) * 4)),
}

for _aid, (_full, _smoke) in ALL.items():
    register(_aid, _full, _smoke)
