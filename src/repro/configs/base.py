"""Model / shape configuration dataclasses + the architecture registry."""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

from repro.core.rns_matmul import RnsDotConfig
from repro.models.moe import MoEConfig
from repro.models.ssm import SSMConfig


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense|moe|ssm|hybrid|encdec|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # per-layer programs (len == n_layers)
    layer_types: tuple[str, ...] = ()      # attn|mla|mamba|rwkv
    mlp_types: tuple[str, ...] = ()        # dense|moe|channelmix|none
    # options
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    pos_emb: str = "rope"                  # rope|sinusoidal|none
    norm: str = "rmsnorm"
    act: str = "silu"
    gated_mlp: bool = True
    causal: bool = True
    tie_embeddings: bool = False
    emb_scale: bool = False                # gemma: embeddings * sqrt(d)
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # encoder-decoder
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_causal: bool = False
    # modality frontend stub (precomputed embeddings fed via input_specs)
    frontend: str | None = None            # audio|vision|None
    n_frontend_tokens: int = 0
    # numerics / paper technique
    rns: RnsDotConfig | None = None
    rns_targets: str = "mlp"               # mlp|attn|all
    param_dtype: str = "float32"
    remat: str = "full"                    # none|full
    grad_accum: int = 1                    # microbatches per optimizer step
    # attention execution
    attn_dense_max: int = 1024             # dense/one-shot path below this Tq
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    # sliding-window attention: query at position q attends keys in
    # [q - attn_window + 1, q] (None = full causal).  Positions stay
    # absolute; older keys are masked with exact zeros, so serving can
    # evict their KV pages without moving the retained window's math.
    attn_window: int | None = None
    # sharding hints
    attn_shard_heads: bool = True          # heads -> model axis (GSPMD pads)
    attn_batch_shard: bool = False         # attention DP over the full mesh
    sub_quadratic: bool = False            # eligible for long_500k

    def __post_init__(self):
        if not self.layer_types:
            object.__setattr__(self, "layer_types", ("attn",) * self.n_layers)
        if not self.mlp_types:
            object.__setattr__(self, "mlp_types", ("dense",) * self.n_layers)
        assert len(self.layer_types) == self.n_layers
        assert len(self.mlp_types) == self.n_layers

    @property
    def period(self) -> int:
        """Smallest p with a periodic (layer, mlp) program; scan length = L/p."""
        L = self.n_layers
        prog = list(zip(self.layer_types, self.mlp_types))
        for p in range(1, L + 1):
            if L % p == 0 and all(
                prog[i] == prog[i % p] for i in range(L)
            ):
                return p
        return L

    def params_count(self) -> int:
        """Total parameters (exact from shapes; used for MODEL_FLOPS)."""
        from repro.models.model import count_params_analytic

        return count_params_analytic(self)

    def active_params_count(self) -> int:
        from repro.models.model import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train|prefill|decode
    sub_quadratic_only: bool = False


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode", True),
    # reduced shapes for smoke tests / CI
    "train_tiny": ShapeConfig("train_tiny", 128, 4, "train"),
    "prefill_tiny": ShapeConfig("prefill_tiny", 128, 2, "prefill"),
    "decode_tiny": ShapeConfig("decode_tiny", 128, 4, "decode"),
}


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def get_config(arch_id: str, *, smoke: bool = False) -> ModelConfig:
    import repro.configs.all_archs  # noqa: F401  (populates registry)

    reg = _SMOKE if smoke else _REGISTRY
    if arch_id not in reg:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return reg[arch_id]()


def list_archs() -> list[str]:
    import repro.configs.all_archs  # noqa: F401

    return sorted(_REGISTRY)


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, else the skip reason."""
    if shape.sub_quadratic_only and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.arch_id} is full-attention (see DESIGN.md §6)"
        )
    return True, ""
