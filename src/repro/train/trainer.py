"""Training loop with checkpoint/restart, stragglers, preemption.

Single-host execution here; the fault-tolerance hooks are the same objects
a multi-host launcher would drive (see distributed/fault_tolerance.py).
"""

from __future__ import annotations

import dataclasses
import logging
import time

import jax
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.data.pipeline import DataConfig, SyntheticLM, make_frontend_stub
from repro.distributed.fault_tolerance import (
    Heartbeat,
    PreemptionHandler,
    StragglerMonitor,
)
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import init_train_state, make_train_step

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    async_ckpt: bool = True
    keep_last: int = 3


class Trainer:
    def __init__(self, model_cfg, opt_cfg: AdamWConfig, tcfg: TrainerConfig,
                 data_cfg: DataConfig, host: str = "host0"):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.data = SyntheticLM(data_cfg)
        self.step_fn = jax.jit(make_train_step(model_cfg, opt_cfg),
                               donate_argnums=(0,))
        self.straggler = StragglerMonitor()
        self.preempt = PreemptionHandler(install=False)
        self.heartbeat = Heartbeat(tcfg.ckpt_dir + "/hb", host)
        self.host = host
        self._rng = np.random.default_rng(tcfg.seed + 17)
        self._pending_save = None

    # ------------------------------------------------------------ state ---
    def init_or_resume(self):
        latest = ckpt.latest_valid(self.tcfg.ckpt_dir)
        state, _ = init_train_state(
            jax.random.PRNGKey(self.tcfg.seed), self.model_cfg)
        if latest is None:
            return state, 0
        state, extra, step = ckpt.restore(latest, state)
        log.info("resumed from %s (step %d)", latest, step)
        return state, step

    def _batch(self, step):
        b = {k: jax.numpy.asarray(v) for k, v in self.data.batch(step).items()}
        cfg = self.model_cfg
        if cfg.frontend is not None:
            rng = np.random.default_rng((self.tcfg.seed, step, 99))
            b["frontend"] = jax.numpy.asarray(make_frontend_stub(
                rng, self.data.local_batch, cfg.n_frontend_tokens,
                cfg.d_model))
        return b

    def _save(self, state, step):
        if self._pending_save is not None:
            self._pending_save.result()  # backpressure: one in flight
        if self.tcfg.async_ckpt:
            self._pending_save = ckpt.save_async(
                self.tcfg.ckpt_dir, step, state, {"host": self.host})
        else:
            ckpt.save(self.tcfg.ckpt_dir, step, state, {"host": self.host})

    # ------------------------------------------------------------- loop ---
    def run(self, max_steps: int | None = None):
        state, start = self.init_or_resume()
        history = []
        end = min(self.tcfg.total_steps,
                  start + (max_steps or self.tcfg.total_steps))
        for step in range(start, end):
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, self._batch(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.report(self.host, dt)
            self.heartbeat.beat(step)
            history.append(loss)
            if step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.1f ms)", step, loss, dt * 1e3)
            if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == end:
                self._save(state, step + 1)
            if self.preempt.requested:
                log.warning("preemption requested: checkpointing and exiting")
                self._save(state, step + 1)
                break
        if self._pending_save is not None:
            self._pending_save.result()
        return state, history
