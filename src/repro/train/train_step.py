"""The jitted train step: loss -> grads -> AdamW, donation-friendly."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.models import model as M
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def measure_rns_ops(cfg, batch) -> dispatch.OpCounts:
    """Structural RNS primitive counts for one loss evaluation.

    Trace-time only (eval_shape — no FLOPs).  ``normalizes_per_matmul`` is
    the amortization figure of merit: 1.0 on the per-op path, < 1.0 once
    the residue-domain chains (``cfg.rns.defer``, shared conversions) are
    doing their job.  Logged by benchmarks/CI against BENCH_*.json.
    """
    params = jax.eval_shape(lambda k: M.init_model(k, cfg)[0],
                            jax.random.PRNGKey(0))
    return dispatch.trace_op_counts(
        lambda p, b: M.loss_fn(p, cfg, b), params, batch)


def init_train_state(key, cfg):
    params, specs = M.init_model(key, cfg)
    state = {"params": params, "opt": init_opt_state(params)}
    state_specs = {
        "params": specs,
        "opt": {"m": specs, "v": specs, "step": ()},
    }
    return state, state_specs


def make_train_step(cfg, opt_cfg: AdamWConfig, *, compress_dci: bool = False,
                    resident_weights: bool = False):
    """compress_dci: int8+error-feedback quantization of the gradients that
    cross the slow pod-to-pod hop (distributed/compression.py).  The
    residual re-enters next step, so the long-run update is unbiased; state
    gains an "ef" tree when enabled.

    resident_weights: run the forward on resident residue-domain MLP
    weights (models/resident.attach_resident).  The attach happens INSIDE
    the grad closure over the float masters, so the differentiated tree
    stays all-float: the optimizer updates masters, the custom_vjp
    straight-through backward reads masters, and the integer digits are a
    forward-only recompute each step (under jit the encode is hoisted and
    shared across the whole forward — the step still performs one encode
    per weight, but never one per matmul)."""
    accum = max(1, getattr(cfg, "grad_accum", 1))

    def loss_of(p, batch):
        if resident_weights:
            from repro.models.resident import attach_resident

            p = attach_resident(p, cfg)
        return M.loss_fn(p, cfg, batch)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: loss_of(p, batch), has_aux=True)(params)
        return loss, parts, grads

    def train_step(state, batch):
        if accum == 1:
            loss, parts, grads = grads_of(state["params"], batch)
        else:
            # microbatching: bound activation residency (the per-chip HBM
            # fit knob); grads accumulate in f32, sharded like the params
            mb = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def micro(carry, mbatch):
                gacc, lacc, aacc = carry
                loss, parts, g = grads_of(state["params"], mbatch)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + loss, aacc + parts["aux"]), None

            (gsum, lsum, asum), _ = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32),
                        jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(
                lambda g, p: (g / accum).astype(p.dtype), gsum,
                state["params"])
            loss = lsum / accum
            parts = {"ce": loss - asum / accum, "aux": asum / accum}
        new_state = {}
        if compress_dci:
            from repro.distributed.compression import (
                decompress_tree,
                ef_compress_tree,
            )

            qtree, ef = ef_compress_tree(grads, state.get("ef"))
            grads = jax.tree.map(
                lambda g, d: d.astype(g.dtype), grads, decompress_tree(qtree))
            new_state["ef"] = ef
        new_params, new_opt, om = adamw_update(
            opt_cfg, grads, state["opt"], state["params"])
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   **om}
        return {"params": new_params, "opt": new_opt, **new_state}, metrics

    return train_step


def make_eval_step(cfg):
    def eval_step(params, batch):
        loss, parts = M.loss_fn(params, cfg, batch)
        return {"loss": loss, **parts}

    return eval_step


# ------------------------------------------------------ mesh composition ---
def make_dp_train_step(cfg, opt_cfg: AdamWConfig, mesh, *,
                       compress_dci: bool = False, digit_shard: bool = True,
                       resident_weights: bool = False):
    """Data-parallel train step composed with a digit-sharded forward.

    Two orthogonal parallelisms on one mesh:

    * **batch** is sharded over the DP axes (``pod``/``data``); GSPMD
      inserts the gradient all-reduce — standard data parallelism.
    * **residue channels** are sharded over the ``model`` axis
      (``digit_shard=True`` and ``cfg.rns`` set): every RNS
      convert/matmul in the forward (and the RNS backward matmuls, when
      ``cfg.rns.backward_rns``) runs as per-device digit groups with zero
      collectives; only MRC normalizations gather digits.  When the
      profile's digit count doesn't divide the axis, the layout silently
      stays replicated — same numerics, no sharding.

    The returned callable has the same (state, batch) -> (state, metrics)
    contract as :func:`make_train_step`; losses match the single-device
    step to float tolerance (reduction order differs across devices).
    Host numpy batches are placed with the batch sharding before the call.
    """
    import contextlib

    from jax.sharding import NamedSharding

    from repro.distributed import sharding as SH

    base = make_train_step(cfg, opt_cfg, compress_dci=compress_dci,
                           resident_weights=resident_weights)
    jitted = jax.jit(base, donate_argnums=(0,))
    bspec = NamedSharding(mesh, SH.batch_spec(mesh))

    def step(state, batch):
        batch = jax.device_put(
            batch, jax.tree.map(lambda _: bspec, batch))
        dctx = (SH.use_digit_sharding(mesh)
                if digit_shard and cfg.rns is not None
                else contextlib.nullcontext())
        with dctx, SH.use_activation_sharding(mesh):
            return jitted(state, batch)

    return step
