"""Abstract input specs + shardings for every (arch x shape x mesh) cell.

ShapeDtypeStruct stand-ins only — nothing is allocated; ``jit(...).lower``
consumes these directly (the shannon/kernels pattern).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.models import model as M
from repro.train.train_step import init_train_state


def abstract_train_state(cfg: ModelConfig):
    """(state ShapeDtypeStructs, state logical-axis specs) — no allocation."""
    box = {}

    def f(key):
        state, specs = init_train_state(key, cfg)
        box["specs"] = specs
        return state

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def abstract_params(cfg: ModelConfig):
    box = {}

    def f(key):
        p, s = M.init_model(key, cfg)
        box["specs"] = s
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box["specs"]


def state_shardings(cfg, mesh):
    shapes, specs = abstract_train_state(cfg)
    return shd.tree_shardings(specs, shapes, mesh)


def param_shardings(cfg, mesh):
    shapes, specs = abstract_params(cfg)
    return shd.tree_shardings(specs, shapes, mesh)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Training/prefill batch ShapeDtypeStructs with shardings."""
    B, S = shape.global_batch, shape.seq_len
    dp = shd.dp_axes(mesh)
    bspec = shd.first_valid_spec((B, S), [P(dp, None)], mesh)
    out = {
        "tokens": jax.ShapeDtypeStruct(
            (B, S), jnp.int32, sharding=NamedSharding(mesh, bspec)),
    }
    if cfg.frontend is not None:
        fspec = shd.first_valid_spec(
            (B, cfg.n_frontend_tokens, cfg.d_model),
            [P(dp, None, None)], mesh)
        out["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, fspec))
    return out


def _cache_sharding_tree(cfg, shape, mesh, cache_shapes):
    """Walk the abstract cache; candidate specs per leaf role.

    Order of preference encodes the parallelism policy:
      1. batch -> DP axes (+ heads/latent-seq -> model)
      2. batch too small: sequence -> DP axes (flash-decoding), heads -> model
      3. sequence -> (DP+model) jointly when heads can't split
    """
    dp = shd.dp_axes(mesh)

    def pick(leaf_shape, name):
        nd = len(leaf_shape)
        if name in ("k", "v"):          # [np?, B, S, Hk, D] (cross: no np)
            lead = (None,) * (nd - 4)
            cands = [
                P(*lead, dp, None, "model", None),
                P(*lead, dp, "model", None, None),
                P(*lead, None, dp, "model", None),
                P(*lead, None, dp + ("model",), None, None),
                P(*lead, None, dp, None, None),
            ]
        elif name in ("c_kv", "k_rope"):  # [np, B, S, r]
            cands = [
                P(None, dp, "model", None),
                P(None, None, dp + ("model",), None),
                P(None, None, dp, None),
            ]
        elif name == "lengths":           # [np, B] or [B]
            lead = (None,) * (nd - 1)
            cands = [P(*lead, dp), P(*lead, None)]
        elif name == "S":                 # rwkv [np, B, H, D, D]
            cands = [
                P(None, dp, "model", None, None),
                P(None, None, "model", None, None),
            ]
        elif name == "h":                 # mamba [np, B, d_in, N]
            cands = [
                P(None, dp, "model", None),
                P(None, None, "model", None),
            ]
        elif name == "conv":              # [np, B, K-1, d_in]
            cands = [
                P(None, dp, None, "model"),
                P(None, None, None, "model"),
            ]
        elif name in ("x_tm", "x_cm"):    # [np, B, 1, d]
            cands = [P(None, dp, None, None)]
        else:
            cands = []
        return NamedSharding(mesh, shd.first_valid_spec(leaf_shape, cands, mesh))

    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        return pick(tree.shape, path[-1])

    return walk(cache_shapes, ())


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """(token, cache) ShapeDtypeStructs + shardings for serve_step lowering.

    Cache is sized at shape.seq_len with lengths = seq_len - 1: "one new
    token against a KV cache of seq_len".
    """
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        functools.partial(M.make_cache, cfg, B, S, dtype=jnp.bfloat16),
        lengths=jax.ShapeDtypeStruct((B,), jnp.int32))
    cache_sh = _cache_sharding_tree(cfg, shape, mesh, cache_shapes)
    cache = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cache_sh)
    tok_spec = shd.first_valid_spec((B, 1), [P(shd.dp_axes(mesh), None)], mesh)
    token = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec))
    return token, cache, cache_sh


def with_shape_overrides(cfg: ModelConfig, *, dryrun: bool = True,
                         rns: bool = False) -> ModelConfig:
    """Full-config execution settings: bf16 params, full remat (+RNS path)."""
    over = {}
    if dryrun:
        over["param_dtype"] = "bfloat16"
        over["remat"] = "full"
    if rns:
        from repro.core.rns_matmul import RnsDotConfig

        over["rns"] = RnsDotConfig(profile="rns9", qx=16, qw=16,
                                   backward_rns=True)
        over["rns_targets"] = "mlp"
    return dataclasses.replace(cfg, **over)
