"""Mesh factories: production, digit-sharded, and virtual-CPU testing.

FUNCTIONS (not module constants) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before any jax init, tests keep 1
device.
"""

from __future__ import annotations

import os

import jax

#: XLA flag that splits the host CPU into N virtual devices — the only
#: way to exercise real GSPMD partitioning / shard_map collectives
#: without accelerators.  MUST be set before jax initializes a backend
#: (use a subprocess: tests/test_distributed_rns.py, benchmarks/
#: bench_dist.py), which is why this is a string helper, not a setter.
VIRTUAL_CPU_FLAG = "--xla_force_host_platform_device_count={n}"


def virtual_cpu_env(n: int, base: dict | None = None) -> dict:
    """Environment for a subprocess with ``n`` virtual CPU devices."""
    env = dict(base if base is not None else os.environ)
    env["XLA_FLAGS"] = VIRTUAL_CPU_FLAG.format(n=n)
    env["JAX_PLATFORMS"] = "cpu"
    return env


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_digit_mesh(n_model: int | None = None, *, n_data: int = 1):
    """``("data", "model")`` mesh for residue-channel sharding.

    The ``model`` axis carries RNS digit groups (size it to divide the
    profile's digit count: 8 devices x rns16 -> 2 digits/device, the
    paper's one-slice-per-unit layout as a mesh axis); ``data`` carries
    batch rows.  ``n_model=None`` uses every device not consumed by
    ``n_data``.  Works on 1 device too (1x1 mesh — shard_map still runs,
    partitioning is a no-op), so programs are mesh-agnostic.
    """
    n_dev = jax.device_count()
    if n_model is None:
        if n_dev % n_data:
            raise ValueError(f"{n_dev} devices not divisible by "
                             f"n_data={n_data}")
        n_model = n_dev // n_data
    if n_data * n_model > n_dev:
        raise ValueError(
            f"mesh ({n_data}, {n_model}) needs {n_data * n_model} devices, "
            f"have {n_dev} (CPU testing: set XLA_FLAGS="
            f"{VIRTUAL_CPU_FLAG.format(n=n_data * n_model)} before jax "
            "initializes)")
    return jax.make_mesh((n_data, n_model), ("data", "model"))
