"""Production mesh factory.

A FUNCTION (not a module constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before any jax init, tests keep 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    return jax.make_mesh(shape, axes)
