"""End-to-end training launcher.

CPU-runnable demo (smoke configs) and the production entry (full configs
on a real TPU fleet — same code path, bigger mesh):

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --steps 200 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --smoke \
        --rns --steps 50          # train THROUGH the RNS digit-sliced matmul
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

from repro.configs.base import get_config
from repro.core.rns_matmul import RnsDotConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--rns", action="store_true",
                    help="route MLP matmuls through the RNS datapath")
    ap.add_argument("--rns-profile", default="rns9")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.rns:
        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile=args.rns_profile, qx=16, qw=16),
            rns_targets="mlp")
    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
    )
    state, history = trainer.run()
    print(f"final loss: {history[-1]:.4f} (from {history[0]:.4f})")


if __name__ == "__main__":
    main()
