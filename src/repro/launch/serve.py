"""Batched serving launcher (smoke-scale on CPU; same engine at fleet scale).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --batch 8 --prompt-len 32 --new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    engine = Engine(params, cfg, ServeConfig(
        max_cache=args.prompt_len + args.new + 8, max_new_tokens=args.new))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    frontend = None
    if cfg.frontend:
        frontend = rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    out = engine.generate(prompts.astype(np.int32), frontend=frontend)
    dt = time.perf_counter() - t0
    n_tok = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    t0 = time.perf_counter()
    out = engine.generate(prompts.astype(np.int32), frontend=frontend)
    dt = time.perf_counter() - t0
    print(f"warm: {n_tok/dt:.1f} tok/s")
    print("sample:", out[0][:16])


if __name__ == "__main__":
    main()
