"""Serving launcher (smoke-scale on CPU; same engines at fleet scale).

Bucketed (equal-length batch, legacy):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --batch 8 --prompt-len 32 --new 16

Continuous batching over the paged KV cache (mixed-length traffic):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --continuous --requests 12 --prompt-lens 7,33,120 --new 16

Residue-domain MLP datapath with resident (encode-once) weights:

    PYTHONPATH=src python -m repro.launch.serve --continuous --rns rns9 \
        --resident-weights --per-layer-profiles --requests 4 --new 8

Chunked prefill (packed mixed-phase steps, no prefill/decode barrier):

    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --chunked-prefill --token-budget 64 --requests 8 --new 8

Sliding-window attention with cyclic KV page reuse (long streams in a
page pool far smaller than the stream):

    PYTHONPATH=src python -m repro.launch.serve --continuous \
        --window-tokens 32 --requests 2 --prompt-lens 10 --new 96
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


def _digit_mesh(args):
    if not args.digit_shard:
        return None
    from repro.launch.mesh import make_digit_mesh

    mesh = make_digit_mesh()            # all local devices on "model"
    print(f"digit sharding over {mesh.shape['model']} device(s) "
          "(residue channels; see docs/distributed.md)")
    return mesh


def _bucketed(args, cfg, params):
    engine = Engine(params, cfg, ServeConfig(
        max_cache=args.prompt_len + args.new + 8, max_new_tokens=args.new,
        rns_backend=args.rns_backend, mesh=_digit_mesh(args),
        resident_weights=args.resident_weights,
        per_layer_profiles=args.per_layer_profiles))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    frontend = None
    if cfg.frontend:
        frontend = rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    out = engine.generate(prompts.astype(np.int32), frontend=frontend)
    dt = time.perf_counter() - t0
    n_tok = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({n_tok/dt:.1f} tok/s incl. compile)")
    t0 = time.perf_counter()
    out = engine.generate(prompts.astype(np.int32), frontend=frontend)
    dt = time.perf_counter() - t0
    print(f"warm: {n_tok/dt:.1f} tok/s")
    print("sample:", out[0][:16])


def _continuous(args, cfg, params):
    lens = [int(x) for x in args.prompt_lens.split(",")]
    max_cache = max(lens) + args.new + 8
    engine = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=max_cache, max_new_tokens=args.new,
        page_size=args.page_size, max_seqs=args.max_seqs,
        n_pages=args.n_pages, rns_backend=args.rns_backend,
        prefix_cache=args.prefix_cache, spec_decode=args.spec_decode,
        spec_k=args.spec_k, mesh=_digit_mesh(args),
        resident_weights=args.resident_weights,
        per_layer_profiles=args.per_layer_profiles,
        chunked_prefill=args.chunked_prefill,
        token_budget=args.token_budget, chunk_size=args.chunk_size,
        window_tokens=args.window_tokens))
    if args.resident_weights:
        from repro.models.resident import resident_profiles

        profs = sorted(set(resident_profiles(engine.params).values()))
        print(f"resident weights: encoded once at build "
              f"(profiles {profs or ['-']})")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (lens[i % len(lens)],)).astype(
        np.int32) for i in range(args.requests)]
    res, stats = engine.run(prompts)
    print(f"served {stats['n_requests']} mixed-length requests "
          f"(lens {sorted(set(lens))}) in {stats['n_steps']} steps / "
          f"{stats['wall_s']:.2f}s -> {stats['tokens_per_s']:.1f} tok/s")
    print(f"latency p50={stats['latency_p50_s']:.3f}s "
          f"p99={stats['latency_p99_s']:.3f}s  "
          f"page util (mean)={stats['mean_page_utilization']:.2f}  "
          f"preemptions={stats['n_preemptions']}")
    if args.spec_decode:
        print(f"speculative: tokens/step={stats['tokens_per_step']:.2f} "
              f"acceptance={stats['acceptance_rate']:.2f} "
              f"(window {engine.spec_window})")
    if args.prefix_cache:
        print(f"prefix cache: hit_tokens={stats['cache_hit_tokens']} "
              f"pages_shared={stats['pages_shared']} "
              f"pages_allocated={stats['pages_allocated']} "
              f"cow_splits={stats['cow_splits']}")
    if args.window_tokens:
        print(f"sliding window: {args.window_tokens} tokens retained per "
              f"row, pages_window_evicted={stats['pages_window_evicted']}")
    if args.chunked_prefill:
        mixed = sum(1 for s in stats["steps"]
                    if s["prefill_tokens"] > 0 and s["decode_tokens"] > 0)
        print(f"chunked prefill: budget={engine.scfg.token_budget} lanes "
              f"ttft p50={stats['ttft_p50_s']:.3f}s "
              f"p95={stats['ttft_p95_s']:.3f}s  mixed steps={mixed}")
        print(f"compiles: mixed={engine._mixed._cache_size()} "
              f"(per-mix recompiles: 0)")
    else:
        decode_jit = engine._verify if args.spec_decode else engine._decode
        print(f"compiles: prefill={engine._prefill._cache_size()} "
              f"{'verify' if args.spec_decode else 'decode'}="
              f"{decode_jit._cache_size()} (per-length recompiles: 0)")
    print("sample:", res[0][:16])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over the paged KV cache")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-lens", default="7,33,120",
                    help="comma list; requests cycle through these lengths")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-seqs", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=None)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="copy-on-write prefix caching: sequences sharing "
                         "a prompt prefix share physical KV pages "
                         "(continuous engine only)")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative (n-gram prompt-lookup) decoding "
                         "through one jitted [R, k+1] verify step "
                         "(continuous engine only; tokens stay identical "
                         "to vanilla decode)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="draft tokens per speculative step")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="packed mixed-phase batching: prefill chunks and "
                         "decode rows share ONE jitted step over a fixed "
                         "token budget (continuous engine only; tokens "
                         "stay identical to whole-prompt prefill)")
    ap.add_argument("--token-budget", type=int, default=64,
                    help="packed lanes per mixed step (--chunked-prefill)")
    ap.add_argument("--chunk-size", type=int, default=None,
                    help="max prefill tokens per row per mixed step; must "
                         "be a multiple of --page-size")
    ap.add_argument("--window-tokens", type=int, default=None,
                    help="sliding-window attention: each row attends at "
                         "most this many trailing tokens and the scheduler "
                         "recycles KV pages behind the window (continuous "
                         "engine only; bounded page-pool occupancy for "
                         "arbitrarily long streams)")
    ap.add_argument("--rns", metavar="PROFILE", default=None,
                    help="run the MLP datapath in residues on PROFILE "
                         "(e.g. rns9); required for --rns-backend/"
                         "--resident-weights to have any effect")
    ap.add_argument("--rns-backend", default=None,
                    help="RNS execution backend override for either engine "
                         "(reference|pallas|pallas_fused|...; pallas_fused "
                         "runs the fused encode->matmul->normalize kernels)")
    ap.add_argument("--resident-weights", action="store_true",
                    help="encode RNS MLP weights once at engine build "
                         "(resident residue-domain weights: zero per-step "
                         "weight conversions, token-identical output)")
    ap.add_argument("--per-layer-profiles", action="store_true",
                    help="with --resident-weights: narrow layers encode "
                         "on fewer/smaller moduli (ledger-proved exact)")
    ap.add_argument("--digit-shard", action="store_true",
                    help="shard RNS residue channels over all local "
                         "devices (either engine; needs an RNS arch "
                         "whose digit count divides the device count)")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if args.rns:
        from repro.core.rns_matmul import RnsDotConfig

        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile=args.rns, qx=8, qw=8),
            rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    if args.continuous:
        _continuous(args, cfg, params)
    else:
        _bucketed(args, cfg, params)


if __name__ == "__main__":
    main()
