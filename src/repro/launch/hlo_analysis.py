"""Collective-traffic extraction from post-SPMD HLO text.

``compiled.as_text()`` shapes are PER-DEVICE (post-partitioning), which is
exactly the per-chip wire traffic basis the roofline needs.  cost_analysis
does not report collective bytes, so we parse the ops ourselves.

Wire-byte model per op (ring algorithms, n-1/n ~ 1):
  all-reduce          2x bytes (reduce-scatter + all-gather phases)
  all-gather          1x result bytes
  reduce-scatter      1x operand bytes
  all-to-all          1x bytes
  collective-permute  1x bytes
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-type counts and wire bytes (per device) from HLO text."""
    out: dict[str, dict] = {}
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op, _start = m.group(1), m.group(2), m.group(3)
        raw = _shape_bytes(shape_str)
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += raw
        rec["wire_bytes"] += raw * _MULT[op]
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    return out
