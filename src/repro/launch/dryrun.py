import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above executes before
any other import so jax sees 512 placeholder devices).

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: one JSON per cell under artifacts/dryrun/ with
memory_analysis, cost_analysis, and per-collective wire bytes — the
roofline inputs (EXPERIMENTS.md §Dry-run / §Roofline).
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (  # noqa: E402
    SHAPES,
    cell_is_runnable,
    get_config,
    list_archs,
)
from repro.launch import specs as SP  # noqa: E402
from repro.launch.analyze import collective_stats  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402


def lower_cell(cfg, shape, mesh):
    """Returns (lowered, compiled, meta) for one cell.

    All shardings are explicit NamedShardings (they carry the mesh), so no
    ambient mesh context is required.
    """
    from repro.distributed import sharding as shd

    with shd.use_activation_sharding(mesh):
        return _lower_cell_inner(cfg, shape, mesh, shd)


def _lower_cell_inner(cfg, shape, mesh, shd):
    t0 = time.perf_counter()
    if shape.kind == "train":
        state_shapes, state_specs = SP.abstract_train_state(cfg)
        state_sh = shd.tree_shardings(state_specs, state_shapes, mesh)
        state_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            state_shapes, state_sh)
        batch = SP.batch_specs(cfg, shape, mesh)
        step = make_train_step(cfg, AdamWConfig())
        lowered = jax.jit(
            step,
            in_shardings=(state_sh, jax.tree.map(lambda x: x.sharding, batch)),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        ).lower(state_abs, batch)
    elif shape.kind == "prefill":
        params_shapes, pspecs = SP.abstract_params(cfg)
        psh = shd.tree_shardings(pspecs, params_shapes, mesh)
        params_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            params_shapes, psh)
        batch = SP.batch_specs(cfg, shape, mesh)

        # the cache must also hold the frontend prefix (vlm early fusion)
        s_max = shape.seq_len + (
            cfg.n_frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0)

        def prefill_step(params, batch):
            return M.prefill(params, cfg, batch, S_max=s_max,
                             cache_dtype=jnp.bfloat16)

        lowered = jax.jit(
            prefill_step,
            in_shardings=(psh, jax.tree.map(lambda x: x.sharding, batch)),
        ).lower(params_abs, batch)
    else:  # decode
        params_shapes, pspecs = SP.abstract_params(cfg)
        psh = shd.tree_shardings(pspecs, params_shapes, mesh)
        params_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            params_shapes, psh)
        token, cache, cache_sh = SP.decode_specs(cfg, shape, mesh)

        def serve_step(params, token, cache):
            return M.decode_step(params, cfg, token, cache)

        lowered = jax.jit(
            serve_step,
            in_shardings=(psh, token.sharding, cache_sh),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,),
        ).lower(params_abs, token, cache)
    lower_s = time.perf_counter() - t0
    t1 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t1
    return lowered, compiled, {"lower_s": lower_s, "compile_s": compile_s}


def analyze(cfg, shape, mesh_name, compiled, meta):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, list) else cost
    hlo_text = compiled.as_text()
    # trip-count-aware per-device costs (XLA's cost_analysis counts while
    # bodies once; see launch/hlo_cost.py)
    from repro.launch.hlo_cost import analyze_hlo

    deep = analyze_hlo(hlo_text)
    coll = collective_stats(hlo_text)  # entry-graph view (kept for reference)
    total, active = M.count_params(cfg)
    n_dev = {"single": 256, "multi": 512}[mesh_name]
    rec = {
        "arch": cfg.arch_id,
        "shape": shape.name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "rns": cfg.rns is not None,
        "params_total": total,
        "params_active": active,
        "flops_per_device": float(deep["flops"]),
        "vflops_per_device": float(deep["vflops"]),
        "bytes_per_device": float(deep["hbm_bytes"]),
        "hbm_write_bytes": float(deep["hbm_write_bytes"]),
        "collectives": {
            **deep["collectives"],
            "total_wire_bytes": deep["total_wire_bytes"],
        },
        "xla_entry_flops": float(cost.get("flops", 0.0)),
        "entry_collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", 0),
        },
        **meta,
    }
    return rec


def run_cell(arch, shape_name, mesh_name, outdir, *, rns=False, force=False):
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__rns" if rns else "")
    path = os.path.join(outdir, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {tag}")
        return json.load(open(path))
    cfg = SP.with_shape_overrides(get_config(arch), rns=rns)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "skipped": why}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {tag}: {why}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    print(f"[lower+compile] {tag} ...", flush=True)
    try:
        lowered, compiled, meta = lower_cell(cfg, shape, mesh)
        rec = analyze(cfg, shape, mesh_name, compiled, meta)
        # keep the per-device HLO for recompile-free re-analysis (§Perf)
        import gzip

        with gzip.open(os.path.join(outdir, tag + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
        mem = rec["memory"]
        print(
            f"  ok: {meta['lower_s']:.1f}s lower, {meta['compile_s']:.1f}s "
            f"compile; args {mem['argument_bytes']/2**30:.2f} GiB/dev, "
            f"temp {mem['temp_bytes']/2**30:.2f} GiB/dev, "
            f"flops/dev {rec['flops_per_device']:.3e}, "
            f"wire {rec['collectives'].get('total_wire_bytes', 0)/2**30:.3f} GiB/dev",
            flush=True)
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        print(f"  FAIL: {type(e).__name__}: {e}", flush=True)
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--rns", action="store_true",
                    help="enable the RNS matmul datapath (paper technique)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = (
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        if (args.all or args.shape is None) else [args.shape]
    )
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_name, args.out,
                               rns=args.rns, force=args.force)
                if "error" in rec:
                    n_fail += 1
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
