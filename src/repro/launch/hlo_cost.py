"""Trip-count-aware cost model over post-optimization HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts scan-over-layers / scan-over-chunks graphs by the trip count
(verified in tests/test_hlo_cost.py).  This walker parses the HLO module,
builds the call graph (while/fusion/call/conditional), extracts static trip
counts from loop conditions, and accumulates:

  * flops       — 2 * prod(result) * K for every dot (MXU work)
  * vflops      — 1 per output element of non-dot compute ops (VPU floor)
  * hbm_bytes   — sum of (operand + result) bytes of top-level ops in each
                  computation: the post-fusion HBM traffic model (each
                  fusion reads its operands once, writes its result once)
  * collectives — wire bytes per op type (all-reduce 2x, others 1x),
                  multiplied through enclosing loops

Shapes in ``compiled.as_text()`` are post-SPMD per-device shapes, so all
numbers are per-device — exactly the roofline basis.
"""

from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_COLL_MULT = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# an op line: %name = TYPE opcode(args...), attrs.  Tuple types may contain
# /*index=N*/ comments (hence no [^=] tricks) but never nested parens.
_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(\(.*?\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->")


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    elems = bytes_ = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dtype]
    return elems, bytes_


class _Op:
    __slots__ = ("name", "shape", "opcode", "rest", "line")

    def __init__(self, name, shape, opcode, rest, line):
        self.name, self.shape, self.opcode = name, shape, opcode
        self.rest, self.line = rest, line


def _parse_computations(text: str) -> tuple[dict[str, list[_Op]], str | None]:
    comps: dict[str, list[_Op]] = {}
    cur = None
    entry = None
    for line in text.splitlines():
        if not line.startswith(" ") and ("->" in line) and line.rstrip().endswith("{"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            comps[cur].append(_Op(m.group(1), m.group(2), m.group(3),
                                  m.group(4), line))
    return comps, entry


def _operand_names(rest: str) -> list[str]:
    # take args up to matching close paren of the op's '('
    depth, out, i = 1, [], 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
        i += 1
    arglist = rest[: i - 1]
    # newer XLA prints typed operands ("f32[256,256]{1,0} %name"); strip
    # the shape annotations so the dtype token is not mistaken for a name
    arglist = re.sub(r"[\w\-]+\[[\d,]*\](?:\{[^}]*\})?", " ", arglist)
    return re.findall(r"%?([\w.\-]+)", arglist)


def _dot_flops(op: _Op, shapes: dict[str, str]) -> float:
    # contraction size K from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    ops = _operand_names(op.rest)
    if not m or not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0], "")
    dims_m = _SHAPE_RE.search(lhs_shape)
    if not dims_m:
        return 0.0
    dims = [int(d) for d in dims_m.group(2).split(",") if d]
    K = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(dims):
            K *= dims[i]
    out_elems, _ = _shape_elems_bytes(op.shape)
    return 2.0 * out_elems * K


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy-start", "copy-done", "after-all", "partition-id", "replica-id",
}


def _trip_count(cond_ops: list[_Op]) -> int:
    """Static trip count from a scan-style while condition.

    Scan lowers to ``while (iv < constant(N))``; the compare may be inside a
    wrapped fusion, so we take the largest integer constant in the condition
    computation (the loop bound dominates any other constant there).
    """
    best = 1
    for op in cond_ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> dict:
    comps, entry_name = _parse_computations(text)
    shapes: dict[str, str] = {}
    for ops in comps.values():
        for op in ops:
            shapes[op.name] = op.shape

    import functools

    @functools.lru_cache(maxsize=None)
    def comp_cost(comp_name: str) -> tuple:
        flops = vflops = hbm = hbm_w = 0.0
        coll: dict[str, float] = {}
        coll_counts: dict[str, int] = {}
        for op in comps.get(comp_name, []):
            oc = op.opcode
            out_elems, out_bytes = _shape_elems_bytes(op.shape)
            # ---- nested computations
            if oc == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.line)
                cond = re.search(r"condition=%?([\w.\-]+)", op.line)
                n = _trip_count(comps.get(cond.group(1), [])) if cond else 1
                bf, bv, bh, bw, bc, bcc = comp_cost(body.group(1)) if body else (
                    0, 0, 0, 0, {}, {})
                flops += n * bf
                vflops += n * bv
                hbm += n * bh
                hbm_w += n * bw
                for k, v in bc.items():
                    coll[k] = coll.get(k, 0.0) + n * v
                for k, v in bcc.items():
                    coll_counts[k] = coll_counts.get(k, 0) + n * v
                continue
            if oc in ("fusion", "call", "conditional", "async-start"):
                for callee in re.findall(
                        r"(?:calls|body|branch_computations=\{)[=%]?([\w.\-]+)",
                        op.line):
                    cf, cv, ch, cw, cc, ccc = comp_cost(callee)
                    flops += cf
                    vflops += cv
                    hbm += ch
                    hbm_w += cw
                    for k, v in cc.items():
                        coll[k] = coll.get(k, 0.0) + v
                    for k, v in ccc.items():
                        coll_counts[k] = coll_counts.get(k, 0) + v
                # fusion op itself: HBM traffic = operands + result
                if oc == "fusion":
                    hbm += out_bytes
                    hbm_w += out_bytes
                    for name in _operand_names(op.rest):
                        _, b = _shape_elems_bytes(shapes.get(name, ""))
                        hbm += b
                continue
            # ---- collectives (count -start, skip -done)
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLL_MULT and not oc.endswith("-done"):
                coll[base] = coll.get(base, 0.0) + out_bytes * _COLL_MULT[base]
                coll_counts[base] = coll_counts.get(base, 0) + 1
                hbm += 2 * out_bytes
                hbm_w += out_bytes
                continue
            if oc in _SKIP_BYTES or oc.endswith("-done"):
                continue
            # ---- compute ops
            if oc == "dot":
                flops += _dot_flops(op, shapes)
            elif oc == "convolution":
                # rare here (mamba depthwise conv); floor: 2*out*K_window
                m = re.search(r"size=([\dx]+)", op.line)
                k = 1
                if m:
                    for d in m.group(1).split("x"):
                        k *= int(d)
                flops += 2.0 * out_elems * k
            else:
                vflops += out_elems
            hbm += out_bytes
            hbm_w += out_bytes
            for name in _operand_names(op.rest):
                _, b = _shape_elems_bytes(shapes.get(name, ""))
                hbm += b
        return flops, vflops, hbm, hbm_w, coll, coll_counts

    entry = entry_name or next(iter(comps))
    f, v, h, hw, c, cc = comp_cost(entry)
    return {
        "entry": entry,
        "flops": f,
        "vflops": v,
        "hbm_bytes": h,
        "hbm_write_bytes": hw,
        "collectives": {k: {"wire_bytes": vv, "count": cc.get(k, 0)}
                        for k, vv in c.items()},
        "total_wire_bytes": sum(c.values()),
    }
