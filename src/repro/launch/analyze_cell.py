import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Hillclimb harness: lower ONE cell with config overrides, print the
roofline terms.  Each invocation is one hypothesis->measure iteration
(EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m repro.launch.analyze_cell \
        --arch deepseek-v2-236b --shape train_4k \
        --set moe.dispatch=gather --tag moe_gather
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import warnings  # noqa: E402

warnings.filterwarnings("ignore")

from repro.configs.base import SHAPES, get_config  # noqa: E402
from repro.launch import specs as SP  # noqa: E402
from repro.launch.dryrun import analyze, lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

PEAK = 197e12
PEAK_INT8 = 394e12
HBM = 819e9
LINK = 50e9


def apply_overrides(cfg, sets):
    for kv in sets:
        key, val = kv.split("=", 1)
        parts = key.split(".")
        try:
            val = json.loads(val)
        except json.JSONDecodeError:
            pass
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: val})
        else:
            sub = getattr(cfg, parts[0])
            sub = dataclasses.replace(sub, **{parts[1]: val})
            cfg = dataclasses.replace(cfg, **{parts[0]: sub})
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--rns", action="store_true")
    ap.add_argument("--rns-profile", default="rns9")
    ap.add_argument("--rns-slice-parallel", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. moe.dispatch=gather")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = SP.with_shape_overrides(get_config(args.arch), rns=args.rns)
    if args.rns and (args.rns_profile != "rns9" or args.rns_slice_parallel):
        from repro.core.rns_matmul import RnsDotConfig

        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile=args.rns_profile, qx=16, qw=16,
                                  slice_parallel=args.rns_slice_parallel))
    cfg = apply_overrides(cfg, args.set)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, compiled, meta = lower_cell(cfg, shape, mesh)
    rec = analyze(cfg, shape, args.mesh, compiled, meta)
    if args.save_hlo:
        import gzip

        with gzip.open(args.save_hlo, "wt") as f:
            f.write(compiled.as_text())

    t_c = rec["flops_per_device"] / (PEAK_INT8 if args.rns else PEAK)
    t_v = rec["vflops_per_device"] / (PEAK / 8)
    t_m = rec["hbm_write_bytes"] / HBM
    t_x = rec["collectives"]["total_wire_bytes"] / LINK
    terms = {"compute": max(t_c, t_v), "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    print(f"\n=== {args.arch}/{args.shape}/{args.mesh} [{args.tag}] "
          f"{'RNS' if args.rns else ''} {' '.join(args.set)}")
    print(f"compute {t_c:10.3f}s  vpu {t_v:8.3f}s  memory {t_m:10.3f}s  "
          f"collective {t_x:10.3f}s   DOMINANT={dom}")
    print(f"flops/dev {rec['flops_per_device']:.3e}  "
          f"hbm_w {rec['hbm_write_bytes']/2**40:.2f} TiB  "
          f"wire {rec['collectives']['total_wire_bytes']/2**40:.2f} TiB  "
          f"temp {rec['memory']['temp_bytes']/2**30:.1f} GiB  "
          f"compile {meta['compile_s']:.0f}s")
    for k, v in rec["collectives"].items():
        if isinstance(v, dict):
            print(f"  {k:20s} n={v['count']:6d} wire={v['wire_bytes']/2**40:.3f} TiB")
    tagf = f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"
    json.dump(rec, open(os.path.join(args.out, tagf), "w"), indent=1)


if __name__ == "__main__":
    main()
