"""Static analysis CLI: exactness/kernel audits and roofline analysis.

Three modes behind one entry point (this module absorbed the seed tools
``launch/analyze_cell.py`` and ``launch/hlo_analysis.py``):

``--audit``
    Run the static RNS exactness auditor (``repro.analysis``) over a
    serving configuration WITHOUT running the model: build the engine,
    trace every jitted phase abstractly, propagate worst-case magnitude
    bounds, and print the proof (or the named counterexample) plus the
    per-site headroom table.  ``--json`` writes the machine-readable
    :class:`repro.analysis.AuditReport`::

        PYTHONPATH=src python -m repro.launch.analyze --audit \
            --arch smollm-135m --rns rns9 --resident-weights \
            --chunked-prefill --json artifacts/audit.json

``--kernels``
    Run the static Pallas kernel auditor
    (``repro.analysis.kernel_audit``) over every kernel family x shape
    bucket x block config — the autotune DEFAULTS, every CANDIDATE, and
    any persisted cache row — proving Mosaic tiling legality, grid
    coverage, VMEM working set, and fused digit-axis residency, again
    without running anything.  Exit 1 if any config is illegal::

        PYTHONPATH=src python -m repro.launch.analyze --kernels \
            --json artifacts/kernel_audit.json

``--cell``
    Hillclimb harness: lower ONE (arch, shape, mesh) cell with config
    overrides and print the roofline terms.  Each invocation is one
    hypothesis->measure iteration (EXPERIMENTS.md §Perf)::

        PYTHONPATH=src python -m repro.launch.analyze --cell \
            --arch deepseek-v2-236b --shape train_4k \
            --set moe.dispatch=gather --tag moe_gather

Module level stays stdlib-only: ``--cell`` must install XLA_FLAGS
(512 placeholder devices) BEFORE the first jax import, so all heavy
imports happen inside the mode handlers after arg parsing.
"""

from __future__ import annotations

import argparse
import json
import os
import re
import sys

# ------------------------------------------------- HLO collective stats ---
# Pure-regex extraction from post-SPMD HLO text (moved here from the seed
# launch/hlo_analysis.py).  ``compiled.as_text()`` shapes are PER-DEVICE
# (post-partitioning) — exactly the per-chip wire-traffic basis the
# roofline needs; cost_analysis does not report collective bytes, so we
# parse the ops ourselves.  Wire-byte model per op (ring algorithms,
# n-1/n ~ 1): all-reduce 2x bytes (reduce-scatter + all-gather phases),
# all-gather / reduce-scatter / all-to-all / collective-permute 1x.

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)

_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-type counts and wire bytes (per device) from HLO text."""
    out: dict[str, dict] = {}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, op = m.group(1), m.group(2)
        raw = _shape_bytes(shape_str)
        rec = out.setdefault(op, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        rec["count"] += 1
        rec["bytes"] += raw
        rec["wire_bytes"] += raw * _MULT[op]
    out["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


# ------------------------------------------------------------ --audit ----
def _run_audit(args) -> int:
    import dataclasses

    import jax

    from repro.analysis.ledger_audit import audit_serve
    from repro.configs.base import get_config
    from repro.core.rns_matmul import RnsDotConfig
    from repro.models import model as M
    from repro.serve.engine import ServeConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.rns:
        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile=args.rns, qx=args.qx, qw=args.qw,
                                  defer=args.defer),
            rns_targets=args.rns_targets)
    if cfg.rns is None:
        print("nothing to audit: config has no RNS datapath "
              "(pass --rns PROFILE)")
        return 2
    params = M.init_model(jax.random.PRNGKey(0), cfg)[0]
    scfg = ServeConfig(
        max_cache=args.max_cache, page_size=args.page_size,
        max_seqs=args.max_seqs, rns_backend=args.rns_backend,
        resident_weights=args.resident_weights,
        per_layer_profiles=args.per_layer_profiles,
        prefix_cache=args.prefix_cache, spec_decode=args.spec_decode,
        spec_k=args.spec_k, chunked_prefill=args.chunked_prefill,
        token_budget=args.token_budget, chunk_size=args.chunk_size)
    report = audit_serve(params, cfg, scfg)
    print(report.table())
    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            f.write(report.to_json())
        print(f"\nwrote {args.json}")
    return 0 if report.ok else 1


# ---------------------------------------------------------- --kernels ----
def _run_kernels(args) -> int:
    from repro.analysis.kernel_audit import audit_all

    profiles = (args.rns,) if args.rns else ("rns6", "rns9")
    report = audit_all(profiles=profiles)
    print(report.table())
    print()
    print(report.summary())
    if args.json:
        d = os.path.dirname(args.json)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.json, "w") as f:
            f.write(report.to_json())
        print(f"\nwrote {args.json}")
    return 0 if report.ok else 1


# ------------------------------------------------------------- --cell ----
# Single-pod roofline constants (per device): int8 path doubles MXU rate.
PEAK = 197e12
PEAK_INT8 = 394e12
HBM = 819e9
LINK = 50e9


def apply_overrides(cfg, sets):
    """``a.b=json_value`` dotted dataclass overrides (depth <= 2)."""
    import dataclasses

    for kv in sets:
        key, val = kv.split("=", 1)
        parts = key.split(".")
        try:
            val = json.loads(val)
        except json.JSONDecodeError:
            pass
        if len(parts) == 1:
            cfg = dataclasses.replace(cfg, **{parts[0]: val})
        else:
            sub = getattr(cfg, parts[0])
            sub = dataclasses.replace(sub, **{parts[1]: val})
            cfg = dataclasses.replace(cfg, **{parts[0]: sub})
    return cfg


def _run_cell(args) -> int:
    # 512 placeholder devices BEFORE jax loads (this is why --cell parses
    # args first and imports lazily — see module docstring)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                               + os.environ.get("XLA_FLAGS", ""))
    import dataclasses
    import warnings

    warnings.filterwarnings("ignore")

    from repro.configs.base import SHAPES, get_config
    from repro.launch import specs as SP
    from repro.launch.dryrun import analyze, lower_cell
    from repro.launch.mesh import make_production_mesh

    os.makedirs(args.out, exist_ok=True)
    cfg = SP.with_shape_overrides(get_config(args.arch), rns=bool(args.rns))
    if args.rns and (args.rns != "rns9" or args.rns_slice_parallel):
        from repro.core.rns_matmul import RnsDotConfig

        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile=args.rns, qx=16, qw=16,
                                  slice_parallel=args.rns_slice_parallel))
    cfg = apply_overrides(cfg, args.set)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    lowered, compiled, meta = lower_cell(cfg, shape, mesh)
    rec = analyze(cfg, shape, args.mesh, compiled, meta)
    if args.save_hlo:
        import gzip

        with gzip.open(args.save_hlo, "wt") as f:
            f.write(compiled.as_text())

    t_c = rec["flops_per_device"] / (PEAK_INT8 if args.rns else PEAK)
    t_v = rec["vflops_per_device"] / (PEAK / 8)
    t_m = rec["hbm_write_bytes"] / HBM
    t_x = rec["collectives"]["total_wire_bytes"] / LINK
    terms = {"compute": max(t_c, t_v), "memory": t_m, "collective": t_x}
    dom = max(terms, key=terms.get)
    print(f"\n=== {args.arch}/{args.shape}/{args.mesh} [{args.tag}] "
          f"{'RNS' if args.rns else ''} {' '.join(args.set)}")
    print(f"compute {t_c:10.3f}s  vpu {t_v:8.3f}s  memory {t_m:10.3f}s  "
          f"collective {t_x:10.3f}s   DOMINANT={dom}")
    print(f"flops/dev {rec['flops_per_device']:.3e}  "
          f"hbm_w {rec['hbm_write_bytes']/2**40:.2f} TiB  "
          f"wire {rec['collectives']['total_wire_bytes']/2**40:.2f} TiB  "
          f"temp {rec['memory']['temp_bytes']/2**30:.1f} GiB  "
          f"compile {meta['compile_s']:.0f}s")
    for k, v in rec["collectives"].items():
        if isinstance(v, dict):
            print(f"  {k:20s} n={v['count']:6d} "
                  f"wire={v['wire_bytes']/2**40:.3f} TiB")
    tagf = f"{args.arch}__{args.shape}__{args.mesh}__{args.tag}.json"
    with open(os.path.join(args.out, tagf), "w") as f:
        json.dump(rec, f, indent=1)
    return 0


# ---------------------------------------------------------------- main ----
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="static analysis: --audit (RNS exactness proof), "
                    "--kernels (Pallas legality/VMEM proof), or "
                    "--cell (roofline lowering)")
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--audit", action="store_true",
                      help="prove the RNS datapath overflow-free for a "
                           "serving config (no model execution)")
    mode.add_argument("--kernels", action="store_true",
                      help="prove every kernel family x autotune config "
                           "Mosaic-legal and within the VMEM budget")
    mode.add_argument("--cell", action="store_true",
                      help="lower one (arch, shape, mesh) cell and print "
                           "roofline terms")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--rns", metavar="PROFILE", default=None,
                    help="RNS moduli profile (e.g. rns9); --cell keeps its "
                         "legacy qx/qw=16, --audit uses --qx/--qw")
    # audit-mode flags (a subset of launch/serve.py's ServeConfig surface)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="smoke-size the model config (default)")
    ap.add_argument("--full", dest="smoke", action="store_false",
                    help="audit the full-size config")
    ap.add_argument("--rns-targets", default="mlp")
    ap.add_argument("--qx", type=int, default=8)
    ap.add_argument("--qw", type=int, default=8)
    ap.add_argument("--defer", action="store_true",
                    help="residue-domain MLP chaining")
    ap.add_argument("--rns-backend", default=None)
    ap.add_argument("--max-cache", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--max-seqs", type=int, default=2)
    ap.add_argument("--resident-weights", action="store_true")
    ap.add_argument("--per-layer-profiles", action="store_true")
    ap.add_argument("--prefix-cache", action="store_true")
    ap.add_argument("--spec-decode", action="store_true")
    ap.add_argument("--spec-k", type=int, default=3)
    ap.add_argument("--chunked-prefill", action="store_true")
    ap.add_argument("--token-budget", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the AuditReport JSON here")
    # cell-mode flags (the legacy analyze_cell surface)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--rns-slice-parallel", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override, e.g. moe.dispatch=gather")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args(argv)
    if args.cell:
        return _run_cell(args)
    if args.kernels:
        return _run_kernels(args)
    return _run_audit(args)


if __name__ == "__main__":
    sys.exit(main())
