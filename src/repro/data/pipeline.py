"""Deterministic, shardable, resumable synthetic LM data pipeline.

Every batch is a pure function of (seed, step, host_shard) — so restart
from a checkpointed step reproduces the exact stream (fault-tolerance
property tested in tests/test_checkpoint.py), and each host materializes
only its shard (multi-host scalability).

The synthetic distribution is an order-1 Markov chain with a banded,
skewed transition structure plus noise — enough signal for a small model's
loss to drop well below the uniform-entropy floor within a few hundred
steps (used by examples/train_lm.py and the integration tests).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1        # fraction of uniformly random tokens
    branch: int = 8           # Markov out-degree


class SyntheticLM:
    """Stateless-per-step synthetic corpus."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        rng = np.random.default_rng(cfg.seed)
        # fixed transition table: vocab x branch successor ids, zipf weights
        self._succ = rng.integers(0, cfg.vocab, size=(cfg.vocab, cfg.branch))
        w = 1.0 / np.arange(1, cfg.branch + 1)
        self._w = w / w.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.host_id, 0xD1CE))
        B, T = self.local_batch, cfg.seq_len
        toks = np.empty((B, T), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, B)
        branch_draw = rng.choice(cfg.branch, size=(B, T), p=self._w)
        noise_mask = rng.random((B, T)) < cfg.noise
        noise_tok = rng.integers(0, cfg.vocab, (B, T))
        for t in range(1, T):
            nxt = self._succ[toks[:, t - 1], branch_draw[:, t]]
            toks[:, t] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": toks}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_frontend_stub(rng: np.random.Generator, batch: int, n_tokens: int,
                       d_model: int) -> np.ndarray:
    """Precomputed frame/patch embeddings for audio/vlm archs (the stub)."""
    return rng.standard_normal((batch, n_tokens, d_model)).astype(np.float32)
