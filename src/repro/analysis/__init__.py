"""Static analysis for the RNS datapath.

Two passes, both ahead-of-time (nothing here runs the model):

* :mod:`repro.analysis.ledger_audit` — the exactness auditor.  It traces
  an entry point under :func:`repro.core.dispatch.record_ops` (the
  abstract-interpretation shim: ``jax.eval_shape`` runs the python code
  with zero FLOPs while every convert/matmul/normalize/fused composite
  reports itself), then propagates worst-case ``log2|X|`` bounds through
  the recorded dataflow graph and proves — with the SAME formulas the
  runtime ledger uses (``core.tensor.ledger_limit_bits`` /
  ``dot_out_bits``) — that no op can exceed its profile's exact range.
* :mod:`repro.analysis.lint` — an AST linter enforcing the repo
  invariants the codebase otherwise keeps by convention (kernel calls
  stay in ``kernels/``, raw digit arithmetic stays in ``core/``, backend
  selection goes through ``dispatch.resolve_backend``, no host calls on
  jitted paths).

Surfaces: ``launch/analyze.py --audit``, ``ServeConfig(audit=True)``,
``python -m repro.analysis.lint``, and the ``static-analysis`` CI job.
See docs/analysis.md.

Attribute access is lazy (PEP 562) so ``python -m repro.analysis.lint``
never pays the jax import the auditor needs.
"""

_EXPORTS = {
    "GraphRecorder": "repro.analysis.graph",
    "OpGraph": "repro.analysis.graph",
    "OpNode": "repro.analysis.graph",
    "trace_graph": "repro.analysis.graph",
    "AuditReport": "repro.analysis.ledger_audit",
    "PhaseAudit": "repro.analysis.ledger_audit",
    "audit_fn": "repro.analysis.ledger_audit",
    "audit_engine": "repro.analysis.ledger_audit",
    "audit_serve": "repro.analysis.ledger_audit",
    "LintViolation": "repro.analysis.lint",
    "run_lint": "repro.analysis.lint",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
