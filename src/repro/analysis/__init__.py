"""Static analysis for the RNS datapath.

Four passes, all ahead-of-time (nothing here runs the model):

* :mod:`repro.analysis.ledger_audit` — the exactness auditor.  It traces
  an entry point under :func:`repro.core.dispatch.record_ops` (the
  abstract-interpretation shim: ``jax.eval_shape`` runs the python code
  with zero FLOPs while every convert/matmul/normalize/fused composite
  reports itself), then propagates worst-case ``log2|X|`` bounds through
  the recorded dataflow graph and proves — with the SAME formulas the
  runtime ledger uses (``core.tensor.ledger_limit_bits`` /
  ``dot_out_bits``) — that no op can exceed its profile's exact range.
* :mod:`repro.analysis.kernel_audit` — the Pallas kernel legality and
  VMEM auditor.  It captures every ``pallas_call`` a wrapper (or a whole
  engine phase) lowers to under ``jax.eval_shape`` and proves Mosaic
  tiling legality, grid x index_map coverage, the double-buffered VMEM
  working set against the per-core budget, and the fused kernels'
  digit-axis scratch residency — for the autotune DEFAULTS, every
  CANDIDATE, and any persisted cache row.
* :mod:`repro.analysis.trace_audit` — the jit compile-churn prover.  It
  rebuilds each engine's ``_trace_specs(traffic=...)`` closures over a
  generated traffic family and proves the jit cache keys (treedef +
  per-leaf shape/dtype/weak_type) are traffic-invariant.
* :mod:`repro.analysis.lint` — an AST linter enforcing the repo
  invariants the codebase otherwise keeps by convention (kernel calls
  stay in ``kernels/``, raw digit arithmetic stays in ``core/``, backend
  selection goes through ``dispatch.resolve_backend``, no host calls on
  jitted paths, no whole-array VMEM BlockSpecs outside the wrappers).

Surfaces: ``launch/analyze.py --audit``/``--kernels``,
``ServeConfig(audit=True)``, ``python -m repro.analysis.lint``, and the
``static-analysis`` CI job.  See docs/analysis.md.

Attribute access is lazy (PEP 562) so ``python -m repro.analysis.lint``
never pays the jax import the auditors need.
"""

_EXPORTS = {
    "GraphRecorder": "repro.analysis.graph",
    "OpGraph": "repro.analysis.graph",
    "OpNode": "repro.analysis.graph",
    "trace_graph": "repro.analysis.graph",
    "AuditReport": "repro.analysis.ledger_audit",
    "PhaseAudit": "repro.analysis.ledger_audit",
    "audit_fn": "repro.analysis.ledger_audit",
    "audit_engine": "repro.analysis.ledger_audit",
    "audit_serve": "repro.analysis.ledger_audit",
    "BlockConfigError": "repro.analysis.kernel_audit",
    "KernelAuditReport": "repro.analysis.kernel_audit",
    "KernelLaunch": "repro.analysis.kernel_audit",
    "audit_all": "repro.analysis.kernel_audit",
    "audit_config": "repro.analysis.kernel_audit",
    "audit_engine_kernels": "repro.analysis.kernel_audit",
    "capture_launches": "repro.analysis.kernel_audit",
    "check_launch": "repro.analysis.kernel_audit",
    "check_wrapper_blocks": "repro.analysis.kernel_audit",
    "validate_blocks": "repro.analysis.kernel_audit",
    "vmem_bytes": "repro.analysis.kernel_audit",
    "PhaseTraceAudit": "repro.analysis.trace_audit",
    "TraceAuditReport": "repro.analysis.trace_audit",
    "arg_signature": "repro.analysis.trace_audit",
    "audit_traces": "repro.analysis.trace_audit",
    "traffic_family": "repro.analysis.trace_audit",
    "LintViolation": "repro.analysis.lint",
    "run_lint": "repro.analysis.lint",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
