"""Repo-invariant linter: enforce by AST what the codebase keeps by
convention.

Rules (ids are the suppression keys):

* ``pallas-call`` — ``pl.pallas_call`` only inside ``kernels/``; every
  other layer talks to kernels through the ``kernels/*/ops.py`` wrappers
  via ``core/dispatch.py``.
* ``raw-digits`` — no arithmetic on raw ``RnsTensor.digits`` outside
  ``core/`` + ``kernels/``; digit planes are only combined by the
  residue primitives (layout moves like ``moveaxis``/``device_put`` are
  fine).
* ``backend-flag`` — backend selection goes through
  ``core/dispatch.resolve_backend``: no stray ``interpret=`` kwargs
  outside ``kernels/`` + ``core/dispatch.py`` and no ``use_pallas=``
  outside its legacy home ``core/rns_matmul.py``.
* ``host-in-jit`` — no ``time.*`` / ``np.random.*`` calls in the traced
  surface (``core/``, ``models/``, ``kernels/``): host calls burn in a
  constant at trace time and silently stop varying under jit.
* ``whole-array-vmem`` — every ``BlockSpec`` names an explicit block
  shape.  A shapeless/None BlockSpec maps the WHOLE operand into VMEM:
  fine for toy shapes, an unbounded-VMEM landmine at serving sizes (see
  analysis/kernel_audit.py for the budget it would blow).  Approved
  wrapper files are listed in ``_WHOLE_ARRAY_OK`` (currently none).

Suppression: ``# lint-ok: <rule>[, <rule>...] [reason]`` on the flagged
line or the line above; ``# lint-ok-file: <rule>`` anywhere in a file
suppresses the rule for the whole file (e.g. the autotuner, which times
on the host *by design*).

Run as a pytest (tests/test_analysis.py asserts zero unsuppressed
violations on ``src/``), as a CI job, or directly::

    PYTHONPATH=src python -m repro.analysis.lint
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
import sys

__all__ = ["LintViolation", "RULES", "lint_source", "run_lint", "main"]

RULES = {
    "pallas-call": "pl.pallas_call outside kernels/",
    "raw-digits": "arithmetic on raw RnsTensor.digits outside core/+kernels/",
    "backend-flag": "backend selection bypassing core/dispatch "
                    "(stray interpret=/use_pallas=)",
    "host-in-jit": "time.*/np.random.* call on a jitted code path",
    "whole-array-vmem": "BlockSpec without an explicit block shape "
                        "(whole-array VMEM residency)",
}

#: directories (relative to src/repro/) whose modules count as the traced
#: surface for host-in-jit
_TRACED_DIRS = ("core/", "models/", "kernels/")
#: where each bypass flag may legitimately appear
_INTERPRET_OK = ("kernels/", "core/dispatch.py")
_USE_PALLAS_OK = ("core/rns_matmul.py",)
#: wrapper files allowed to build whole-array VMEM BlockSpecs.  Empty on
#: purpose: every shipped kernel streams bounded blocks; add a file here
#: only with a VMEM argument in review.
_WHOLE_ARRAY_OK: tuple[str, ...] = ()
#: call names that count as arithmetic for raw-digits (layout moves and
#: placement don't — resident encode legitimately moveaxis/device_puts)
_ARITH_CALLS = {"matmul", "einsum", "dot", "tensordot", "remainder", "mod",
                "add", "subtract", "multiply", "sum", "prod", "cumsum"}

_SUPPRESS_RE = re.compile(r"#\s*lint-ok(?P<file>-file)?:\s*"
                          r"(?P<rules>[\w-]+(?:\s*,\s*[\w-]+)*)")


@dataclasses.dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _suppressions(src: str):
    """(file-wide rule set, line -> rule set).  A line-level pragma covers
    its own line and the one below it."""
    file_rules: set[str] = set()
    line_rules: dict[int, set[str]] = {}
    for i, text in enumerate(src.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group("rules").split(",")}
        if m.group("file"):
            file_rules |= rules
        else:
            line_rules.setdefault(i, set()).update(rules)
            line_rules.setdefault(i + 1, set()).update(rules)
    return file_rules, line_rules


def _is_digits_attr(node) -> bool:
    return isinstance(node, ast.Attribute) and node.attr == "digits"


class _Checker(ast.NodeVisitor):
    def __init__(self, rel: str):
        self.rel = rel
        self.found: list[tuple[int, str, str]] = []

    def flag(self, node, rule: str, message: str):
        self.found.append((node.lineno, rule, message))

    # --- pallas-call / backend-flag / host-in-jit (all Call-shaped) ------
    def visit_Call(self, node: ast.Call):
        fn = node.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        if name == "pallas_call" and not self.rel.startswith("kernels/"):
            self.flag(node, "pallas-call",
                      "pallas_call belongs in kernels/ (route through "
                      "core/dispatch)")
        if name == "BlockSpec" and not (
                _WHOLE_ARRAY_OK and self.rel.startswith(_WHOLE_ARRAY_OK)):
            # an explicit block shape is any non-None first positional
            # arg or non-None block_shape= kwarg; bare/None BlockSpecs
            # map the whole operand into VMEM
            def _none(a):
                return isinstance(a, ast.Constant) and a.value is None
            shaped = bool(node.args) and not _none(node.args[0])
            shaped = shaped or any(
                kw.arg == "block_shape" and not _none(kw.value)
                for kw in node.keywords)
            if not shaped:
                self.flag(node, "whole-array-vmem",
                          "BlockSpec without an explicit block shape pins "
                          "the whole operand in VMEM; pass a bounded "
                          "block (or list the file in _WHOLE_ARRAY_OK)")
        for kw in node.keywords:
            if kw.arg == "interpret" \
                    and not self.rel.startswith(_INTERPRET_OK):
                self.flag(node, "backend-flag",
                          "interpret= outside kernels//dispatch; use "
                          "dispatch.resolve_backend")
            if kw.arg == "use_pallas" \
                    and not self.rel.startswith(_USE_PALLAS_OK):
                self.flag(node, "backend-flag",
                          "use_pallas= is a legacy core/rns_matmul alias; "
                          "pass backend= instead")
        if self.rel.startswith(_TRACED_DIRS):
            if isinstance(fn, ast.Attribute):
                v = fn.value
                if isinstance(v, ast.Name) and v.id == "time":
                    self.flag(node, "host-in-jit",
                              f"time.{fn.attr} on a traced path")
                if isinstance(v, ast.Attribute) and v.attr == "random" \
                        and isinstance(v.value, ast.Name) \
                        and v.value.id in ("np", "numpy"):
                    self.flag(node, "host-in-jit",
                              f"np.random.{fn.attr} on a traced path")
        # raw-digits via arithmetic-shaped calls
        if name in _ARITH_CALLS and not self.rel.startswith(("core/",
                                                             "kernels/")):
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if _is_digits_attr(arg):
                    self.flag(node, "raw-digits",
                              f".digits operand of {name}() outside core/")
        self.generic_visit(node)

    # --- raw-digits (operator-shaped) ------------------------------------
    def _digits_arith(self, node, operands):
        if self.rel.startswith(("core/", "kernels/")):
            return
        if any(_is_digits_attr(o) for o in operands):
            self.flag(node, "raw-digits",
                      "arithmetic on raw .digits outside core/ (use the "
                      "rt_*/dispatch primitives)")

    def visit_BinOp(self, node: ast.BinOp):
        self._digits_arith(node, (node.left, node.right))
        self.generic_visit(node)

    def visit_UnaryOp(self, node: ast.UnaryOp):
        if not isinstance(node.op, ast.Not):
            self._digits_arith(node, (node.operand,))
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._digits_arith(node, (node.target, node.value))
        self.generic_visit(node)


def lint_source(src: str, rel: str, path: str | None = None
                ) -> list[LintViolation]:
    """Lint one module's source.  ``rel`` is its path relative to
    ``src/repro/`` (rule scoping key); ``path`` is for messages."""
    file_rules, line_rules = _suppressions(src)
    checker = _Checker(rel)
    checker.visit(ast.parse(src))
    out = []
    for line, rule, message in checker.found:
        if rule in file_rules or rule in line_rules.get(line, ()):
            continue
        out.append(LintViolation(path or rel, line, rule, message))
    return sorted(out, key=lambda v: (v.path, v.line))


def run_lint(root=None) -> list[LintViolation]:
    """Lint every module under ``src/repro/`` plus the repo-root
    ``benchmarks/`` tree (zero violations is a CI gate; see
    .github/workflows/ci.yml job ``static-analysis``).  ``launch/`` lives
    under ``src/repro/`` and is covered by the main walk; benchmark
    modules get a ``benchmarks/`` rule-scoping prefix (outside
    ``kernels/``, so kernel calls and backend flags are flagged there
    like any other layer)."""
    if root is not None:
        bases = [(pathlib.Path(root), "")]
    else:
        base = pathlib.Path(__file__).resolve().parents[1]
        bases = [(base, ""), (base.parents[1] / "benchmarks", "benchmarks/")]
    out: list[LintViolation] = []
    for base, prefix in bases:
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            rel = prefix + py.relative_to(base).as_posix()
            out.extend(lint_source(py.read_text(), rel, str(py)))
    return out


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    violations = run_lint(args[0] if args else None)
    for v in violations:
        print(v)
    print(f"repro lint: {len(violations)} violation(s), "
          f"{len(RULES)} rules")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
