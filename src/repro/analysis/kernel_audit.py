"""Static Pallas kernel legality & VMEM auditor.

The kernels in ``src/repro/kernels/`` are only as portable as their block
configs: a tile that violates Mosaic's sublane/lane layout rules, an
``index_map`` that walks off the padded operand, or a working set that
does not fit the per-core VMEM budget all fail *at lowering time* on a
real TPU — long after the autotuner cache or a caller picked the config.
This module proves those properties ahead of time, with zero FLOPs:

* **closed-form layer** — :func:`validate_blocks` / :func:`vmem_bytes`
  score a ``{"bm": .., "bn": .., "bk": ..}``-style block dict against a
  per-kind model of every tile the kernel streams (operands, outputs,
  scratch).  This is what ``kernels/autotune.py`` uses to refuse illegal
  candidates/cache rows and what the wrappers call (via
  :func:`check_wrapper_blocks`) to fail fast with the kernel, blocks,
  and computed VMEM bytes in the message;
* **capture layer** — :func:`capture_launches` abstract-interprets a
  wrapper under ``jax.eval_shape`` with ``pl.pallas_call`` shimmed out,
  recording every launch's grid, BlockSpecs, operand/output avals and
  scratch shapes; :func:`check_launch` then verifies tiling legality,
  grid x index_map coverage (no out-of-bounds block reads, every output
  tile written), the VMEM working set, and the fused kernels'
  digit-axis scratch residency against the *actual* traced launch;
* **report layer** — :func:`audit_all` sweeps every kernel family x
  shape bucket x block config (defaults, every autotune CANDIDATE, and
  any persisted cache row) and returns a :class:`KernelAuditReport`;
  :func:`audit_engine_kernels` audits the launches of a built engine's
  own ``_trace_specs()`` closures (the gate behind
  ``ServeConfig(audit=True)``).

VMEM accounting (the formula ``docs/analysis.md`` documents)::

    working_set = 2 * sum(block_bytes(operand and output tiles))
                + sum(scratch_bytes)           <= 16 MiB per core

The factor 2 models Mosaic's double-buffering of every streamed block
(next tile prefetches while the current one computes); scratch is
allocated once per core and is not double-buffered.
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import json
import threading

__all__ = [
    "BUDGET_BYTES",
    "LANE",
    "BlockConfigError",
    "KernelLaunch",
    "KernelAuditReport",
    "audit_all",
    "audit_config",
    "audit_engine_kernels",
    "capture_launches",
    "check_launch",
    "check_wrapper_blocks",
    "sublane",
    "validate_blocks",
    "vmem_bytes",
]

#: per-core VMEM budget the working set must fit in (16 MiB).
BUDGET_BYTES = 16 * 2**20

#: lane count — the last dim of every >=1-D tile lays out over 128 lanes.
LANE = 128

#: minimum sublane multiple by element width: (8, 128) f32/int32 tiles,
#: (16, 128) for 2-byte, (32, 128) for int8.
_SUBLANE = {1: 32, 2: 16, 4: 8}

#: grids larger than this are corner-sampled instead of enumerated.
_MAX_ENUM = 65536

_CAPTURE_LOCK = threading.Lock()


class BlockConfigError(ValueError):
    """An illegal (Mosaic-illegal or VMEM-over-budget) block config,
    raised by the wrapper-side gate.  A distinct type so the OTHER
    auditors tracing the same wrappers (the exactness auditor runs them
    under ``eval_shape`` too) can tell a tile-legality refusal apart
    from a numeric ledger error and blame the right pass."""

_MATMUL_KINDS = (
    "rns_matmul",
    "rns_fused_encode_matmul",
    "rns_fused_matmul_normalize",
    "rns_fused_dot",
)

#: block names each kind requires (the autotune DEFAULTS schema).
_REQUIRED: dict[str, tuple[str, ...]] = {
    **{k: ("bm", "bn", "bk") for k in _MATMUL_KINDS},
    "rns_convert": ("bt",),
    "rns_normalize": ("bt",),
    "flash_attention": ("bq", "bk"),
}

#: (package under kernels/, kernel fn __name__) -> audit kind.
_KIND_BY_FN = {
    ("rns_matmul", "_kernel"): "rns_matmul",
    ("rns_convert", "_kernel"): "rns_convert",
    ("rns_normalize", "_kernel"): "rns_normalize",
    ("rns_fused", "_encode_matmul_kernel"): "rns_fused_encode_matmul",
    ("rns_fused", "_matmul_normalize_kernel"): "rns_fused_matmul_normalize",
    ("rns_fused", "_fused_dot_kernel"): "rns_fused_dot",
    ("flash_attention", "_kernel"): "flash_attention",
}

#: fused kinds whose digit axis must stay scratch-resident:
#: kind -> index of the weight-residue operand whose leading dim is K.
_RESIDENT_B_OPERAND = {
    "rns_fused_matmul_normalize": 1,
    "rns_fused_dot": 2,
}

#: audited shape families per kind (pre-padding wrapper shapes).
#: matmul kinds: (M, D, N); convert/normalize: (T,);
#: flash: (B, Tq, Tk, H, Hk, D, Dv).
_AUDIT_SHAPES: dict[str, list[tuple[int, ...]]] = {
    **{k: [(8, 512, 512), (128, 2048, 2048)] for k in _MATMUL_KINDS},
    "rns_convert": [(512,), (65536,)],
    "rns_normalize": [(512,), (65536,)],
    "flash_attention": [(1, 128, 128, 4, 4, 64, 64),
                        (2, 256, 512, 8, 4, 64, 64)],
}


def sublane(elem_bytes: int) -> int:
    """Minimum sublane multiple for an element width in bytes."""
    return _SUBLANE.get(int(elem_bytes), 8)


# ---------------------------------------------------------------------------
# closed-form layer: block dict -> tile model -> violations / VMEM bytes
# ---------------------------------------------------------------------------


def _tile_violations(label, block, elem_bytes, full):
    """Mosaic tiling legality for one tile.

    Per dim: a known array dim must be evenly tiled; the lane (last) dim
    must be a LANE multiple unless the block covers the whole dim; the
    sublane (2nd-last) dim must be a sublane(dtype) multiple unless it is
    1 or covers the whole dim.  Leading dims only need to divide.
    """
    out = []
    nd = len(block)
    sub = sublane(elem_bytes)
    for axis, b in enumerate(block):
        f = None
        if full is not None and axis < len(full):
            f = full[axis]
        if not isinstance(b, int) or isinstance(b, bool) or b <= 0:
            out.append(f"{label}: block dim {axis} is {b!r} "
                       "(need a positive int)")
            continue
        whole = f is not None and b == f
        if f is not None:
            if b > f:
                out.append(f"{label}: block dim {axis} = {b} exceeds "
                           f"array dim {f}")
            elif f % b != 0:
                out.append(f"{label}: block dim {axis} = {b} does not "
                           f"evenly tile array dim {f}")
        if axis == nd - 1:
            if b % LANE != 0 and not whole:
                out.append(f"{label}: lane dim {b} is not a multiple of "
                           f"{LANE} (and does not span the array dim)")
        elif axis == nd - 2:
            if b % sub != 0 and b != 1 and not whole:
                out.append(f"{label}: sublane dim {b} is not a multiple "
                           f"of {sub} for {elem_bytes}-byte elements")
    return out


def _block_layout(kind, blocks, n_digits, res_bytes, dims):
    """The per-kind tile model: every VMEM block a launch streams.

    Returns ``(tiles, scratch)`` where tiles are
    ``(label, block_shape, elem_bytes, full_dims_or_None)`` and scratch
    entries are ``(shape, elem_bytes)``.  ``dims`` names the (padded)
    array dims when known (``M/D/N``, ``T``, flash ``D/Dv/Tq/Tk``) —
    unknown dims disable the divide/whole-dim checks but never the
    multiple checks.
    """
    d = dict(dims or {})
    K = int(n_digits)
    g = d.get
    if kind == "rns_matmul":
        bm, bn, bk = blocks["bm"], blocks["bn"], blocks["bk"]
        tiles = [
            ("moduli", (1, 1), 4, (K, 1)),
            ("a_res", (1, bm, bk), res_bytes, (K, g("M"), g("D"))),
            ("b_res", (1, bk, bn), res_bytes, (K, g("D"), g("N"))),
            ("out", (1, bm, bn), 4, (K, g("M"), g("N"))),
        ]
        scratch = [((bm, bn), 4)]
    elif kind == "rns_fused_encode_matmul":
        bm, bn, bk = blocks["bm"], blocks["bn"], blocks["bk"]
        tiles = [
            ("moduli", (1, 1), 4, (K, 1)),
            ("x", (bm, bk), 4, (g("M"), g("D"))),
            ("scale", (bm, 1), 4, (g("M"), 1)),
            ("b_res", (1, bk, bn), res_bytes, (K, g("D"), g("N"))),
            ("out", (1, bm, bn), 4, (K, g("M"), g("N"))),
        ]
        scratch = [((bm, bn), 4)]
    elif kind == "rns_fused_matmul_normalize":
        bm, bn, bk = blocks["bm"], blocks["bn"], blocks["bk"]
        tiles = [
            ("a_res", (K, bm, bk), res_bytes, (K, g("M"), g("D"))),
            ("b_res", (K, bk, bn), res_bytes, (K, g("D"), g("N"))),
            ("out", (bm, bn), 4, (g("M"), g("N"))),
        ]
        scratch = [((K, bm, bn), 4)]
    elif kind == "rns_fused_dot":
        bm, bn, bk = blocks["bm"], blocks["bn"], blocks["bk"]
        tiles = [
            ("x", (bm, bk), 4, (g("M"), g("D"))),
            ("scale", (bm, 1), 4, (g("M"), 1)),
            ("b_res", (K, bk, bn), res_bytes, (K, g("D"), g("N"))),
            ("out", (bm, bn), 4, (g("M"), g("N"))),
        ]
        scratch = [((K, bm, bn), 4)]
    elif kind == "rns_convert":
        bt = blocks["bt"]
        # scale modeled per-element — the conservative case; scalar
        # callers stream a (1, 1) broadcast block instead.
        tiles = [
            ("x", (bt,), 4, (g("T"),)),
            ("scale", (bt,), 4, (g("T"),)),
            ("out", (K, bt), res_bytes, (K, g("T"))),
        ]
        scratch = []
    elif kind == "rns_normalize":
        bt = blocks["bt"]
        tiles = [
            ("res", (K, bt), 4, (K, g("T"))),
            ("out", (bt,), 4, (g("T"),)),
        ]
        scratch = []
    elif kind == "flash_attention":
        bq, bkf = blocks["bq"], blocks["bk"]
        D = g("D", 128)
        Dv = g("Dv", 128)
        tiles = [
            ("q", (1, bq, D), 4, (None, g("Tq"), D)),
            ("k", (1, bkf, D), 4, (None, g("Tk"), D)),
            ("v", (1, bkf, Dv), 4, (None, g("Tk"), Dv)),
            ("out", (1, bq, Dv), 4, (None, g("Tq"), Dv)),
        ]
        scratch = [((bq, 1), 4), ((bq, 1), 4), ((bq, Dv), 4)]
    else:
        raise KeyError(f"unknown kernel kind {kind!r}")
    return tiles, scratch


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def vmem_bytes(kind, blocks, *, n_digits=1, res_bytes=4, dims=None) -> int:
    """Closed-form VMEM working set: ``2 * streamed-block bytes +
    scratch bytes`` (the double-buffering model; see module docstring)."""
    tiles, scratch = _block_layout(kind, blocks, n_digits, res_bytes, dims)
    streamed = sum(_prod(b) * eb for _, b, eb, _ in tiles)
    return 2 * streamed + sum(_prod(s) * eb for s, eb in scratch)


def validate_blocks(kind, blocks, *, n_digits=1, res_bytes=4,
                    dims=None) -> list[str]:
    """All legality violations of a block dict for one kernel kind.

    Empty list == the config is statically proven Mosaic-legal and
    within the VMEM budget for the given profile/dims.  Tolerates junk
    input (missing keys, non-int sizes) by *naming* it rather than
    raising — this is the autotune cache gate.
    """
    if kind not in _REQUIRED:
        return [f"unknown kernel kind {kind!r}"]
    if not isinstance(blocks, dict):
        return [f"{kind}: blocks is {type(blocks).__name__}, not a dict"]
    bad = []
    for name in _REQUIRED[kind]:
        v = blocks.get(name)
        if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
            bad.append(f"{kind}: block {name!r} is {v!r} "
                       "(need a positive int)")
    if bad:
        return bad
    tiles, _ = _block_layout(kind, blocks, n_digits, res_bytes, dims)
    out = []
    for label, block, eb, full in tiles:
        out.extend(_tile_violations(f"{kind}.{label}", block, eb, full))
    vm = vmem_bytes(kind, blocks, n_digits=n_digits, res_bytes=res_bytes,
                    dims=dims)
    if vm > BUDGET_BYTES:
        out.append(f"{kind}: VMEM working set {vm} bytes exceeds the "
                   f"{BUDGET_BYTES}-byte per-core budget")
    return out


@functools.lru_cache(maxsize=4096)
def _check_cached(kind, block_items, dim_items, n_digits, res_bytes):
    blocks = dict(block_items)
    dims = dict(dim_items)
    violations = validate_blocks(kind, blocks, n_digits=n_digits,
                                 res_bytes=res_bytes, dims=dims)
    if violations:
        try:
            vm = str(vmem_bytes(kind, blocks, n_digits=n_digits,
                                res_bytes=res_bytes, dims=dims))
        except (KeyError, TypeError):
            vm = "n/a"
        raise BlockConfigError(
            f"{kind}: illegal block config {blocks} (VMEM working set "
            f"{vm} bytes vs budget {BUDGET_BYTES}): "
            + "; ".join(violations))
    return True


def check_wrapper_blocks(kind, blocks, *, dims, n_digits=1,
                         res_bytes=4) -> None:
    """Wrapper-side gate: raise ``ValueError`` naming the kernel, the
    blocks, and the computed VMEM bytes if the (resolved, padded) config
    is illegal — instead of failing deep inside Mosaic lowering.  Legal
    configs are memoized so the trace-time cost is one dict lookup."""
    _check_cached(kind, tuple(sorted(blocks.items())),
                  tuple(sorted((dims or {}).items())),
                  int(n_digits), int(res_bytes))


# ---------------------------------------------------------------------------
# capture layer: eval_shape with pallas_call shimmed out
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelLaunch:
    """One recorded ``pallas_call``: everything the legality checks need."""

    kind: str
    kernel_name: str
    profile: str | None
    grid: tuple
    in_specs: tuple    # ((block_shape, index_map), ...)
    out_specs: tuple
    operands: tuple    # ((shape, dtype_str, itemsize), ...)
    outs: tuple
    scratch: tuple     # ((shape, itemsize), ...)


def _clear_tile_caches() -> None:
    """Drop every jitted ``*_tiles`` entry point's compile cache.

    Called before a capture (so the python bodies re-run through the
    shim instead of replaying a cached jaxpr) and after (so the
    zeros-returning shim trace can never serve a real call)."""
    from repro.kernels.flash_attention.kernel import flash_attention_bhtd
    from repro.kernels.rns_convert.kernel import rns_convert_tiles
    from repro.kernels.rns_fused.kernel import (
        rns_fused_dot_tiles,
        rns_fused_encode_matmul_tiles,
        rns_fused_matmul_normalize_tiles,
    )
    from repro.kernels.rns_matmul.kernel import rns_matmul_tiles
    from repro.kernels.rns_normalize.kernel import rns_normalize_tiles

    for fn in (rns_matmul_tiles, rns_convert_tiles, rns_normalize_tiles,
               rns_fused_encode_matmul_tiles, rns_fused_matmul_normalize_tiles,
               rns_fused_dot_tiles, flash_attention_bhtd):
        fn.clear_cache()


def _kernel_identity(fn):
    """Unwrap a (possibly partial'd) kernel fn to (kind, name, profile)."""
    kw = {}
    while isinstance(fn, functools.partial):
        for k, v in (fn.keywords or {}).items():
            kw.setdefault(k, v)
        fn = fn.func
    mod = getattr(fn, "__module__", "") or ""
    name = getattr(fn, "__name__", "<kernel>")
    seg = mod.split(".kernels.", 1)[1].split(".", 1)[0] \
        if ".kernels." in mod else mod
    kind = _KIND_BY_FN.get((seg, name), f"{seg}.{name}")
    prof = kw.get("profile")
    return kind, name, (prof if isinstance(prof, str)
                        else getattr(prof, "name", None))


def capture_launches(fn, *args, **kwargs) -> list[KernelLaunch]:
    """Abstract-interpret ``fn`` (zero FLOPs) recording every pallas_call.

    ``jax.eval_shape`` runs the wrapper python under a shim installed on
    ``jax.experimental.pallas.pallas_call`` that records the launch and
    returns zeros of ``out_shape`` — so padding/reshape logic runs as
    written and the recorded grid/BlockSpecs are the real ones.  The
    jitted ``*_tiles`` compile caches are cleared on both sides of the
    capture (see :func:`_clear_tile_caches`)."""
    import jax
    import jax.experimental.pallas as pl_mod
    import jax.numpy as jnp

    captured: list[KernelLaunch] = []

    def fake_pallas_call(kernel, *fargs, out_shape=None, grid=None,
                         in_specs=None, out_specs=None, scratch_shapes=None,
                         **_kw):
        if fargs and out_shape is None:
            out_shape = fargs[0]
        kind, kname, prof = _kernel_identity(kernel)
        grid_t = (grid,) if isinstance(grid, int) else tuple(grid or ())
        ins = tuple((tuple(s.block_shape), s.index_map)
                    for s in (in_specs or []))
        out_spec_list = (list(out_specs) if isinstance(out_specs, (list, tuple))
                         else [out_specs])
        outs_t = tuple((tuple(s.block_shape), s.index_map)
                       for s in out_spec_list if s is not None)
        scratch = tuple((tuple(s.shape), jnp.dtype(s.dtype).itemsize)
                        for s in (scratch_shapes or []))
        out_leaves = jax.tree_util.tree_leaves(out_shape)

        def runner(*operands):
            captured.append(KernelLaunch(
                kind=kind, kernel_name=kname, profile=prof, grid=grid_t,
                in_specs=ins, out_specs=outs_t,
                operands=tuple(
                    (tuple(o.shape), str(o.dtype),
                     jnp.dtype(o.dtype).itemsize) for o in operands),
                outs=tuple(
                    (tuple(s.shape), str(s.dtype),
                     jnp.dtype(s.dtype).itemsize) for s in out_leaves),
                scratch=scratch))
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                                out_shape)

        return runner

    with _CAPTURE_LOCK:
        real = pl_mod.pallas_call
        _clear_tile_caches()
        pl_mod.pallas_call = fake_pallas_call
        try:
            jax.eval_shape(fn, *args, **kwargs)
        finally:
            pl_mod.pallas_call = real
            _clear_tile_caches()
    return captured


def _grid_points(grid):
    """Grid points to probe: exhaustive when small, corners otherwise."""
    if _prod(grid) <= _MAX_ENUM:
        return list(itertools.product(*(range(g) for g in grid))), True
    axes = [sorted({0, g // 2, g - 1}) for g in grid]
    return list(itertools.product(*axes)), False


def _check_spec(kind, label, block, index_map, full, elem_bytes, grid,
                points, exhaustive, want_cover):
    """Tiling + coverage checks for one (BlockSpec, operand) pair."""
    out = list(_tile_violations(f"{kind}.{label}", block, elem_bytes, full))
    if len(block) != len(full):
        out.append(f"{kind}.{label}: block rank {len(block)} != operand "
                   f"rank {len(full)}")
        return out
    if out:
        return out
    seen = set()
    for pt in points:
        try:
            idx = index_map(*pt)
        except TypeError:
            out.append(f"{kind}.{label}: index_map arity != grid rank "
                       f"{len(grid)}")
            return out
        idx = tuple(int(i) for i in idx)
        if len(idx) != len(block):
            out.append(f"{kind}.{label}: index_map returns {len(idx)} "
                       f"indices for a rank-{len(block)} block")
            return out
        for d, (i, b, f) in enumerate(zip(idx, block, full)):
            if i < 0 or (i + 1) * b > f:
                out.append(
                    f"{kind}.{label}: grid point {pt} reads block "
                    f"{idx} — dim {d} spans [{i * b}, {(i + 1) * b}) "
                    f"outside array dim {f}")
                return out
        seen.add(idx)
    if want_cover and exhaustive:
        tiles_needed = _prod(f // b for f, b in zip(full, block))
        if len(seen) != tiles_needed:
            out.append(
                f"{kind}.{label}: grid writes {len(seen)} distinct "
                f"blocks but the output has {tiles_needed} tiles — "
                "output not fully covered")
    return out


def check_launch(launch: KernelLaunch) -> list[str]:
    """All legality violations of one captured launch (empty == proved).

    Checks: Mosaic tiling of every in/out BlockSpec against its operand
    aval, grid x index_map block reads in bounds, every output tile
    written exactly once per pass, the double-buffered VMEM working set
    against :data:`BUDGET_BYTES`, and — for the fused matmul+normalize
    kernels — that the digit-axis scratch ``[K, bm, bn]`` covers every
    digit (K resident, never grid-tiled)."""
    kind = launch.kind
    out = []
    if len(launch.in_specs) != len(launch.operands):
        out.append(f"{kind}: {len(launch.in_specs)} in_specs for "
                   f"{len(launch.operands)} operands")
        return out
    if len(launch.out_specs) != len(launch.outs):
        out.append(f"{kind}: {len(launch.out_specs)} out_specs for "
                   f"{len(launch.outs)} outputs")
        return out
    points, exhaustive = _grid_points(launch.grid)
    for i, ((block, imap), (shape, _dt, eb)) in enumerate(
            zip(launch.in_specs, launch.operands)):
        out.extend(_check_spec(kind, f"in{i}", block, imap, shape, eb,
                               launch.grid, points, exhaustive, False))
    for i, ((block, imap), (shape, _dt, eb)) in enumerate(
            zip(launch.out_specs, launch.outs)):
        out.extend(_check_spec(kind, f"out{i}", block, imap, shape, eb,
                               launch.grid, points, exhaustive, True))
    vm = launch_vmem_bytes(launch)
    if vm > BUDGET_BYTES:
        out.append(f"{kind}: VMEM working set {vm} bytes exceeds the "
                   f"{BUDGET_BYTES}-byte per-core budget")
    b_idx = _RESIDENT_B_OPERAND.get(kind)
    if b_idx is not None and b_idx < len(launch.operands):
        K = launch.operands[b_idx][0][0]  # b_res [K, D, N] leading dim
        if not launch.scratch or launch.scratch[0][0][:1] != (K,):
            got = launch.scratch[0][0] if launch.scratch else None
            out.append(f"{kind}: digit-axis scratch is {got} — must be "
                       f"[K={K}, bm, bn] resident")
        if b_idx < len(launch.in_specs) and \
                launch.in_specs[b_idx][0][0] != K:
            out.append(
                f"{kind}: weight-residue block leading dim "
                f"{launch.in_specs[b_idx][0][0]} != K={K} — the digit "
                "axis must stay resident, not grid-tiled")
    return out


def launch_vmem_bytes(launch: KernelLaunch) -> int:
    """Double-buffered working set of a captured launch, in bytes."""
    streamed = sum(
        _prod(block) * eb
        for (block, _), (_, _, eb) in
        list(zip(launch.in_specs, launch.operands))
        + list(zip(launch.out_specs, launch.outs)))
    return 2 * streamed + sum(_prod(s) * eb for s, eb in launch.scratch)


# ---------------------------------------------------------------------------
# report layer: sweep kinds x shapes x configs, audit engines
# ---------------------------------------------------------------------------


def _profile_meta(kind, profile):
    """(n_digits, residue element bytes) for a (kind, profile) pair."""
    if kind == "flash_attention":
        return 1, 4
    from repro.core.moduli import get_profile

    p = get_profile(profile) if isinstance(profile, str) else profile
    return p.n_digits, (1 if p.int8_safe else 4)


def _capture_kind(kind, profile, shape, blocks) -> list[KernelLaunch]:
    """Capture the real wrapper's launches for one shape + block config."""
    import jax
    import jax.numpy as jnp

    def spec(s, dt):
        return jax.ShapeDtypeStruct(tuple(s), dt)

    n_digits, res_bytes = _profile_meta(kind, profile)
    rdt = jnp.int8 if res_bytes == 1 else jnp.int32
    if kind == "rns_matmul":
        from repro.kernels.rns_matmul.ops import rns_matmul

        M, D, N = shape
        return capture_launches(
            lambda a, b: rns_matmul(profile, a, b, **blocks),
            spec((n_digits, M, D), rdt), spec((n_digits, D, N), rdt))
    if kind == "rns_fused_encode_matmul":
        from repro.kernels.rns_fused.ops import rns_fused_encode_matmul

        M, D, N = shape
        return capture_launches(
            lambda x, s, b: rns_fused_encode_matmul(profile, x, s, b,
                                                    **blocks),
            spec((M, D), jnp.float32), spec((), jnp.float32),
            spec((n_digits, D, N), rdt))
    if kind == "rns_fused_matmul_normalize":
        from repro.kernels.rns_fused.ops import rns_fused_matmul_normalize

        M, D, N = shape
        return capture_launches(
            lambda a, b: rns_fused_matmul_normalize(profile, a, b, **blocks),
            spec((n_digits, M, D), rdt), spec((n_digits, D, N), rdt))
    if kind == "rns_fused_dot":
        from repro.kernels.rns_fused.ops import rns_fused_dot

        M, D, N = shape
        return capture_launches(
            lambda x, s, b: rns_fused_dot(profile, x, s, b, **blocks),
            spec((M, D), jnp.float32), spec((), jnp.float32),
            spec((n_digits, D, N), rdt))
    if kind == "rns_convert":
        from repro.kernels.rns_convert.ops import rns_convert

        (T,) = shape
        return capture_launches(
            lambda x, s: rns_convert(profile, x, s, out_dtype=rdt, **blocks),
            spec((T,), jnp.float32), spec((), jnp.float32))
    if kind == "rns_normalize":
        from repro.kernels.rns_normalize.ops import rns_normalize

        (T,) = shape
        return capture_launches(
            lambda r: rns_normalize(profile, r, **blocks),
            spec((n_digits, T), jnp.int32))
    if kind == "flash_attention":
        from repro.kernels.flash_attention.ops import flash_attention

        B, Tq, Tk, H, Hk, D, Dv = shape
        return capture_launches(
            lambda q, k, v: flash_attention(q, k, v, causal=True, **blocks),
            spec((B, Tq, H, D), jnp.float32),
            spec((B, Tk, Hk, D), jnp.float32),
            spec((B, Tk, Hk, Dv), jnp.float32))
    raise KeyError(f"unknown kernel kind {kind!r}")


def audit_config(kind, profile, shape, blocks, source="defaults") -> dict:
    """Audit ONE (kind, profile, shape, blocks) config, both layers.

    The closed-form model and the captured launches must *agree*: a
    config is ok only if the block dict validates, the wrapper builds
    (its own guard may refuse first — that failure is recorded, not
    raised), and every captured launch passes :func:`check_launch`.  The
    capture also cross-checks that the closed-form VMEM model is
    conservative (captured working set <= modeled)."""
    n_digits, res_bytes = _profile_meta(kind, profile)
    violations = list(validate_blocks(kind, blocks, n_digits=n_digits,
                                      res_bytes=res_bytes))
    entry = {
        "kind": kind, "profile": str(profile), "shape": list(shape),
        "source": source, "blocks": dict(blocks),
        "grid": None, "vmem_bytes": None, "n_launches": 0,
    }
    launches: list[KernelLaunch] = []
    try:
        launches = _capture_kind(kind, profile, shape, blocks)
    except ValueError as e:  # the wrapper guard refused the config
        violations.append(f"{kind}: wrapper refused to build: {e}")
    model_vm = None
    if not any("positive int" in v for v in violations):
        model_vm = vmem_bytes(kind, blocks, n_digits=n_digits,
                              res_bytes=res_bytes)
    for ln in launches:
        violations.extend(check_launch(ln))
        vm = launch_vmem_bytes(ln)
        entry["grid"] = list(ln.grid)
        entry["vmem_bytes"] = max(entry["vmem_bytes"] or 0, vm)
        if model_vm is not None and vm > model_vm:
            violations.append(
                f"{kind}: captured working set {vm} bytes exceeds the "
                f"closed-form model {model_vm} — the VMEM model is not "
                "conservative")
    if entry["vmem_bytes"] is None:
        entry["vmem_bytes"] = model_vm
    entry["n_launches"] = len(launches)
    # dedupe, preserving order (closed-form + capture often agree)
    entry["violations"] = list(dict.fromkeys(violations))
    entry["ok"] = not entry["violations"]
    return entry


@dataclasses.dataclass
class KernelAuditReport:
    """Result of a kernel-legality sweep (``audit_all`` / engine audit)."""

    ok: bool
    entries: list
    budget_bytes: int = BUDGET_BYTES

    @property
    def failed(self) -> list:
        return [e for e in self.entries if not e["ok"]]

    def summary(self) -> str:
        if self.ok:
            kinds = sorted({e["kind"] for e in self.entries})
            return (f"kernel audit: PROVED ({len(self.entries)} configs "
                    f"across {len(kinds)} kernels, all Mosaic-legal, "
                    f"VMEM <= {self.budget_bytes} bytes)")
        bad = self.failed
        head = bad[0]
        return (f"kernel audit: FAILED ({len(bad)}/{len(self.entries)} "
                f"configs illegal; first: {head['kind']} "
                f"{head['blocks']} [{head['source']}] — "
                f"{head['violations'][0]})")

    def table(self) -> str:
        rows = ["kind | profile | shape | source | blocks | vmem_bytes | ok"]
        for e in self.entries:
            blocks = ",".join(f"{k}={v}" for k, v in e["blocks"].items())
            shape = "x".join(str(s) for s in e["shape"])
            rows.append(
                f"{e['kind']} | {e['profile']} | {shape} | {e['source']} "
                f"| {blocks} | {e['vmem_bytes']} | "
                f"{'ok' if e['ok'] else 'FAIL: ' + e['violations'][0]}")
        return "\n".join(rows)

    def to_dict(self) -> dict:
        return {"ok": self.ok, "budget_bytes": self.budget_bytes,
                "summary": self.summary(), "entries": self.entries}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


def audit_all(profiles=("rns6", "rns9"), include_candidates=True,
              include_cache=True) -> KernelAuditReport:
    """Sweep every kernel family x shape bucket x block config.

    Configs audited per (kind, profile, shape): the autotune DEFAULTS,
    every autotune CANDIDATE (when ``include_candidates``), and any
    persisted autotune cache row for the kind (when ``include_cache``) —
    so a stale tuned row is proved or named just like the shipped
    search space.  flash_attention has no RNS profile; it is audited
    once under the pseudo-profile ``float32``."""
    from repro.kernels import autotune

    entries = []
    for kind, shapes in _AUDIT_SHAPES.items():
        profs = ("float32",) if kind == "flash_attention" else tuple(profiles)
        configs: list[tuple[str, dict]] = [
            ("defaults", dict(autotune.DEFAULTS[kind]))]
        if include_candidates:
            configs += [(f"candidate[{i}]", dict(c)) for i, c in
                        enumerate(autotune.CANDIDATES.get(kind, ()))]
        if include_cache:
            seen = {tuple(sorted(c.items())) for _, c in configs}
            for key, row in sorted(autotune._load().items()):
                if key.split("|", 1)[0] != kind:
                    continue
                blocks = dict(autotune.DEFAULTS[kind], **row["blocks"])
                if tuple(sorted(blocks.items())) not in seen:
                    seen.add(tuple(sorted(blocks.items())))
                    configs.append((f"cache[{key}]", blocks))
        for prof in profs:
            for shape in shapes:
                for source, blocks in configs:
                    entries.append(
                        audit_config(kind, prof, shape, blocks, source))
    return KernelAuditReport(ok=all(e["ok"] for e in entries),
                             entries=entries)


def audit_engine_kernels(engine) -> KernelAuditReport:
    """Audit the pallas launches of a built engine's own jitted phases.

    Captures each ``engine._trace_specs()`` closure — the exact programs
    the engine serves — and checks every recorded launch.  An engine
    whose backend never lowers to Pallas (reference) records zero
    launches and is trivially proved.  This is the kernel half of the
    ``ServeConfig(audit=True)`` build gate."""
    entries = []
    for phase, (fn, args) in engine._trace_specs().items():
        try:
            launches = capture_launches(fn, *args)
        except ValueError as e:
            entries.append({
                "kind": f"engine.{phase}", "profile": None,
                "shape": [], "source": "engine", "blocks": {},
                "grid": None, "vmem_bytes": None, "n_launches": 0,
                "violations": [f"engine phase {phase!r} refused to "
                               f"build: {e}"],
                "ok": False})
            continue
        violations = []
        vmem = None
        for ln in launches:
            for v in check_launch(ln):
                violations.append(f"[{ln.kind}] {v}")
            vmem = max(vmem or 0, launch_vmem_bytes(ln))
        entries.append({
            "kind": f"engine.{phase}", "profile": None, "shape": [],
            "source": "engine",
            "blocks": {}, "grid": None, "vmem_bytes": vmem,
            "n_launches": len(launches),
            "violations": list(dict.fromkeys(violations)),
            "ok": not violations})
    return KernelAuditReport(ok=all(e["ok"] for e in entries),
                             entries=entries)
