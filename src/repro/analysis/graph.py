"""Dataflow-graph capture for the static exactness auditor.

:class:`GraphRecorder` is the object :func:`repro.core.dispatch.record_ops`
installs: every primitive/composite reports ``(kind, out, ins, **meta)``
with the *operand objects themselves* — abstract tracers under
``jax.eval_shape`` — and the recorder links consumers to producers by
object identity (``id``), keeping strong references so ids stay unique
for the life of the capture.  Ledger-level call sites additionally
``annotate`` digit arrays with ground-truth ``mag_bits`` (resident
weights have no recorded producer; dtype casts break identity chains,
so they carry a ``base`` alias back to the original digits object).

The result is an :class:`OpGraph`: ordered :class:`OpNode` entries
(execution order — producers always precede consumers), an annotation
table, and an alias table.  Bound propagation lives in
:mod:`repro.analysis.ledger_audit`; this module only captures structure.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core import dispatch

__all__ = ["OpNode", "OpGraph", "GraphRecorder", "trace_graph"]

#: OpCounts fields a node's ``tallies`` metadata may carry — the graph's
#: structural-count prediction sums exactly these.
COUNT_FIELDS = ("converts", "matmuls", "normalizes", "fused", "fallbacks",
                "weight_converts")


@dataclasses.dataclass
class OpNode:
    """One recorded op.  ``out_id``/``in_ids`` are object identities of
    the produced/consumed arrays (None for marker events); bound fields
    are filled by the auditor's propagation pass."""

    idx: int
    kind: str
    site: str
    profile: str | None
    meta: dict
    out_id: int | None
    in_ids: tuple[int, ...]
    # --- filled by ledger_audit.propagate_bounds ---
    in_bits: tuple = ()
    out_bits: float | None = None    # worst-case log2|X| reached in this op
    limit: float | None = None       # ledger_limit_bits(profile)
    headroom: float | None = None    # limit - out_bits

    def describe(self) -> str:
        extra = ""
        if self.out_bits is not None:
            extra = (f" out_bits={self.out_bits:.1f}"
                     f" headroom={self.headroom:+.1f}")
        return f"{self.kind}[{self.profile or '-'}] @ {self.site}{extra}"


@dataclasses.dataclass
class OpGraph:
    """Execution-ordered op nodes + identity-keyed annotations/aliases."""

    nodes: list
    annotations: dict      # id(arr) -> {mag_bits, profile, frac_exp, role}
    aliases: dict          # id(cast_arr) -> id(base_arr)
    traced_counts: dispatch.OpCounts | None = None

    def producers(self) -> dict:
        """id(out array) -> producing node (unique: ids are kept alive)."""
        return {n.out_id: n for n in self.nodes if n.out_id is not None}

    def counts(self) -> dict:
        """Structural op counts predicted from the recorded tallies."""
        out = dict.fromkeys(COUNT_FIELDS, 0)
        for n in self.nodes:
            for k, v in n.meta.get("tallies", {}).items():
                out[k] += v
        return out

    def counts_match_traced(self) -> bool:
        """Graph-derived counts vs the independently tallied OpCounts of
        the same trace — divergence means the recorder or the counters
        have a bug."""
        if self.traced_counts is None:
            return True
        c = self.counts()
        return all(getattr(self.traced_counts, f) == c[f]
                   for f in COUNT_FIELDS)


class GraphRecorder:
    """Duck-typed recorder for :func:`dispatch.record_ops`."""

    def __init__(self):
        self._nodes: list[OpNode] = []
        self._annotations: dict[int, dict] = {}
        self._aliases: dict[int, int] = {}
        self._keep: list = []        # pin object identities for the capture

    # --- dispatch-facing protocol -----------------------------------------
    def record(self, kind, out, ins, *, site, **meta):
        self._keep.append((out, ins))
        self._nodes.append(OpNode(
            idx=len(self._nodes), kind=kind, site=site,
            profile=meta.pop("profile", None), meta=meta,
            out_id=None if out is None else id(out),
            in_ids=tuple(id(x) for x in ins)))

    def annotate(self, arr, *, base=None, **meta):
        self._keep.append(arr)
        if base is not None:
            self._keep.append(base)
            if base is not arr:
                self._aliases[id(arr)] = id(base)
        self._annotations.setdefault(id(arr), {}).update(meta)

    # --- result -----------------------------------------------------------
    def graph(self, traced_counts=None) -> OpGraph:
        return OpGraph(nodes=self._nodes, annotations=self._annotations,
                       aliases=self._aliases, traced_counts=traced_counts)


def trace_graph(fn, *args, **kwargs) -> OpGraph:
    """Capture ``fn``'s residue-op dataflow graph abstractly (no FLOPs),
    with an independent :class:`~repro.core.dispatch.OpCounts` tally of
    the SAME trace attached for cross-checking."""
    rec = GraphRecorder()
    with dispatch.record_ops(rec), dispatch.count_ops() as c:
        jax.eval_shape(fn, *args, **kwargs)
    return rec.graph(traced_counts=c)
