"""Static RNS exactness auditor — prove the ledger, don't run the model.

The runtime magnitude ledger (``core/tensor.py``) enforces exactness one
op at a time, while tracing.  This pass proves it for a whole entry
point ahead of time: capture the residue dataflow graph abstractly
(:func:`repro.analysis.graph.trace_graph` — ``jax.eval_shape`` under the
dispatch recorder, zero FLOPs), then propagate worst-case ``log2|X|``
bounds forward through the graph with the SAME shared formulas the
runtime uses (:func:`repro.core.tensor.dot_out_bits` against
:func:`repro.core.tensor.ledger_limit_bits`) and check every
residue-bearing op.

What comes out (:class:`AuditReport`):

* a proof (or named counterexample) that no op exceeds
  ``signed_bits - _SAFETY_BITS`` for its profile;
* the minimum-headroom critical path and a per-site headroom table;
* propagated-vs-annotated bound cross-checks (the recorder carries the
  runtime ledger's own numbers as annotations — divergence is a bug in
  one of them) and graph-vs-``OpCounts`` structural count cross-checks;
* reference-backend fallbacks by site and reason (no longer a bare
  counter);
* *missed deferrals*: with ``defer`` off, the deferred variant of the
  same engine is audited too — if its bounds prove exact, the normalize
  ops it saves were provably unnecessary;
* resident profile validation: the stored amortized ledger bounds and
  the per-layer profile selections re-checked against column sums
  recomputed from the master weights.

Entry points: :func:`audit_fn` (any traceable callable),
:func:`audit_engine` (a built Engine/ContinuousEngine),
:func:`audit_serve` (params + configs).  Surfaced by
``launch/analyze.py --audit`` and ``ServeConfig(audit=True)``.
"""

from __future__ import annotations

import dataclasses
import json
import math

import jax

from repro.analysis.graph import COUNT_FIELDS, GraphRecorder, OpGraph
from repro.core import dispatch
from repro.core.tensor import dot_out_bits, ledger_limit_bits

__all__ = ["PhaseAudit", "AuditReport", "audit_fn", "audit_engine",
           "audit_serve", "propagate_bounds", "validate_resident"]

_TOL = 1e-9          # float slack on the limit comparison (matches runtime >)
_AGREE_TOL = 1e-6    # propagated vs annotated bounds must agree to this


# ------------------------------------------------------- propagation ----
def propagate_bounds(g: OpGraph) -> list[dict]:
    """Forward worst-case bit-bound propagation over a captured graph.

    Mutates each node's ``in_bits/out_bits/limit/headroom`` in place and
    returns the violations: ``overflow`` (a bound exceeds the profile's
    ledger limit — the exactness proof fails), ``unresolved`` (an operand
    bound could not be derived — the proof is incomplete), and
    ``bound-mismatch`` (propagation disagrees with the runtime ledger's
    annotation — a bug in one of them).
    """
    producers = g.producers()
    violations: list[dict] = []

    def resolve(oid):
        """(bits, how) for an operand id: runtime annotation wins, then
        the producing node's propagated bound, then alias chains."""
        seen = set()
        while oid is not None and oid not in seen:
            seen.add(oid)
            ann = g.annotations.get(oid, {})
            node = producers.get(oid)
            ann_bits = ann.get("mag_bits")
            node_bits = node.out_bits if node is not None else None
            if ann_bits is not None and node_bits is not None \
                    and abs(ann_bits - node_bits) > _AGREE_TOL:
                return float(ann_bits), "conflict"
            if ann_bits is not None:
                return float(ann_bits), "annotation"
            if node_bits is not None:
                return node_bits, "node"
            oid = g.aliases.get(oid)
        return None, None

    def operand(n, pos):
        bits, how = resolve(n.in_ids[pos]) if pos < len(n.in_ids) else (None,
                                                                        None)
        if how == "conflict":
            violations.append({
                "kind": "bound-mismatch", "op": n.kind, "site": n.site,
                "profile": n.profile,
                "detail": f"operand {pos}: runtime annotation disagrees "
                          f"with propagated bound"})
        if bits is None:
            violations.append({
                "kind": "unresolved", "op": n.kind, "site": n.site,
                "profile": n.profile,
                "detail": f"operand {pos} has no derivable bit bound"})
        return bits

    for n in g.nodes:
        if n.kind in ("fallback", "renormalize"):
            continue
        if n.kind == "convert":
            n.out_bits = float(n.meta["bits"] - 1)
        elif n.kind == "matmul":
            a, w = operand(n, 0), operand(n, 1)
            n.in_bits = (a, w)
            if a is None or w is None:
                continue
            n.out_bits = dot_out_bits(a, w, n.meta["contract_dim"])
        elif n.kind in ("fused_encode_matmul", "fused_dot"):
            w = operand(n, 1)
            n.in_bits = (float(n.meta["bits"] - 1), w)
            if w is None:
                continue
            n.out_bits = dot_out_bits(n.in_bits[0], w,
                                      n.meta["contract_dim"])
        elif n.kind == "fused_matmul_normalize":
            a, w = operand(n, 0), operand(n, 1)
            n.in_bits = (a, w)
            if a is None or w is None:
                continue
            n.out_bits = dot_out_bits(a, w, n.meta["contract_dim"])
        elif n.kind == "normalize":
            a = operand(n, 0)
            n.in_bits = (a,)
            if a is None:
                continue
            n.out_bits = a       # peak magnitude being MRC-decoded
        elif n.kind == "pac_mul":
            a, b = operand(n, 0), operand(n, 1)
            n.in_bits = (a, b)
            if a is None or b is None:
                continue
            n.out_bits = a + b
        elif n.kind == "pac_add":
            a, b = operand(n, 0), operand(n, 1)
            n.in_bits = (a, b)
            if a is None or b is None:
                continue
            n.out_bits = max(a, b) + 1.0
        else:                    # unknown kinds: structural only
            continue
        if n.profile is not None and n.out_bits is not None:
            n.limit = ledger_limit_bits(n.profile)
            n.headroom = n.limit - n.out_bits
            if n.out_bits > n.limit + _TOL:
                violations.append({
                    "kind": "overflow", "op": n.kind, "site": n.site,
                    "profile": n.profile, "out_bits": n.out_bits,
                    "limit": n.limit,
                    "detail": f"worst-case log2|X| = {n.out_bits:.2f} "
                              f"exceeds ledger limit {n.limit:.2f}"})
        # cross-check the runtime ledger's own bound for this output
        if n.out_id is not None and n.out_bits is not None:
            ann = g.annotations.get(n.out_id, {})
            if ann.get("mag_bits") is not None \
                    and abs(ann["mag_bits"] - n.out_bits) > _AGREE_TOL:
                violations.append({
                    "kind": "bound-mismatch", "op": n.kind, "site": n.site,
                    "profile": n.profile,
                    "detail": f"propagated {n.out_bits:.3f} != runtime "
                              f"ledger {ann['mag_bits']:.3f}"})
    return violations


def _critical_path(g: OpGraph) -> list:
    """Producer chain ending at the minimum-headroom node."""
    bounded = [n for n in g.nodes if n.headroom is not None]
    if not bounded:
        return []
    producers = g.producers()
    path = [min(bounded, key=lambda n: n.headroom)]
    seen = {path[0].idx}
    while True:
        cur, best = path[-1], None
        for oid in cur.in_ids:
            p = producers.get(oid) or producers.get(g.aliases.get(oid))
            if p is not None and p.idx not in seen \
                    and p.headroom is not None \
                    and (best is None or p.headroom < best.headroom):
                best = p
        if best is None:
            return list(reversed(path))
        seen.add(best.idx)
        path.append(best)


def _headroom_table(g: OpGraph) -> list[dict]:
    rows: dict[tuple, dict] = {}
    for n in g.nodes:
        if n.headroom is None:
            continue
        r = rows.setdefault((n.site, n.profile), {
            "site": n.site, "profile": n.profile, "ops": 0,
            "max_out_bits": -math.inf, "limit": n.limit,
            "min_headroom": math.inf})
        r["ops"] += 1
        r["max_out_bits"] = max(r["max_out_bits"], n.out_bits)
        r["min_headroom"] = min(r["min_headroom"], n.headroom)
    return sorted(rows.values(), key=lambda r: r["min_headroom"])


# ------------------------------------------------------ phase audits ----
@dataclasses.dataclass
class PhaseAudit:
    """Audit of one traced entry point (one jitted phase of an engine)."""

    name: str
    ok: bool
    n_ops: int = 0
    counts: dict = dataclasses.field(default_factory=dict)
    traced_counts: dict = dataclasses.field(default_factory=dict)
    counts_match: bool = True
    violations: list = dataclasses.field(default_factory=list)
    min_headroom: float | None = None
    critical_path: list = dataclasses.field(default_factory=list)
    headroom: list = dataclasses.field(default_factory=list)
    fallbacks: list = dataclasses.field(default_factory=list)
    renormalizes: int = 0
    error: str | None = None
    error_site: dict | None = None


_CORE_PREFIXES = ("core/", "kernels/")


def _blame(tb) -> dict:
    """Name the failing layer (deepest model/serve frame) and op (deepest
    core frame) from a trace-time ledger exception."""
    layer = op = None
    while tb is not None:
        fname = tb.tb_frame.f_code.co_filename.replace("\\", "/")
        if "/repro/" in fname:
            rel = fname.rsplit("/repro/", 1)[1]
            label = f"{rel}:{tb.tb_frame.f_code.co_name}"
            if rel.startswith(_CORE_PREFIXES):
                op = label
            elif not rel.startswith("analysis/"):
                layer = label
        tb = tb.tb_next
    return {"layer": layer, "op": op}


def _audit_graph(name: str, g: OpGraph) -> PhaseAudit:
    violations = propagate_bounds(g)
    counts = g.counts()
    traced = {f: getattr(g.traced_counts, f) for f in COUNT_FIELDS} \
        if g.traced_counts is not None else {}
    counts_match = g.counts_match_traced()
    if not counts_match:
        violations.append({
            "kind": "count-mismatch", "op": "-", "site": "-", "profile": None,
            "detail": f"graph-derived counts {counts} != traced {traced}"})
    fb: dict[tuple, int] = {}
    for n in g.nodes:
        if n.kind == "fallback":
            key = (n.site, n.meta.get("reason", "?"))
            fb[key] = fb.get(key, 0) + 1
    headrooms = [n.headroom for n in g.nodes if n.headroom is not None]
    return PhaseAudit(
        name=name, ok=not violations, n_ops=len(g.nodes), counts=counts,
        traced_counts=traced, counts_match=counts_match,
        violations=violations,
        min_headroom=min(headrooms) if headrooms else None,
        critical_path=[n.describe() for n in _critical_path(g)],
        headroom=_headroom_table(g),
        fallbacks=[{"site": s, "reason": r, "count": c}
                   for (s, r), c in sorted(fb.items())],
        renormalizes=sum(1 for n in g.nodes if n.kind == "renormalize"))


def audit_phase(name: str, fn, *args, **kwargs) -> PhaseAudit:
    """Trace one entry point abstractly and audit its graph.  A ledger
    error raised *during* the trace (the runtime check caught it first)
    becomes a failed phase naming the layer and op."""
    from repro.analysis.kernel_audit import BlockConfigError

    rec = GraphRecorder()
    try:
        with dispatch.record_ops(rec), dispatch.count_ops() as c:
            jax.eval_shape(fn, *args, **kwargs)
    except BlockConfigError:
        raise          # a tile-legality refusal: the kernel auditor's case
    except ValueError as e:
        return PhaseAudit(name=name, ok=False, n_ops=len(rec.graph().nodes),
                          error=str(e), error_site=_blame(e.__traceback__))
    return _audit_graph(name, rec.graph(traced_counts=c))


# ---------------------------------------------------- resident checks ----
def validate_resident(params, rns) -> list[dict]:
    """Re-derive every resident weight's ledger entry from first
    principles and check the stored amortized bound and the selected
    profile against it — the auditor does not trust the encode-time
    column-sum heuristic, it re-proves it.

    Per weight: the stored ``mag_bits`` must reconstruct a column-sum
    bound no smaller than one recomputed from the float master (when the
    master is still in the tree), and the per-op product summation
    ``dot_out_bits(qx-1, mag_bits, D_in)`` must fit the selected
    profile.  Per gated layer: the deferred-chain worst case
    ``(qx-1)+cb_wi+(qx-1)+cb_wo`` must fit too (the bound
    ``models/resident._select_profile`` sized the profile for).
    """
    from repro.models import resident as R

    if rns is None:
        return []
    entries: list[dict] = []
    qx = float(rns.qx - 1)

    def check_mlp(mlp, path):
        names = [n for n in R._MLP_WEIGHTS if n in mlp
                 and isinstance(mlp[n], dict) and "w_res" in mlp[n]]
        cb: dict[str, float] = {}
        for name in names:
            w_res = mlp[name]["w_res"]
            d_in = int(w_res.digits.shape[-2])
            lim = ledger_limit_bits(w_res.profile)
            cb[name] = w_res.mag_bits + math.log2(max(d_in, 1))
            e = {"path": "/".join(path + (name,)),
                 "profile": w_res.profile, "d_in": d_in,
                 "mag_bits": w_res.mag_bits, "limit": lim,
                 "need": dot_out_bits(qx, w_res.mag_bits, d_in),
                 "ok": True, "detail": ""}
            if e["need"] > lim + _TOL:
                e["ok"] = False
                e["detail"] = (f"per-op product summation needs "
                               f"{e['need']:.2f} bits > limit {lim:.2f}")
            master = mlp[name].get("w")
            if e["ok"] and master is not None \
                    and not isinstance(master, jax.core.Tracer):
                true_cb = R._colsum_bits(master, rns.qw)
                if true_cb > cb[name] + _AGREE_TOL:
                    e["ok"] = False
                    e["detail"] = (
                        f"stored ledger bound (colsum 2^{cb[name]:.2f}) "
                        f"under-approximates the master's recomputed "
                        f"column sum 2^{true_cb:.2f}")
            entries.append(e)
        if "wi" in cb and "wo" in cb and "wg" in cb:
            lim = ledger_limit_bits(mlp["wi"]["w_res"].profile)
            chain = qx + cb["wi"] + qx + cb["wo"]
            entries.append({
                "path": "/".join(path) or "<root>",
                "profile": mlp["wi"]["w_res"].profile, "d_in": None,
                "mag_bits": None, "need": chain, "limit": lim,
                "ok": chain <= lim + _TOL,
                "detail": "" if chain <= lim + _TOL else
                          f"deferred gated chain needs {chain:.2f} bits "
                          f"> limit {lim:.2f}"})
        return mlp

    R._walk_mlps(params, check_mlp)
    return entries


# ------------------------------------------------------- full reports ----
@dataclasses.dataclass
class AuditReport:
    """Everything the static pass can say about one configuration."""

    ok: bool
    phases: list
    resident: list = dataclasses.field(default_factory=list)
    missed_deferrals: list = dataclasses.field(default_factory=list)
    config: dict = dataclasses.field(default_factory=dict)

    @property
    def min_headroom(self) -> float | None:
        hs = [p.min_headroom for p in self.phases
              if p.min_headroom is not None]
        return min(hs) if hs else None

    def to_dict(self) -> dict:
        return {"ok": self.ok, "min_headroom": self.min_headroom,
                "config": self.config,
                "phases": [dataclasses.asdict(p) for p in self.phases],
                "resident": self.resident,
                "missed_deferrals": self.missed_deferrals}

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), indent=kw.pop("indent", 2), **kw)

    def summary(self) -> str:
        if self.ok:
            h = self.min_headroom
            extra = f" (min headroom {h:+.1f} bits)" if h is not None else ""
            return f"exactness audit: PROVED{extra}"
        lines = ["exactness audit: FAILED"]
        for p in self.phases:
            if p.error:
                site = p.error_site or {}
                lines.append(f"  phase {p.name}: ledger error in layer "
                             f"{site.get('layer')} at op {site.get('op')}: "
                             f"{p.error}")
            for v in p.violations:
                lines.append(f"  phase {p.name}: {v['kind']} at {v['op']} "
                             f"({v['site']}): {v['detail']}")
        for r in self.resident:
            if not r["ok"]:
                lines.append(f"  resident {r['path']}: {r['detail']}")
        return "\n".join(lines)

    def table(self) -> str:
        """Human-readable report (the --audit CLI output)."""
        out = [self.summary()]
        if self.config:
            out.append("config: " + ", ".join(
                f"{k}={v}" for k, v in self.config.items()))
        for p in self.phases:
            if p.error:
                continue
            c = ", ".join(f"{k}={v}" for k, v in p.counts.items() if v)
            out.append(f"\nphase {p.name}: {p.n_ops} recorded ops "
                       f"[{c or 'no residue ops'}] counts_match="
                       f"{p.counts_match} renormalizes={p.renormalizes}")
            if p.headroom:
                out.append(f"  {'site':<58} {'profile':<8} {'ops':>4} "
                           f"{'bits':>6} {'limit':>6} {'headroom':>8}")
                for r in p.headroom:
                    out.append(f"  {r['site'][:58]:<58} {r['profile']:<8} "
                               f"{r['ops']:>4} {r['max_out_bits']:>6.1f} "
                               f"{r['limit']:>6.1f} "
                               f"{r['min_headroom']:>+8.1f}")
            if p.critical_path:
                out.append("  critical path (ends at min headroom):")
                out.extend(f"    {s}" for s in p.critical_path)
            for f in p.fallbacks:
                out.append(f"  fallback x{f['count']}: {f['reason']} "
                           f"at {f['site']}")
        if self.resident:
            n_bad = sum(1 for r in self.resident if not r["ok"])
            out.append(f"\nresident ledger entries: "
                       f"{len(self.resident) - n_bad}/{len(self.resident)} "
                       f"re-proved from masters")
        for m in self.missed_deferrals:
            out.append(f"missed deferral [{m['phase']}]: deferring would "
                       f"save {m['saved']} of {m['normalizes']} normalizes "
                       f"(bounds prove the deferred chain exact)")
        return "\n".join(out)


def audit_fn(fn, *args, name: str = "trace", **kwargs) -> AuditReport:
    """Audit any traceable entry point (a layer fn, ``model.prefill``,
    ``decode_step``, ``mixed_step``, ...) on example/abstract args."""
    return AuditReport(phases=[ph := audit_phase(name, fn, *args, **kwargs)],
                       ok=ph.ok)


def _missed_deferrals(engine, phases) -> list[dict]:
    """With deferral off, audit the defer=True variant of the SAME engine
    traces; normalizes it saves while staying provably exact were
    unnecessary.  (Config-level by design: between a decode/encode pair
    the floats may pass through nonlinearities the graph cannot see, so
    node-level "this normalize was avoidable" claims would be guesses —
    re-proving the deferred configuration is not.)"""
    cfg = engine.cfg
    rns = getattr(cfg, "rns", None)
    if rns is None or getattr(rns, "defer", False):
        return []
    out: list[dict] = []
    engine.cfg = dataclasses.replace(
        cfg, rns=dataclasses.replace(rns, defer=True))
    try:
        specs = engine._trace_specs()
        for p in phases:
            if not p.ok or p.name not in specs:
                continue
            fn, args = specs[p.name]
            dp = audit_phase(p.name, fn, *args)
            saved = p.counts.get("normalizes", 0) - dp.counts.get(
                "normalizes", 0)
            if dp.ok and saved > 0:
                out.append({"phase": p.name,
                            "normalizes": p.counts["normalizes"],
                            "deferred_normalizes": dp.counts["normalizes"],
                            "saved": saved})
    finally:
        engine.cfg = cfg
    return out


def _describe(engine) -> dict:
    scfg = getattr(engine, "scfg", None)
    rns = getattr(engine.cfg, "rns", None)
    d = {"arch": getattr(engine.cfg, "arch_id",
                         getattr(engine.cfg, "name", "?")),
         "rns": getattr(rns, "profile", None),
         "defer": getattr(rns, "defer", None)}
    if scfg is not None:
        d.update(backend=scfg.rns_backend,
                 resident=scfg.resident_weights,
                 per_layer_profiles=scfg.per_layer_profiles,
                 chunked=getattr(scfg, "chunked_prefill", False),
                 spec=getattr(scfg, "spec_decode", False),
                 prefix=getattr(scfg, "prefix_cache", False))
    return d


def audit_engine(engine) -> AuditReport:
    """Audit every jitted phase of a built Engine/ContinuousEngine — the
    exact trace closures ``_rns_ops`` counts (``_trace_specs``), so the
    audit's structural predictions and the engine's reported counts are
    claims about the same program."""
    phases = [audit_phase(n, fn, *args)
              for n, (fn, args) in engine._trace_specs().items()]
    resident = validate_resident(engine.params, getattr(engine.cfg, "rns",
                                                        None))
    ok = all(p.ok for p in phases) and all(r["ok"] for r in resident)
    return AuditReport(ok=ok, phases=phases, resident=resident,
                       missed_deferrals=_missed_deferrals(engine, phases),
                       config=_describe(engine))


def audit_serve(params, model_cfg, scfg=None) -> AuditReport:
    """Audit a whole ServeConfig: build the continuous engine (weights
    encode, schedules size themselves) and audit its phases."""
    from repro.serve.engine import ContinuousEngine, ServeConfig

    if scfg is None:
        scfg = ServeConfig(max_cache=64)
    if scfg.audit:
        # the build-time hook would recurse into this very audit
        scfg = dataclasses.replace(scfg, audit=False)
    return audit_engine(ContinuousEngine(params, model_cfg, scfg))
