"""Static jit compile-churn prover for the serving engines.

An engine's steady-state throughput rests on a claim the tests can only
check *after the fact* (``_cache_size() == 1`` pins): that the jitted
step functions are traced once and replayed forever, no matter what
traffic arrives.  A single leaf whose dtype, ``weak_type``, or shape
varies with traffic re-keys the jit cache and silently recompiles every
step.  This module proves the claim ahead of time:

* :func:`arg_signature` computes exactly what the jit cache keys on for
  a concrete argument pytree — the treedef plus every leaf's
  ``(shape, dtype, weak_type)`` aval (via
  ``jax.api_util.shaped_abstractify``, so python scalars show their
  weak types the same way they would at a real call site);
* :func:`traffic_family` generates a family of traffic variants (fill
  values, prompt lengths) spanning what the engine will see;
* :func:`audit_traces` rebuilds every ``engine._trace_specs(traffic=t)``
  step closure per variant and proves each phase's argument signature
  is INVARIANT across the family.  Any drift is named down to the leaf
  (``leaf 3: 2x8:int32 -> 2x9:int32``) at audit time, instead of
  surfacing as a mystery recompile in production.

The contract this enforces (see ``docs/analysis.md``): a phase's jit
cache key is a pure function of the engine *config*, never of the
traffic.  The bucketed ``Engine`` recompiles per (B, T) bucket BY
DESIGN; its spec pins invariance within a bucket, which is what its
``_trace_specs`` models.
"""

from __future__ import annotations

import dataclasses
import json

__all__ = [
    "PhaseTraceAudit",
    "TraceAuditReport",
    "arg_signature",
    "audit_traces",
    "describe_signature",
    "traffic_family",
]


def arg_signature(args):
    """The jit cache key of an argument tuple: ``(treedef, leaf avals)``.

    Leaf avals are ``(shape, dtype, weak_type)`` triples — the three
    degrees of freedom that re-key a jit trace.  Two calls with equal
    signatures hit the same compiled executable."""
    import jax

    flat, treedef = jax.tree_util.tree_flatten(args)
    leaves = []
    for leaf in flat:
        aval = jax.api_util.shaped_abstractify(leaf)
        leaves.append((tuple(aval.shape), str(aval.dtype),
                       bool(getattr(aval, "weak_type", False))))
    return (str(treedef), tuple(leaves))


def describe_signature(sig) -> str:
    """Human-readable leaf list: ``2x8:int32``, ``scalar:float32~``
    (``~`` marks a weak type, the silent re-trace trigger)."""
    _, leaves = sig
    out = []
    for shape, dtype, weak in leaves:
        dims = "x".join(str(d) for d in shape) if shape else "scalar"
        out.append(f"{dims}:{dtype}" + ("~" if weak else ""))
    return f"{len(leaves)} leaves: " + ", ".join(out)


def traffic_family(engine) -> list[dict]:
    """Traffic variants spanning what this engine's phases will see:
    prompt lengths from 1 to the prompt pad, with varying token fill."""
    pad = int(getattr(engine, "prompt_pad", 8))
    lengths = sorted({1, 2, max(1, pad // 2), max(1, pad - 1), pad})
    fills = (0, 1, 7)
    return [{"fill": fills[i % len(fills)], "length": L}
            for i, L in enumerate(lengths)]


def _leaf_drift(a, b) -> list[str]:
    """Leaf-wise description of how signature ``b`` diverges from ``a``."""
    out = []
    if a[0] != b[0]:
        out.append("argument tree structure differs between variants")
    la, lb = a[1], b[1]
    if len(la) != len(lb):
        out.append(f"{len(la)} leaves vs {len(lb)} leaves")
        return out
    for i, (x, y) in enumerate(zip(la, lb)):
        if x == y:
            continue
        def fmt(t):
            dims = "x".join(str(d) for d in t[0]) if t[0] else "scalar"
            return f"{dims}:{t[1]}" + ("~" if t[2] else "")
        out.append(f"leaf {i}: {fmt(x)} -> {fmt(y)}")
    return out


@dataclasses.dataclass
class PhaseTraceAudit:
    """One phase's invariance proof (or the drift that broke it)."""

    phase: str
    ok: bool
    n_variants: int
    signature: str          # the (single, when ok) argument signature
    drift: list

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TraceAuditReport:
    """Result of :func:`audit_traces` over one engine."""

    ok: bool
    phases: list
    n_variants: int

    @property
    def failed(self) -> list:
        return [p for p in self.phases if not p.ok]

    def summary(self) -> str:
        if self.ok:
            return (f"trace audit: PROVED ({len(self.phases)} phases x "
                    f"{self.n_variants} traffic variants, one jit "
                    "signature each)")
        bad = self.failed[0]
        return (f"trace audit: FAILED (phase {bad.phase!r} re-keys the "
                f"jit cache across traffic: {'; '.join(bad.drift[:3])})")

    def table(self) -> str:
        rows = ["phase | variants | ok | signature"]
        for p in self.phases:
            state = "ok" if p.ok else "DRIFT: " + "; ".join(p.drift[:2])
            rows.append(f"{p.phase} | {p.n_variants} | {state} | "
                        f"{p.signature}")
        return "\n".join(rows)

    def to_dict(self) -> dict:
        return {"ok": self.ok, "n_variants": self.n_variants,
                "summary": self.summary(),
                "phases": [p.to_dict() for p in self.phases]}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


def audit_traces(engine, family=None) -> TraceAuditReport:
    """Prove every engine phase's jit signature is traffic-invariant.

    Rebuilds ``engine._trace_specs(traffic=t)`` for each variant in the
    family and compares each phase's :func:`arg_signature` — the exact
    cache key jit sees.  Zero FLOPs, zero traces: only the argument
    avals are inspected.  A phase that would retrace on real traffic is
    reported with the drifting leaf named."""
    fam = list(family) if family is not None else traffic_family(engine)
    per_phase: dict[str, list] = {}
    for t in fam:
        for phase, (_fn, args) in engine._trace_specs(traffic=t).items():
            per_phase.setdefault(phase, []).append(arg_signature(args))
    phases = []
    for phase, sigs in per_phase.items():
        uniq = list(dict.fromkeys(sigs))
        drift = []
        if len(sigs) != len(fam):
            drift.append(f"phase present in only {len(sigs)}/{len(fam)} "
                         "traffic variants")
        for other in uniq[1:]:
            drift.extend(_leaf_drift(uniq[0], other))
        phases.append(PhaseTraceAudit(
            phase=phase, ok=not drift, n_variants=len(sigs),
            signature=describe_signature(uniq[0]), drift=drift))
    return TraceAuditReport(ok=all(p.ok for p in phases), phases=phases,
                            n_variants=len(fam))
