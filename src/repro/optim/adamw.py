"""AdamW + schedules + global-norm clipping (functional, partitionable).

Optimizer state mirrors the param tree (same sharding specs apply), so
FSDP shards m/v alongside the weights — the ZeRO property.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"       # cosine|linear|constant
    min_lr_ratio: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        if cfg.schedule == "cosine":
            decay = 0.5 * (1 + jnp.cos(jnp.pi * t))
        else:
            decay = 1.0 - t
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * decay
    return cfg.lr * warm * decay


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m2.astype(m.dtype), v2.astype(v.dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gn, "lr": lr}
