"""Continuous-batching request scheduler (host-side policy, no jax).

One :class:`Scheduler` owns the page pool and the slot map and makes the
in-flight-batching decisions each engine step:

  * **growth** — running sequences get the page(s) the tokens they write
    next will land in; with speculative decoding the write window is
    ``lookahead`` tokens wide, so the first page is preemption-backed
    (required for the guaranteed one-token-per-step progress) and the
    rest are best-effort (draft KV past the allocated pages goes to the
    trash page and the engine caps acceptance).  Running rows always
    outrank new admissions for pages.
  * **copy-on-write** — a row about to write into a *shared* page
    (refcount > 1: the prefix index and/or other rows hold it) first
    splits it: a fresh page is allocated, the engine copies the contents
    on device (``kv_cache.copy_pages``), and the row's block table is
    repointed.  The shared original stays frozen for its other holders.
  * **preemption** — when the pool is exhausted, the *youngest* running
    sequence (LIFO, the vLLM recompute policy) is evicted: its pages are
    freed and the request returns to the *front* of the waiting queue.
    Re-admission re-prefills from the original prompt; greedy decoding
    makes the regenerated tokens identical to the uninterrupted run
    (asserted in tests/test_serve_continuous.py).  A sequence preempted
    ``preempt_shield`` times becomes immune: victim selection skips it
    while any unshielded candidate exists, which bounds how often
    page-growth priority can bounce the same request (starvation guard).
  * **window eviction** — with ``window_tokens`` set, every step begins
    by recycling each row's blocks that no future query can attend
    (sliding-window attention: query ``q`` sees keys ``[q - W + 1, q]``).
    Evicted block-table entries become the trash page — absolute
    positions and block indices are preserved, the attention mask zeroes
    the evicted positions exactly, and the freed pages serve the same
    step's growth/admissions.  Windowed rows never register prefix-cache
    blocks (every one is eventually evicted; the index only holds
    immutable live pages).
  * **admission** — while a slot is free and the pool can hold the
    prompt plus one decode token.  With the prefix cache on, the waiting
    request with the longest cached prefix is admitted first (its shared
    pages cost nothing); strict FCFS resumes whenever the queue head was
    preempted before or has waited ``starvation_limit`` steps — the
    cache preference must not starve the head (second starvation guard).

With ``prefix_cache=True`` the scheduler also maintains the
content-addressed :class:`~repro.serve.kv_cache.PrefixCache`: admissions
adopt cached pages block-by-block (the engine's prefill blit skips them
— zero redundant page writes), prefilled full-prompt blocks are
registered immediately, and a finishing/preempted row stashes its
partial last prompt block before releasing its pages (registering it any
earlier would force the producer itself to COW its own tail).

The scheduler never touches device memory: it hands the engine numpy
block tables / lengths / active masks (:meth:`tables`), lists of
sequences to prefill, and COW (slot, block, src, dst) splits to copy.
All device work lives in ``serve/engine.py``.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.serve.kv_cache import (
    TRASH_PAGE,
    PageAllocator,
    PagedCacheConfig,
    PrefixCache,
)

__all__ = ["Request", "SeqState", "StepPlan", "PackedSegment", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # [T] int32 prompt
    max_new: int
    submit_time: float = 0.0
    wait_steps: int = 0         # scheduler steps spent waiting (starvation)
    n_preempts: int = 0         # times evicted (preemption shield)


@dataclasses.dataclass
class SeqState:
    """A running sequence: its slot, pages, and generation progress."""

    req: Request
    slot: int
    pages: list[int]            # physical pages, logical-block order
    length: int                 # tokens resident in cache
    emitted: list[int]          # generated token ids (greedy)
    last_token: int = 0
    admit_seq: int = -1         # admission order (LIFO preemption key)
    cached_tokens: int = 0      # prompt tokens served by the prefix cache
    shared_blocks: set[int] = dataclasses.field(default_factory=set)
    # chunked prefill: prompt positions still to run through the mixed
    # step, ascending (None = non-chunked or prefill complete).  Adopted
    # shared blocks' positions are excluded — their KV is resident — but
    # the last prompt position always stays in (its logits are the first
    # generated token; rewriting its KV is a bitwise-identical no-op).
    todo: collections.deque[int] | None = None
    admit_step: int = -1        # engine step of admission (TTFT accounting)

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def prefilling(self) -> bool:
        return bool(self.todo)

    @property
    def resident(self) -> int:
        """Prompt tokens whose KV is resident (a contiguous prefix:
        ``todo`` is consumed in order and earlier positions are either
        consumed or adopted from the prefix cache)."""
        if self.todo:
            return self.todo[0]
        return len(self.req.tokens)


@dataclasses.dataclass
class StepPlan:
    """What the engine must do this step."""

    admitted: list[SeqState]    # need a prefill + page blit
    preempted: list[int]        # rids evicted back to the queue
    grew: bool = False          # some running row got a new page
    # copy-on-write splits: device copies src -> dst the engine must run
    # BEFORE this step's decode writes (block tables already repointed)
    cow: list[tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list)           # (slot, block, src, dst)


@dataclasses.dataclass
class PackedSegment:
    """One contiguous run of lanes in a packed mixed step.

    ``kind`` is ``"chunk"`` (prefill-chunk tokens; ``tokens`` filled
    from the prompt) or ``"decode"`` (a decode row's next-token lane
    plus ``n - 1`` speculative-draft lanes; the engine fills ``tokens``
    with last_token + drafts).  ``offset`` is the segment's first lane
    in the step's fixed [token_budget] arrays, set by the engine when it
    packs.  ``last`` marks a chunk that completes its prompt — the
    final lane's logits are the row's first generated token (TTFT).
    """

    seq: SeqState
    kind: str
    positions: np.ndarray       # [n] absolute positions, ascending
    tokens: np.ndarray | None   # [n] token ids (None for decode segs)
    last: bool = False
    offset: int = 0

    @property
    def n(self) -> int:
        return len(self.positions)


class Scheduler:
    def __init__(self, pcfg: PagedCacheConfig, *, prefix_cache: bool = False,
                 lookahead: int = 1, starvation_limit: int = 8,
                 preempt_shield: int = 2, chunked: bool = False,
                 token_budget: int = 0, chunk_size: int | None = None,
                 prefill_reserve: int = 0, window_tokens: int | None = None):
        self.pcfg = pcfg
        self.alloc = PageAllocator(pcfg.n_pages)
        self.prefix = (PrefixCache(self.alloc, pcfg.page_size)
                       if prefix_cache else None)
        self.lookahead = max(1, lookahead)
        self.starvation_limit = starvation_limit
        self.preempt_shield = preempt_shield
        self.chunked = chunked
        self.token_budget = token_budget
        self.chunk_size = chunk_size
        self.prefill_reserve = prefill_reserve
        self.window = window_tokens     # sliding-window width (None = full)
        self.window_evictions = 0       # pages recycled by _evict_window
        self._rr = 0                    # decode round-robin rotation
        self.waiting: collections.deque[Request] = collections.deque()
        self.running: dict[int, SeqState] = {}          # slot -> seq
        self._free_slots = list(range(pcfg.max_seqs - 1, -1, -1))
        self._admit_clock = 0
        self._peek_memo: dict[int, tuple[int, int]] = {}   # rid -> (gen, n)
        self.cow_splits = 0
        self.cache_hit_tokens = 0

    # ------------------------------------------------------------ intake --
    def submit(self, req: Request) -> None:
        bs = self.pcfg.page_size
        T = len(req.tokens)
        need = -(-(T + req.max_new) // bs)
        if need > self.pcfg.max_blocks:
            raise ValueError(
                f"request {req.rid}: prompt {T} + max_new {req.max_new} "
                f"needs {need} blocks > per-seq capacity "
                f"{self.pcfg.max_blocks} ({self.pcfg.tokens_per_seq} tokens)")
        # pool feasibility: windowed rows recycle their oldest pages as
        # they go, so their PHYSICAL footprint is bounded by the window
        # (plus the write lookahead and block-alignment slack) no matter
        # how long the stream runs — only the per-seq block-table bound
        # above stays length-proportional
        need_pool = need
        if self.window is not None:
            need_pool = min(need,
                            -(-(self.window + self.lookahead) // bs) + 1)
        if need_pool > self.alloc.n_pages - 1:
            raise ValueError(
                f"request {req.rid} can never fit: needs {need_pool} pages, "
                f"pool has {self.alloc.n_pages - 1}")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ policy --
    def _alloc(self, n: int) -> list[int] | None:
        """Allocate, reclaiming LRU prefix-cache pages before giving up."""
        pages = self.alloc.alloc(n)
        if pages is None and self.prefix is not None:
            self.prefix.evict(n - self.alloc.n_free)
            pages = self.alloc.alloc(n)
        return pages

    def _stash_prefix(self, seq: SeqState) -> None:
        """Register a departing row's prompt blocks (incl. partial tail).

        Full blocks were registered at prefill; the partial tail is only
        stashed now, when the producer stops writing into it — from here
        on the page is frozen and any adopter COW-splits before writing.

        A row evicted before its prefill ran (admitted and preempted in
        the same schedule() call) has nothing to stash: its pages were
        never blitted, and registering them would poison the index with
        never-written KV that a readmission would then silently adopt.

        Sliding-window rows never register anything: under a window EVERY
        block eventually becomes evictable, and the prefix index's whole
        contract is that entries point at live, immutable pages.
        """
        if (self.prefix is not None and self.window is None
                and seq.pages and seq.emitted):
            self.prefix.insert(seq.req.tokens, seq.pages)

    def _release(self, seq: SeqState) -> None:
        """Free a departing row's REAL pages and clear its state.

        Window eviction leaves ``TRASH_PAGE`` placeholders in
        ``seq.pages`` to preserve absolute block indexing; freeing those
        through the allocator would raise (the trash page is never
        allocated), and before this helper existed a row that was both
        window-evicted and preempted/completed in the same step did
        exactly that.  ``todo`` is dropped so a stale reference held by
        the engine can never look prefilling again (readmission rebuilds
        it from the original prompt).
        """
        self.alloc.free([pg for pg in seq.pages if pg != TRASH_PAGE])
        seq.pages = []
        seq.shared_blocks = set()
        seq.todo = None

    def _preempt_youngest(self) -> int | None:
        """Evict the most recently admitted unshielded running seq.

        Rows preempted ``preempt_shield`` times are skipped while any
        other candidate exists — page-growth priority must not bounce the
        same request forever (readmission is bounded; see the adversarial
        trace in tests/test_serve_continuous.py).  Returns the rid.
        """
        if not self.running:
            return None
        cands = [s for s in self.running.values()
                 if s.req.n_preempts < self.preempt_shield]
        victim = max(cands or self.running.values(),
                     key=lambda s: s.admit_seq)
        victim.req.n_preempts += 1
        self._stash_prefix(victim)
        # _release clears the stale SeqState's pages: the engine may
        # still hold a reference (e.g. it preempts a sequence the same
        # step it finishes) and must not re-free them through complete()
        self._release(victim)
        self._free_slots.append(victim.slot)
        del self.running[victim.slot]
        # back to the FRONT: it has the oldest arrival among waiting peers
        self.waiting.appendleft(victim.req)
        return victim.rid

    def _evict_window(self) -> None:
        """Sliding window: recycle every block no future query can attend.

        A query at absolute position ``q`` attends keys ``[q - window +
        1, q]``.  The earliest query a row will ever run again sits at
        ``qmin`` — the front of its chunked-prefill ``todo`` deque while
        prefilling, else its current ``length`` — and later queries only
        move the bound right, so block ``b`` (positions ``[b*bs, (b+1)*bs
        - 1]``) is dead as soon as ``(b+1)*bs <= qmin - window + 1``.
        Dead blocks' pages go back to the pool and the block-table entry
        becomes the trash page: absolute block indexing is preserved
        (``len(seq.pages)`` still marks the write frontier) and the
        attention-side window mask already zeroes those positions
        exactly, so whatever the recycled page holds next never
        contributes.  Runs FIRST in :meth:`schedule` so recycled pages
        serve this same step's growth and admissions.
        """
        if self.window is None:
            return
        bs = self.pcfg.page_size
        for seq in self.running.values():
            qmin = seq.todo[0] if seq.todo else seq.length
            keep_from = qmin - self.window + 1
            n_dead = min(max(keep_from, 0) // bs, len(seq.pages))
            for b in range(n_dead):
                pg = seq.pages[b]
                if pg == TRASH_PAGE:
                    continue                        # already recycled
                self.alloc.free([pg])
                seq.pages[b] = TRASH_PAGE
                seq.shared_blocks.discard(b)
                self.window_evictions += 1

    def _grow(self, preempted: list[int]) -> bool:
        """Give every running row page(s) for the tokens it writes next.

        The first page (position ``length``) is required — preemption
        backs it so every surviving row emits at least one token per
        step.  Lookahead pages (speculative-draft writes) are best-effort
        only: a missing one just sends that draft's KV to the trash page
        and the engine caps acceptance accordingly.
        """
        bs = self.pcfg.page_size
        grew = False
        for seq in sorted(self.running.values(), key=lambda s: s.admit_seq):
            if self.running.get(seq.slot) is not seq:   # preempted below us
                continue
            required = seq.length // bs + 1
            while len(seq.pages) < required:
                got = self._alloc(1)
                if got is not None:
                    seq.pages.extend(got)
                    grew = True
                    continue
                rid = self._preempt_youngest()
                if rid is None or rid == seq.rid:
                    if rid is not None:
                        preempted.append(rid)
                    break                           # seq itself evicted
                preempted.append(rid)
            desired = min((seq.length + self.lookahead - 1) // bs + 1,
                          self.pcfg.max_blocks)
            while (self.running.get(seq.slot) is seq
                   and len(seq.pages) < desired):
                got = self._alloc(1)
                if got is None:
                    break                           # best-effort only
                seq.pages.extend(got)
                grew = True
        return grew

    def _cow_split(self, preempted: list[int]) -> list[tuple[int, int, int, int]]:
        """Split every shared page in a row's upcoming write window.

        A page with refcount > 1 is frozen (the prefix index and/or other
        rows read it); the row about to write positions
        ``length .. length+lookahead-1`` gets a fresh copy and drops its
        reference on the original.  The engine runs the device copies
        before the decode step.
        """
        cow: list[tuple[int, int, int, int]] = []
        bs = self.pcfg.page_size
        for seq in sorted(self.running.values(), key=lambda s: s.admit_seq):
            if self.running.get(seq.slot) is not seq:
                continue
            b0 = seq.length // bs
            b1 = min((seq.length + self.lookahead - 1) // bs,
                     len(seq.pages) - 1)
            for b in range(b0, b1 + 1):
                if self.running.get(seq.slot) is not seq:
                    break                           # evicted mid-split
                src = seq.pages[b]
                if src == TRASH_PAGE or self.alloc.refcount(src) <= 1:
                    continue
                fresh = self._alloc(1)
                while fresh is None:
                    rid = self._preempt_youngest()
                    if rid is None:
                        break
                    preempted.append(rid)
                    if rid == seq.rid:
                        break                       # seq itself evicted
                    fresh = self._alloc(1)
                if fresh is None or self.running.get(seq.slot) is not seq:
                    break
                dst = fresh[0]
                cow.append((seq.slot, b, src, dst))
                seq.pages[b] = dst
                seq.shared_blocks.discard(b)
                self.alloc.free([src])              # drop OUR ref only
                self.cow_splits += 1
        return cow

    def _pick_next(self) -> int:
        """Index into ``waiting`` of the next admission candidate.

        Prefix-cache preference: the request with the longest cached
        prefix goes first (its shared blocks cost no pages and no
        writes).  Strict FCFS resumes when the queue head was preempted
        or has waited ``starvation_limit`` steps — preference must not
        starve it.
        """
        if self.prefix is None or len(self.waiting) <= 1:
            return 0
        head = self.waiting[0]
        if head.n_preempts > 0 or head.wait_steps >= self.starvation_limit:
            return 0
        best, best_cached = 0, -1
        gen = self.prefix.generation
        for i, req in enumerate(self.waiting):
            # memoized per (request, index generation): the probe hashes
            # O(T^2/page_size) prefix bytes, and this scan runs for the
            # whole queue on every admission attempt — without the memo
            # that cost multiplies by queue length x steps
            memo = self._peek_memo.get(req.rid)
            if memo is not None and memo[0] == gen:
                n_cached = memo[1]
            else:
                n_cached = self.prefix.peek_cached_tokens(req.tokens)
                self._peek_memo[req.rid] = (gen, n_cached)
            if n_cached > best_cached:
                best, best_cached = i, n_cached
        return best

    def _admit(self) -> list[SeqState]:
        bs = self.pcfg.page_size
        admitted = []
        while self.waiting and self._free_slots:
            idx = self._pick_next()
            req = self.waiting[idx]
            n_blocks = -(-(len(req.tokens) + 1) // bs)
            shared: list[int | None] = [None] * n_blocks
            n_cached = 0
            # sliding-window rows never adopt (nothing registers under a
            # window, so the lookup could only miss) — and blocks already
            # outside the window at admission get the trash page instead
            # of a real allocation: a whole-prompt prefill computes its
            # in-prompt attention from the token stream, not the paged
            # cache, so KV the first decode query can't see need never
            # land on a real page (chunked prefill reads the cache, but
            # its ``todo`` starts at position 0 so nothing is dead yet —
            # _evict_window recycles as the chunks drain)
            dead: set[int] = set()
            if self.window is not None:
                if not self.chunked:
                    keep_from = len(req.tokens) - self.window + 1
                    dead = {b for b in range(n_blocks)
                            if (b + 1) * bs <= keep_from}
            elif self.prefix is not None:
                hit, n_cached = self.prefix.lookup(req.tokens)
                shared[: len(hit)] = hit
            share_map = {b: pg for b, pg in enumerate(shared)
                         if pg is not None}
            # incref the adopted pages BEFORE the fresh allocation: _alloc
            # may evict LRU index entries, and an index-only hit page
            # (refcount 1) is exactly what eviction frees — without our
            # reference it could be freed and handed straight back as one
            # of the "fresh" pages below (one physical page, two blocks)
            self.alloc.incref(list(share_map.values()))
            fresh = self._alloc(n_blocks - len(share_map) - len(dead))
            if fresh is None:
                self.alloc.free(list(share_map.values()))   # undo adoption
                break                               # head-of-line blocks
            fi = iter(fresh)
            pages = [share_map[b] if b in share_map
                     else TRASH_PAGE if b in dead else next(fi)
                     for b in range(n_blocks)]
            del self.waiting[idx]
            self._peek_memo.pop(req.rid, None)
            req.wait_steps = 0
            slot = self._free_slots.pop()
            seq = SeqState(req=req, slot=slot, pages=pages,
                           length=len(req.tokens), emitted=[],
                           admit_seq=self._admit_clock,
                           cached_tokens=n_cached,
                           shared_blocks=set(share_map))
            if self.chunked:
                T = len(req.tokens)
                todo = [p for p in range(T)
                        if p // bs not in seq.shared_blocks]
                if not todo or todo[-1] != T - 1:
                    todo.append(T - 1)      # TTFT logits; identical rewrite
                seq.todo = collections.deque(todo)
            self._admit_clock += 1
            self.running[slot] = seq
            admitted.append(seq)
            self.cache_hit_tokens += n_cached
        return admitted

    def schedule(self) -> StepPlan:
        """Window eviction, growth (with LIFO preemption), admission,
        then COW splits."""
        for req in self.waiting:
            req.wait_steps += 1
        self._evict_window()
        preempted: list[int] = []
        grew = self._grow(preempted)
        admitted = self._admit()
        # COW runs last so it also covers rows admitted THIS step (their
        # first decode write can land in an adopted partial block)
        cow = self._cow_split(preempted)
        admitted = [s for s in admitted
                    if self.running.get(s.slot) is s]   # COW may evict
        return StepPlan(admitted=admitted, preempted=preempted, grew=grew,
                        cow=cow)

    def plan_mixed(self, window: int = 1) -> list[PackedSegment]:
        """Fill one mixed step's token budget: decode rows, then chunks.

        Called after :meth:`schedule` (admission/growth/COW done).  Lane
        accounting, enforced by construction (hypothesis-tested in
        tests/test_mixed_sched_props.py):

          * total lanes never exceed ``token_budget``;
          * every decode-phase row gets ``window`` lanes (its next token
            plus ``window - 1`` speculative drafts), round-robin across
            steps when rows outnumber ``token_budget // window`` so no
            row idles forever;
          * while any row is prefilling, decode rows are capped so at
            least ``prefill_reserve`` lanes go to chunks — the bounded-
            TTFT guarantee: a prompt of T tokens is fully prefilled
            within ``ceil(T / prefill_reserve)`` steps of admission — but
            at least one decode row always advances (liveness);
          * chunks drain FCFS by admission order, each row consuming at
            most ``chunk_size`` positions per step, ``todo`` front-first
            (in order — a chunk token's receptive field is always
            resident before it runs).
        """
        budget = self.token_budget
        W = max(1, window)
        segs: list[PackedSegment] = []
        decode_rows = sorted((s for s in self.running.values()
                              if not s.prefilling and s.emitted),
                             key=lambda s: s.slot)
        prefill_rows = sorted((s for s in self.running.values()
                               if s.prefilling), key=lambda s: s.admit_seq)
        max_decode = budget // W
        if prefill_rows:
            max_decode = min(max_decode,
                             max(1, (budget - self.prefill_reserve) // W))
        remaining = budget
        if decode_rows:
            rot = self._rr % len(decode_rows)
            take = (decode_rows[rot:] + decode_rows[:rot])[:max_decode]
            self._rr = (rot + len(take)) % len(decode_rows)
            for seq in take:
                segs.append(PackedSegment(
                    seq=seq, kind="decode",
                    positions=seq.length + np.arange(W, dtype=np.int32),
                    tokens=None))
                remaining -= W
        for seq in prefill_rows:
            if remaining <= 0:
                break
            n = min(len(seq.todo), remaining)
            if self.chunk_size:
                n = min(n, self.chunk_size)
            positions = np.array([seq.todo.popleft() for _ in range(n)],
                                 np.int32)
            segs.append(PackedSegment(
                seq=seq, kind="chunk", positions=positions,
                tokens=np.asarray(seq.req.tokens, np.int32)[positions],
                last=not seq.todo))
            remaining -= n
        return segs

    def register_chunks(self, seq: SeqState) -> None:
        """Register a chunked row's now-fully-resident full prompt blocks
        (the incremental analogue of :meth:`register_prefix`: a block
        becomes discoverable as soon as its last chunk lands; the partial
        tail still waits for :meth:`_stash_prefix`)."""
        if self.prefix is None or self.window is not None:
            return
        bs = self.pcfg.page_size
        n_full = min(seq.resident, len(seq.req.tokens)) // bs
        if n_full:
            self.prefix.insert(seq.req.tokens[: n_full * bs],
                               seq.pages[:n_full])

    def register_prefix(self, seq: SeqState) -> None:
        """Called by the engine right after a prefill blit: the prompt's
        FULL blocks now hold final KV and become discoverable.  The
        partial tail stays private until the row departs
        (:meth:`_stash_prefix`) — the producer keeps writing into it.
        Sliding-window rows register nothing (see :meth:`_stash_prefix`:
        every windowed block is eventually evicted)."""
        if self.prefix is None or self.window is not None:
            return
        T = len(seq.req.tokens)
        n_full = T // self.pcfg.page_size
        if n_full:
            self.prefix.insert(seq.req.tokens[: n_full * self.pcfg.page_size],
                               seq.pages[:n_full])

    def complete(self, seq: SeqState) -> None:
        """Finished row: free its pages and slot immediately.

        Guarded against stale states: if ``seq`` is no longer the
        registered occupant of its slot (it was preempted this same step,
        or completed already), this is a no-op — freeing its slot or
        pages again would hand them to two sequences at once.
        """
        if self.running.get(seq.slot) is not seq:
            return
        self._stash_prefix(seq)
        self._release(seq)
        self._free_slots.append(seq.slot)
        del self.running[seq.slot]

    # ------------------------------------------------------- device views --
    def tables(self):
        """(block_tables [R, nb], lengths [R], active [R], last_tokens [R])
        as numpy — empty slots point at the trash page with length 0."""
        R, nb = self.pcfg.max_seqs, self.pcfg.max_blocks
        bt = np.full((R, nb), TRASH_PAGE, np.int32)
        lengths = np.zeros((R,), np.int32)
        active = np.zeros((R,), bool)
        last = np.zeros((R,), np.int32)
        for slot, seq in self.running.items():
            bt[slot, : len(seq.pages)] = seq.pages
            lengths[slot] = seq.length
            active[slot] = True
            last[slot] = seq.last_token
        return bt, lengths, active, last

    def block_row(self, seq: SeqState, n_blocks: int) -> np.ndarray:
        """[n_blocks] physical pages for a prompt blit (trash-padded).

        Blocks adopted from the prefix cache map to the TRASH page: their
        KV is already resident in the shared page, and blitting it again
        would be a redundant write into a frozen page.  This is the
        zero-redundant-page-writes half of the prefix-cache contract
        (the allocator's ``pages_shared`` counter is the other)."""
        row = np.full((n_blocks,), TRASH_PAGE, np.int32)
        k = min(len(seq.pages), n_blocks)
        row[:k] = seq.pages[:k]
        for b in seq.shared_blocks:
            if b < n_blocks:
                row[b] = TRASH_PAGE
        return row
