"""Continuous-batching request scheduler (host-side policy, no jax).

One :class:`Scheduler` owns the page pool and the slot map and makes the
three in-flight-batching decisions each engine step:

  * **growth** — running sequences get their next page just before the
    decode step that will write into it; running rows always outrank
    new admissions for pages.
  * **preemption** — when the pool is exhausted, the *youngest* running
    sequence (LIFO, the vLLM recompute policy) is evicted: its pages are
    freed and the request returns to the *front* of the waiting queue.
    Re-admission re-prefills from the original prompt; greedy decoding
    makes the regenerated tokens identical to the uninterrupted run
    (asserted in tests/test_serve_continuous.py).
  * **admission** — FCFS from the waiting queue while a slot is free and
    the pool can hold the prompt plus one decode token.

The scheduler never touches device memory: it hands the engine numpy
block tables / lengths / active masks (:meth:`tables`) and lists of
sequences to prefill.  All device work lives in ``serve/engine.py``.
"""

from __future__ import annotations

import collections
import dataclasses

import numpy as np

from repro.serve.kv_cache import TRASH_PAGE, PagedCacheConfig, PageAllocator

__all__ = ["Request", "SeqState", "StepPlan", "Scheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # [T] int32 prompt
    max_new: int
    submit_time: float = 0.0


@dataclasses.dataclass
class SeqState:
    """A running sequence: its slot, pages, and generation progress."""

    req: Request
    slot: int
    pages: list[int]            # physical pages, logical-block order
    length: int                 # tokens resident in cache
    emitted: list[int]          # generated token ids (greedy)
    last_token: int = 0
    admit_seq: int = -1         # admission order (LIFO preemption key)

    @property
    def rid(self) -> int:
        return self.req.rid


@dataclasses.dataclass
class StepPlan:
    """What the engine must do this step."""

    admitted: list[SeqState]    # need a prefill + page blit
    preempted: list[int]        # rids evicted back to the queue
    grew: bool = False          # some running row got a new page


class Scheduler:
    def __init__(self, pcfg: PagedCacheConfig):
        self.pcfg = pcfg
        self.alloc = PageAllocator(pcfg.n_pages)
        self.waiting: collections.deque[Request] = collections.deque()
        self.running: dict[int, SeqState] = {}          # slot -> seq
        self._free_slots = list(range(pcfg.max_seqs - 1, -1, -1))
        self._admit_clock = 0

    # ------------------------------------------------------------ intake --
    def submit(self, req: Request) -> None:
        bs = self.pcfg.page_size
        T = len(req.tokens)
        need = -(-(T + req.max_new) // bs)
        if need > self.pcfg.max_blocks:
            raise ValueError(
                f"request {req.rid}: prompt {T} + max_new {req.max_new} "
                f"needs {need} blocks > per-seq capacity "
                f"{self.pcfg.max_blocks} ({self.pcfg.tokens_per_seq} tokens)")
        if need > self.alloc.n_pages - 1:
            raise ValueError(
                f"request {req.rid} can never fit: needs {need} pages, "
                f"pool has {self.alloc.n_pages - 1}")
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # ------------------------------------------------------------ policy --
    def _preempt_youngest(self) -> int | None:
        """Evict the most recently admitted running seq; return its rid."""
        if not self.running:
            return None
        victim = max(self.running.values(), key=lambda s: s.admit_seq)
        self.alloc.free(victim.pages)
        # clear the stale SeqState's pages: the engine may still hold a
        # reference (e.g. it preempts a sequence the same step it
        # finishes) and must not re-free them through complete()
        victim.pages = []
        self._free_slots.append(victim.slot)
        del self.running[victim.slot]
        # back to the FRONT: it has the oldest arrival among waiting peers
        self.waiting.appendleft(victim.req)
        return victim.rid

    def _grow(self, preempted: list[int]) -> bool:
        """Give every running row a page for the token it writes next."""
        bs = self.pcfg.page_size
        grew = False
        for seq in sorted(self.running.values(), key=lambda s: s.admit_seq):
            if seq.slot not in self.running:        # preempted below us
                continue
            needed_blocks = seq.length // bs + 1
            while len(seq.pages) < needed_blocks:
                got = self.alloc.alloc(1)
                if got is not None:
                    seq.pages.extend(got)
                    grew = True
                    continue
                rid = self._preempt_youngest()
                if rid is None or rid == seq.rid:
                    if rid is not None:
                        preempted.append(rid)
                    break                           # seq itself evicted
                preempted.append(rid)
        return grew

    def _admit(self) -> list[SeqState]:
        bs = self.pcfg.page_size
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            n_blocks = -(-(len(req.tokens) + 1) // bs)
            pages = self.alloc.alloc(n_blocks)
            if pages is None:
                break                               # head-of-line blocks: FCFS
            self.waiting.popleft()
            slot = self._free_slots.pop()
            seq = SeqState(req=req, slot=slot, pages=pages,
                           length=len(req.tokens), emitted=[],
                           admit_seq=self._admit_clock)
            self._admit_clock += 1
            self.running[slot] = seq
            admitted.append(seq)
        return admitted

    def schedule(self) -> StepPlan:
        """Growth (with LIFO preemption) then FCFS admission."""
        preempted: list[int] = []
        grew = self._grow(preempted)
        admitted = self._admit()
        return StepPlan(admitted=admitted, preempted=preempted, grew=grew)

    def complete(self, seq: SeqState) -> None:
        """Finished row: free its pages and slot immediately.

        Guarded against stale states: if ``seq`` is no longer the
        registered occupant of its slot (it was preempted this same step,
        or completed already), this is a no-op — freeing its slot or
        pages again would hand them to two sequences at once.
        """
        if self.running.get(seq.slot) is not seq:
            return
        self.alloc.free(seq.pages)
        seq.pages = []
        self._free_slots.append(seq.slot)
        del self.running[seq.slot]

    # ------------------------------------------------------- device views --
    def tables(self):
        """(block_tables [R, nb], lengths [R], active [R], last_tokens [R])
        as numpy — empty slots point at the trash page with length 0."""
        R, nb = self.pcfg.max_seqs, self.pcfg.max_blocks
        bt = np.full((R, nb), TRASH_PAGE, np.int32)
        lengths = np.zeros((R,), np.int32)
        active = np.zeros((R,), bool)
        last = np.zeros((R,), np.int32)
        for slot, seq in self.running.items():
            bt[slot, : len(seq.pages)] = seq.pages
            lengths[slot] = seq.length
            active[slot] = True
            last[slot] = seq.last_token
        return bt, lengths, active, last

    def block_row(self, seq: SeqState, n_blocks: int) -> np.ndarray:
        """[n_blocks] physical pages for a prompt blit (trash-padded)."""
        row = np.full((n_blocks,), TRASH_PAGE, np.int32)
        k = min(len(seq.pages), n_blocks)
        row[:k] = seq.pages[:k]
        return row
