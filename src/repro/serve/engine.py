"""Serving engines: bucketed batching (legacy) and continuous batching.

:class:`Engine` is the original bucketed engine — batches grouped by
exact prompt length, run to completion.  It remains as the baseline the
traffic benchmark compares against (and for equal-length workloads where
its simplicity wins).

:class:`ContinuousEngine` is the production path: a paged KV cache
(``serve/kv_cache.py``) plus a host-side scheduler
(``serve/scheduler.py``) admit and evict sequences *mid-decode*.  Mixed
prompt lengths share

  * ONE jitted prefill (prompts right-padded to ``prompt_pad``; per-row
    lengths make the padding inert), and
  * ONE jitted decode step (shapes depend only on the slot count and the
    page geometry — never on a prompt length),

so serving arbitrary traffic costs zero per-length recompiles.  Finished
rows free their pages the same step (slot compaction); when the page
pool runs dry the scheduler preempts the youngest sequence and
re-prefills it later (recompute preemption — greedy decode makes the
replay identical).

RNS execution policy: as in the bucketed engine, ``rns_backend`` /
``rns_defer`` override the model config (serving is forward-only, so
residue-domain deferral is free), prefill reuses the shared forward
conversion + deferred-MLP chain, and each ``step()`` reports the
structural convert/matmul/normalize tallies it scheduled
(``stats["rns_ops"]``).  Ragged prefill and batched decode are
token-identical to solo runs on the RNS path too: per-sequence
quantization grids (``core/quantize.token_mask``) keep each row's
fixed-point scale independent of its neighbours and of pad garbage.
With ``ServeConfig.mesh`` set, the whole RNS datapath runs
digit-sharded over the mesh's ``model`` axis (see docs/distributed.md).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models import model as M
from repro.serve import kv_cache as kv
from repro.serve.scheduler import Request, Scheduler


@dataclasses.dataclass
class ServeConfig:
    """Engine knobs shared by both engines.

    ``eos_id`` semantics: a *non-negative* value is the vocabulary id that
    stops a row's generation; the special sentinel ``-1`` means "never
    stop early" (synthetic-traffic benchmarks, perplexity sweeps).  Any
    other negative value can silently never match a sampled token, so it
    is rejected at construction time.
    """

    max_cache: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1            # -1 sentinel: never stops early
    cache_dtype: str = "float32"
    # RNS execution policy overrides (None: keep the model config's).
    # "pallas_fused" routes the whole datapath — including ragged prefill
    # with its per-sequence quantization grids — through the composite
    # kernels (kernels/rns_fused); step stats gain nonzero rns_ops.fused.
    rns_backend: str | None = None   # see core/dispatch.BACKENDS | auto
    rns_defer: bool | None = None    # residue-domain MLP chaining
    # resident residue-domain weights: encode every RNS-target MLP weight
    # ONCE at engine build (models/resident.encode_resident) so the
    # per-step jits consume pre-encoded residues — weight conversions drop
    # to zero while the token stream stays bit-identical to re-encode.
    resident_weights: bool = False
    # per-layer moduli profiles (requires resident_weights): narrow layers
    # are encoded on fewer/smaller moduli, chosen from quantized-weight
    # column-sum statistics with a magnitude-ledger exactness proof.
    per_layer_profiles: bool = False
    # residue-channel sharding: a jax Mesh whose ``digit_axis`` partitions
    # the RNS digit axis (one group of moduli per device; digits meet only
    # at MRC normalization).  None: single-device layout, unchanged.
    mesh: object | None = None       # jax.sharding.Mesh
    digit_axis: str = "model"
    # continuous batching / paged cache (ContinuousEngine only)
    page_size: int = 16              # tokens per physical page
    max_seqs: int = 8                # concurrent decode slots
    n_pages: int | None = None       # physical pool (None: max_seqs full seqs)
    prompt_pad: int | None = None    # prefill pad length (None: seq capacity)
    # copy-on-write prefix caching: sequences sharing a prompt prefix map
    # their block tables onto shared pages (content-addressed index in
    # serve/kv_cache.PrefixCache); the prefill blit skips shared blocks
    # (zero redundant page writes) and a row splits a shared page the
    # first time it writes into one (COW).
    prefix_cache: bool = False
    # self-speculative (n-gram / prompt-lookup) decoding: each step scores
    # [last_token, draft_1..draft_k] through ONE jitted [R, k+1] verify
    # call; greedy accept/reject keeps the stream token-identical to
    # vanilla decode while emitting up to k+1 tokens per step.
    spec_decode: bool = False
    spec_k: int = 3                  # draft tokens per step (window = k+1)
    spec_ngram: int = 3              # max n-gram length for prompt lookup
    # chunked prefill + packed mixed-phase batching (ContinuousEngine):
    # ONE jitted step consumes up to ``token_budget`` packed lanes per
    # iteration — decode rows (spec_k+1 lanes each when spec_decode is
    # on) and prefill chunks of at most ``chunk_size`` tokens (None: no
    # per-row cap beyond the budget).  While any row is prefilling,
    # ``prefill_reserve`` lanes are reserved for chunks (None: half the
    # budget), bounding time-to-first-token under decode load.
    chunked_prefill: bool = False
    token_budget: int = 64           # packed lanes per mixed step
    chunk_size: int | None = None    # max prefill tokens per row per step
    prefill_reserve: int | None = None   # lanes reserved for chunks
    # sliding-window attention + cyclic KV page reuse: each row retains
    # at most ``window_tokens`` of context (query q attends keys
    # [q - window + 1, q], exact-zero masking below that) and the
    # scheduler recycles the oldest full pages as rows outgrow the
    # window — physical occupancy stays bounded by the window no matter
    # how long the stream runs.  Positions stay absolute, so the stream
    # is token-identical to a solo run with the same window.  Windowed
    # rows never register prefix-cache blocks (every block is eventually
    # evicted; the index only holds immutable live pages).
    window_tokens: int | None = None
    # exactness audit at engine build: run the static magnitude-ledger
    # auditor (repro.analysis.ledger_audit) over every jitted phase this
    # config will serve and REFUSE to construct an engine whose RNS
    # datapath cannot be proven overflow-free.  The report is kept on
    # ``engine.audit_report``.  No-op for float configs (cfg.rns None).
    audit: bool = False

    def __post_init__(self):
        if self.eos_id < -1:
            raise ValueError(
                f"eos_id={self.eos_id}: vocabulary ids are non-negative; "
                "use a valid token id, or -1 (the documented sentinel) to "
                "disable early stopping")
        if self.spec_decode and self.spec_k < 1:
            raise ValueError(
                f"spec_k={self.spec_k}: speculative decoding needs at "
                "least one draft token per step")
        if self.spec_decode and self.spec_ngram < 1:
            raise ValueError(f"spec_ngram={self.spec_ngram}: need >= 1")
        if self.per_layer_profiles and not self.resident_weights:
            raise ValueError(
                "per_layer_profiles selects moduli at weight-encode time; "
                "it requires resident_weights=True")
        # cross-feature coherence for the chunked mixed step: every
        # incoherent combination is named by the fields that conflict.
        if self.chunked_prefill:
            if self.token_budget < 1:
                raise ValueError(
                    f"token_budget={self.token_budget}: chunked_prefill "
                    "needs at least one packed lane per step")
            if self.spec_decode and self.token_budget < self.spec_k + 1:
                raise ValueError(
                    f"token_budget={self.token_budget} < spec_k+1="
                    f"{self.spec_k + 1}: a speculative decode row needs "
                    "spec_k+1 lanes in one mixed step; raise token_budget "
                    "or lower spec_k")
            if self.cache_dtype != "float32":
                raise ValueError(
                    f"cache_dtype={self.cache_dtype!r}: chunked prefill "
                    "re-reads earlier chunks' KV from the page pool, so "
                    "the cache must be lossless (float32) to stay "
                    "token-identical to whole-prompt prefill")
        if self.chunk_size is not None:
            if not self.chunked_prefill:
                raise ValueError(
                    "chunk_size is only meaningful with "
                    "chunked_prefill=True")
            if self.chunk_size < 1:
                raise ValueError(f"chunk_size={self.chunk_size}: need >= 1")
            if self.chunk_size % self.page_size:
                raise ValueError(
                    f"chunk_size={self.chunk_size} is not a multiple of "
                    f"page_size={self.page_size}: chunk boundaries must "
                    "align with KV pages so completed blocks register "
                    "with the prefix cache as chunks land")
            if self.chunk_size > self.token_budget:
                raise ValueError(
                    f"chunk_size={self.chunk_size} > token_budget="
                    f"{self.token_budget}: a chunk can never exceed the "
                    "packed lanes available in one step")
        if self.prefill_reserve is not None:
            if not self.chunked_prefill:
                raise ValueError(
                    "prefill_reserve is only meaningful with "
                    "chunked_prefill=True")
            if not 0 <= self.prefill_reserve < self.token_budget:
                raise ValueError(
                    f"prefill_reserve={self.prefill_reserve}: must be in "
                    f"[0, token_budget={self.token_budget}) so decode "
                    "rows keep making progress")
        if self.window_tokens is not None and self.window_tokens < 1:
            raise ValueError(
                f"window_tokens={self.window_tokens}: a sliding window "
                "must retain at least the current token (use None for "
                "full attention)")


def _with_digit_ctx(fn, scfg: ServeConfig):
    """Wrap a jitted callable so tracing sees the digit-sharding context.

    The context only matters during the (first-call) trace, where
    ``core/dispatch.py`` routes convert/matmul/normalize through the
    per-device shard_map bodies; afterwards the wrapper is a cheap
    passthrough.  ``_cache_size`` is forwarded — tests pin the
    zero-per-length-recompiles contract through it.
    """
    if scfg.mesh is None:
        return fn
    from repro.distributed.sharding import use_digit_sharding

    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with use_digit_sharding(scfg.mesh, scfg.digit_axis):
            return fn(*args, **kwargs)

    if hasattr(fn, "_cache_size"):
        wrapped._cache_size = fn._cache_size
    return wrapped


def _apply_rns_policy(model_cfg, scfg: ServeConfig):
    """Fold the serve-side execution overrides into the model config:
    RNS backend/defer policy, and the sliding-window width (which both
    engines thread to attention as ``cfg.attn_window`` — the solo
    bucketed engine with the same ``window_tokens`` is the reference the
    continuous stream is token-identical to)."""
    if scfg.window_tokens is not None:
        model_cfg = dataclasses.replace(model_cfg,
                                        attn_window=scfg.window_tokens)
    if model_cfg.rns is None or (
            scfg.rns_backend is None and scfg.rns_defer is None):
        return model_cfg
    rns = model_cfg.rns
    if scfg.rns_backend is not None:
        rns = dataclasses.replace(rns, backend=scfg.rns_backend)
    if scfg.rns_defer is not None:
        rns = dataclasses.replace(rns, defer=scfg.rns_defer)
    return dataclasses.replace(model_cfg, rns=rns)


def _maybe_resident(params, cfg, scfg: ServeConfig):
    """Encode resident weights at engine build time when asked to."""
    if not scfg.resident_weights or cfg.rns is None:
        return params
    from repro.models.resident import encode_resident

    return encode_resident(params, cfg,
                           per_layer_profiles=scfg.per_layer_profiles,
                           mesh=scfg.mesh, digit_axis=scfg.digit_axis)


def _maybe_audit(engine):
    """Build-time static audits (``ServeConfig(audit=True)``).

    Three ahead-of-time proofs, in order, each refusing to hand back the
    engine with the failed report's summary as the exception text:

    1. **exactness audit** (``repro.analysis.ledger_audit``) — the RNS
       datapath is provably overflow-free; kept on (and returned as)
       ``engine.audit_report``.  Float configs have nothing to prove
       ledger-wise and keep ``audit_report is None``.  This runs FIRST
       so a numerically unprovable config is named by the exactness
       pass — its ledger error would otherwise abort the kernel
       capture below and be misblamed as a launch failure;
    2. **trace audit** (``repro.analysis.trace_audit``) — every jitted
       phase's cache key is traffic-invariant (no steady-state
       recompiles); kept on ``engine.trace_audit_report``;
    3. **kernel audit** (``repro.analysis.kernel_audit``) — every Pallas
       launch the phases lower to is Mosaic-legal and within the VMEM
       budget (an illegal tuned block config refuses to build here);
       kept on ``engine.kernel_audit_report``.
    """
    engine.trace_audit_report = None
    engine.kernel_audit_report = None
    if not engine.scfg.audit:
        return None
    from repro.analysis.kernel_audit import audit_engine_kernels
    from repro.analysis.trace_audit import audit_traces

    report = None
    if engine.cfg.rns is not None:
        from repro.analysis.kernel_audit import BlockConfigError
        from repro.analysis.ledger_audit import audit_engine

        try:
            report = audit_engine(engine)
        except BlockConfigError:
            # an illegal tile aborts the exactness trace; fall through —
            # the kernel audit below reproduces and names it properly
            report = None
        else:
            if not report.ok:
                raise ValueError("ServeConfig(audit=True): exactness "
                                 "audit failed\n" + report.summary())
    trace_report = audit_traces(engine)
    engine.trace_audit_report = trace_report
    if not trace_report.ok:
        raise ValueError("ServeConfig(audit=True): trace audit failed\n"
                         + trace_report.summary())
    kernel_report = audit_engine_kernels(engine)
    engine.kernel_audit_report = kernel_report
    if not kernel_report.ok:
        raise ValueError("ServeConfig(audit=True): kernel audit failed\n"
                         + kernel_report.summary())
    return report


class Engine:
    """Bucketed batching: equal-length prompts, batch runs to completion."""

    def __init__(self, params, model_cfg, scfg: ServeConfig):
        self.cfg = _apply_rns_policy(model_cfg, scfg)
        self.params = _maybe_resident(params, self.cfg, scfg)
        self.scfg = scfg
        self._prefill = _with_digit_ctx(jax.jit(
            functools.partial(M.prefill, cfg=self.cfg, S_max=scfg.max_cache,
                              cache_dtype=jnp.dtype(scfg.cache_dtype)),
            static_argnames=()), scfg)
        self._decode = _with_digit_ctx(jax.jit(
            lambda params, tok, cache: M.decode_step(
                params, self.cfg, tok, cache)), scfg)
        self.audit_report = _maybe_audit(self)

    def rns_op_counts(self, B: int = 1, T: int = 8) -> dispatch.OpCounts:
        """Structural RNS primitive counts for one [B, T] prefill trace."""
        batch = {"tokens": jnp.zeros((B, T), jnp.int32)}
        return dispatch.trace_op_counts(
            lambda p, b: M.prefill(p, self.cfg, b, S_max=self.scfg.max_cache),
            self.params, batch)

    def _trace_specs(self, traffic: dict | None = None) -> dict:
        """``{phase: (fn, args)}`` for the static auditors
        (repro.analysis.ledger_audit / kernel_audit / trace_audit).  The
        bucketed engine serves one compound program — prefill then
        decode on the returned cache — so one combined phase covers both
        jits.  ``traffic`` varies the token *values* only: this engine
        recompiles per (B, T) bucket BY DESIGN, so its invariance claim
        (and the trace audit's proof) is scoped to one bucket."""
        fill = int((traffic or {}).get("fill", 0))

        def prefill_decode(p, t):
            logits, cache = M.prefill(p, self.cfg, {"tokens": t},
                                      S_max=self.scfg.max_cache)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return M.decode_step(p, self.cfg, tok, cache)

        return {"prefill+decode": (
            prefill_decode, (self.params, jnp.full((1, 8), fill, jnp.int32)))}

    def generate(self, prompts: np.ndarray, frontend: np.ndarray | None = None,
                 max_new: int | None = None):
        """prompts [B, T] int32 (equal lengths). Returns [B, n_new] tokens."""
        max_new = max_new or self.scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        logits, cache = self._prefill(self.params, batch=batch)
        B = prompts.shape[0]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        out = [tok]
        for _ in range(max_new - 1):
            done = done | (tok[:, 0] == self.scfg.eos_id)
            logits, cache = self._decode(self.params, tok, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok = jnp.where(done[:, None], tok, nxt)
            out.append(tok)
            if bool(jnp.all(done)):
                break
        return np.asarray(jnp.concatenate(out, axis=1))


# ------------------------------------------------------------ continuous ---
def _round_up(n: int, k: int) -> int:
    return -(-n // k) * k


class ContinuousEngine:
    """In-flight batching over a paged KV cache (decoder-only attn/mla)."""

    def __init__(self, params, model_cfg, scfg: ServeConfig):
        cfg = _apply_rns_policy(model_cfg, scfg)
        bad = sorted({t for t in cfg.layer_types if t not in ("attn", "mla")})
        if bad:
            raise NotImplementedError(
                f"continuous batching pages attn/mla caches only; "
                f"{cfg.arch_id} has layer types {bad}")
        if cfg.enc_dec or cfg.frontend is not None:
            raise NotImplementedError(
                "continuous batching is decoder-only (no enc-dec / frontend)")
        if not cfg.causal:
            raise NotImplementedError("continuous batching requires causal "
                                      "attention (padded prefill relies on it)")
        self.params = _maybe_resident(params, cfg, scfg)
        self.cfg = cfg
        self.scfg = scfg

        bs = scfg.page_size
        max_blocks = -(-scfg.max_cache // bs)
        n_pages = scfg.n_pages or 1 + scfg.max_seqs * max_blocks
        self.spec_window = scfg.spec_k + 1 if scfg.spec_decode else 1
        resident = None
        if scfg.window_tokens is not None:
            # window + lookahead tokens straddle at most this many pages
            resident = -(-(scfg.window_tokens + self.spec_window) // bs) + 1
        self.pcfg = kv.PagedCacheConfig(
            page_size=bs, n_pages=n_pages, max_seqs=scfg.max_seqs,
            max_blocks=max_blocks, resident_blocks=resident)
        self.prompt_pad = _round_up(
            scfg.prompt_pad or self.pcfg.tokens_per_seq, bs)
        if self.prompt_pad > self.pcfg.tokens_per_seq:
            raise ValueError(
                f"prompt_pad {self.prompt_pad} exceeds per-seq cache "
                f"capacity {self.pcfg.tokens_per_seq}")
        self.chunked = scfg.chunked_prefill
        if self.chunked and cfg.rns is not None and cfg.rns_targets == "all" \
                and "mla" in cfg.layer_types:
            raise NotImplementedError(
                "chunked_prefill with rns_targets='all' on an MLA model: "
                "packed chunk tokens re-expand gathered latents, and the "
                "original per-token quantization grids of earlier chunks "
                "are not recoverable from the cache; use rns_targets='mlp'")
        reserve = scfg.prefill_reserve
        if reserve is None:
            reserve = max(1, scfg.token_budget // 2)
        self.sched = Scheduler(self.pcfg, prefix_cache=scfg.prefix_cache,
                               lookahead=self.spec_window,
                               chunked=self.chunked,
                               token_budget=scfg.token_budget,
                               chunk_size=scfg.chunk_size,
                               prefill_reserve=reserve if self.chunked else 0,
                               window_tokens=scfg.window_tokens)
        self.cache = kv.make_paged_cache(
            cfg, self.pcfg, dtype=jnp.dtype(scfg.cache_dtype))

        self._prefill = _with_digit_ctx(jax.jit(
            lambda params, tokens, lengths: M.prefill_ragged(
                params, self.cfg, {"tokens": tokens}, lengths)), scfg)

        def _decode_fn(params, tok, cache, active):
            logits, cache = M.decode_step(params, self.cfg, tok, cache,
                                          active=active)
            # argmax on device: the host pulls R ints, not R x vocab logits
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        # donate the cache operand: the page pool is the big allocation,
        # and both callers immediately rebind self.cache to the result —
        # without donation every decoded token copies the whole pool
        self._decode = _with_digit_ctx(
            jax.jit(_decode_fn, donate_argnums=(2,)), scfg)
        # ONE jitted [R, W] verify step replaces the [R, 1] decode when
        # speculative decoding is on — same zero-per-length-recompiles
        # contract (shapes depend only on the slot count, the page
        # geometry, and the static window width)
        self._verify = _with_digit_ctx(
            jax.jit(self._verify_fn, donate_argnums=(2,)), scfg)
        self._cow = jax.jit(self._cow_fn, donate_argnums=(0,))
        self._ingest = jax.jit(self._ingest_fn, donate_argnums=(0,))
        # ONE jitted mixed step: every iteration consumes the same fixed
        # [token_budget] packed lanes regardless of how many chunks vs
        # decode rows fill them, so the phase mix never recompiles
        self._mixed = _with_digit_ctx(
            jax.jit(self._mixed_fn, donate_argnums=(6,)), scfg)
        self._tables_dirty = True
        self._active = np.zeros((self.pcfg.max_seqs,), bool)

        self._next_rid = 0
        self._step_idx = 0
        self.results: dict[int, np.ndarray] = {}
        self.latencies: dict[int, float] = {}    # submit -> finish, seconds
        self.ttfts: dict[int, float] = {}        # submit -> first token
        self._op_cache: dict[str, dispatch.OpCounts] = {}
        self.audit_report = _maybe_audit(self)

    # ----------------------------------------------------------- ingest ---
    def _ingest_fn(self, cache, ys, block_row):
        """Blit one prefilled request's KV planes into its pages."""
        new = dict(cache)
        for j in range(self.cfg.period):
            lt = self.cfg.layer_types[j]
            z = dict(cache[f"l{j}"])
            y = ys[f"l{j}"]
            if lt == "attn":
                k, v = y
                z["k_pages"] = kv.write_prompt_pages(z["k_pages"], block_row, k)
                z["v_pages"] = kv.write_prompt_pages(z["v_pages"], block_row, v)
            else:  # mla
                ckv, krope = y
                z["ckv_pages"] = kv.write_prompt_pages(
                    z["ckv_pages"], block_row, ckv)
                z["krope_pages"] = kv.write_prompt_pages(
                    z["krope_pages"], block_row, krope)
            new[f"l{j}"] = z
        return new

    def _verify_fn(self, params, window, cache, active, caps):
        """Score a [R, W] draft window and accept/reject on device.

        ``window[:, 0]`` is each row's last emitted token, ``window[:,
        1:]`` its drafts.  Greedy accept: draft i+1 survives iff it
        equals the argmax after window position i AND every earlier draft
        survived — so the emitted stream is the model's own greedy chain
        by construction, token-identical to vanilla decode.  ``caps``
        bounds acceptance per row (max_new budget; drafts whose KV landed
        on the trash page).  Cache lengths advance by accepted+1 on
        device, keeping them in lockstep with the host counters so the
        table upload stays skippable.

        Returns (greedy [R, W], accepted [R], cache).
        """
        logits, ys = M.decode_window(params, self.cfg, window, cache,
                                     active=active)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # [R, W]
        match = (g[:, :-1] == window[:, 1:]).astype(jnp.int32)  # [R, W-1]
        a = jnp.minimum(jnp.sum(jnp.cumprod(match, axis=1), axis=1), caps)
        step = jnp.where(active, a + 1, 0)
        new_cache = M.set_cache_lengths(ys, M._cache_lengths(ys) + step)
        return g, a, new_cache

    def _mixed_fn(self, params, tokens, seg, pos, dec, valid, cache):
        """One packed mixed-phase step over [token_budget] lanes.

        Each lane is (token, owning slot, absolute position, is-decode,
        is-valid); prefill chunks and decode/spec windows share the one
        program.  Returns per-lane greedy argmaxes — the host walks the
        segment map to turn them into first tokens (chunk tails) or
        accept decisions (spec windows).
        """
        logits, cache = M.mixed_step(params, self.cfg, tokens, seg, pos,
                                     dec, valid, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _cow_fn(self, cache, src, dst):
        """Copy-on-write page duplication across every layer's pool."""
        new = dict(cache)
        for j in range(self.cfg.period):
            z = dict(cache[f"l{j}"])
            for name in list(z):
                if name.endswith("_pages"):
                    z[name] = kv.copy_pages(z[name], src, dst)
            new[f"l{j}"] = z
        return new

    def _apply_cow(self, cow):
        """Run the scheduler's COW splits on device (before decode writes).

        Fixed [R] src/dst vectors (TRASH for no-op rows) keep the copy
        jit at one compile; rounds handle the (rare) case of several
        splits on one slot.
        """
        R = self.pcfg.max_seqs
        while cow:
            this_round, rest, seen = [], [], set()
            for e in cow:
                if e[0] in seen:
                    rest.append(e)
                else:
                    seen.add(e[0])
                    this_round.append(e)
            src = np.full((R,), kv.TRASH_PAGE, np.int32)
            dst = np.full((R,), kv.TRASH_PAGE, np.int32)
            for slot, _b, s, d in this_round:
                src[slot], dst[slot] = s, d
            self.cache = self._cow(self.cache, jnp.asarray(src),
                                   jnp.asarray(dst))
            cow = rest

    def _propose(self, seq) -> np.ndarray:
        """Prompt-lookup (n-gram) drafting: match the row's trailing
        n-gram against its own prompt+generation history and propose the
        k tokens that followed the most recent earlier occurrence.
        Misses pad with zeros — a padded draft is only ever accepted if
        it happens to equal the model's greedy choice, so correctness
        never depends on draft quality."""
        k = self.scfg.spec_k
        hist = np.concatenate([seq.req.tokens,
                               np.asarray(seq.emitted, np.int32)])
        out = np.zeros((k,), np.int32)
        for n in range(min(self.scfg.spec_ngram, len(hist) - 1), 0, -1):
            pat = hist[-n:]
            base = hist[:-1]                 # candidate starts need a next
            if len(base) < n:
                continue
            wins = np.lib.stride_tricks.sliding_window_view(base, n)
            hits = np.flatnonzero((wins == pat).all(axis=1))
            if len(hits):
                j = int(hits[-1]) + n
                d = hist[j:j + k]
                out[: len(d)] = d
                return out
        return out

    # ------------------------------------------------------------ intake --
    def submit(self, prompt: np.ndarray, max_new: int | None = None) -> int:
        """Queue one request; returns its request id."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        max_new = max_new or self.scfg.max_new_tokens
        if not self.chunked and len(prompt) > self.prompt_pad:
            raise ValueError(
                f"prompt length {len(prompt)} > prompt_pad {self.prompt_pad}; "
                "raise ServeConfig.prompt_pad or turn on chunked_prefill")
        rid = self._next_rid
        self._next_rid += 1
        self.sched.submit(Request(rid=rid, tokens=prompt, max_new=max_new,
                                  submit_time=time.perf_counter()))
        return rid

    # ----------------------------------------------------------- stepping --
    def _do_prefill(self, seq):
        T = len(seq.req.tokens)
        tokens = np.zeros((1, self.prompt_pad), np.int32)
        tokens[0, :T] = seq.req.tokens
        logits, ys = self._prefill(self.params, jnp.asarray(tokens),
                                   jnp.asarray([T], jnp.int32))
        tok0 = int(jnp.argmax(logits, axis=-1)[0])
        nbp = self.prompt_pad // self.pcfg.page_size
        # block_row maps prefix-cache-shared blocks to the trash page:
        # their KV is already resident, the blit skips them entirely
        block_row = self.sched.block_row(seq, nbp)
        self.cache = self._ingest(self.cache, ys, jnp.asarray(block_row))
        self.sched.register_prefix(seq)
        seq.emitted = [tok0]
        seq.last_token = tok0
        ttft = time.perf_counter() - seq.req.submit_time
        self.ttfts[seq.rid] = ttft
        self._step_ttfts.append(ttft)
        # length stays at T: the decode step writes tok0's KV at position T

    def _finish(self, seq):
        self.results[seq.rid] = np.asarray(seq.emitted, np.int32)
        self.latencies[seq.rid] = time.perf_counter() - seq.req.submit_time
        self.sched.complete(seq)
        self._tables_dirty = True

    def _trace_specs(self, traffic: dict | None = None) -> dict:
        """``{phase: (fn, args)}`` — every jitted shape this config serves.

        ONE source of truth shared by the per-step op counters (traced
        through ``dispatch.trace_op_counts``) and the static auditors
        (``repro.analysis``' ledger_audit / kernel_audit / trace_audit):
        whatever the engine would actually jit is exactly what gets
        audited.  The closures read ``self.cfg`` dynamically, so the
        auditor can probe policy variants (e.g. defer=True) by swapping
        it.  ``traffic`` (``{"fill": tok, "length": L}``) varies the
        argument *contents* the way real requests would — the trace
        auditor proves the resulting jit signatures don't.
        """
        tr = traffic or {}
        fill = int(tr.get("fill", 0))
        L = max(1, min(int(tr.get("length", 1)), self.prompt_pad))
        bt, lengths, active, last = self.sched.tables()
        cache = kv.set_tables(self.cache, bt, lengths)
        if self.chunked:
            # the mixed step's structure is phase-mix invariant: fixed
            # [token_budget] lanes, one trace serves every step
            zi = jnp.full((self.scfg.token_budget,), fill, jnp.int32)
            zb = jnp.zeros((self.scfg.token_budget,), bool)
            return {"mixed": (
                lambda p, t: M.mixed_step(p, self.cfg, t, zi, zi, zb,
                                          zb, cache),
                (self.params, zi))}
        R = self.pcfg.max_seqs
        if self.scfg.spec_decode:
            # spec mode replaces the decode step with the verify step
            decode = (
                lambda p, t: self._verify_fn(
                    p, t, cache, jnp.asarray(active),
                    jnp.zeros((R,), jnp.int32)),
                (self.params,
                 jnp.full((R, self.spec_window), fill, jnp.int32)))
        else:
            decode = (
                lambda p, t: M.decode_step(p, self.cfg, t, cache,
                                           active=jnp.asarray(active)),
                (self.params, jnp.full((R, 1), fill, jnp.int32)))
        # prompt tokens/lengths are jit ARGUMENTS (mirroring the runtime
        # ``self._prefill(params, tokens, [T])`` call), so ragged lengths
        # exercise the same compiled program — which is the claim the
        # trace auditor proves over the traffic family.
        tokens = np.zeros((1, self.prompt_pad), np.int32)
        tokens[0, :L] = fill
        prefill = (
            lambda p, t, n: M.prefill_ragged(
                p, self.cfg, {"tokens": t}, n),
            (self.params, jnp.asarray(tokens),
             jnp.asarray([L], jnp.int32)))
        return {"decode": decode, "prefill": prefill}

    def _rns_ops(self, n_prefills: int) -> dispatch.OpCounts:
        """Structural convert/matmul/normalize counts for this step."""
        if self.cfg.rns is None:
            return dispatch.OpCounts()
        if not self._op_cache:
            for name, (fn, args) in self._trace_specs().items():
                self._op_cache[name] = dispatch.trace_op_counts(fn, *args)
        if self.chunked:
            return self._op_cache["mixed"]
        return self._op_cache["decode"].add(self._op_cache["prefill"],
                                            times=n_prefills)

    def _decode_vanilla(self, last):
        """One [R, 1] decode for every running row; returns #new tokens."""
        nxt, self.cache = self._decode(
            self.params, jnp.asarray(last[:, None]), self.cache,
            jnp.asarray(self._active))
        nxt = np.asarray(nxt, np.int32)
        n_tokens = 0
        for seq in list(self.sched.running.values()):
            tok = int(nxt[seq.slot])
            seq.emitted.append(tok)
            seq.last_token = tok
            seq.length += 1
            n_tokens += 1
            if (len(seq.emitted) >= seq.req.max_new
                    or tok == self.scfg.eos_id
                    or seq.length + 1 > self.pcfg.tokens_per_seq):
                self._step_finished.append(seq.rid)
                self._finish(seq)
        return n_tokens

    def _decode_spec(self, last):
        """One [R, W] draft-propose + verify for every running row.

        Emits ``accepted + 1`` tokens per row (the accepted draft run
        plus the bonus greedy token after it) — between 1 and W per step,
        token-identical to vanilla decode by the greedy accept rule.
        """
        R, W, bs = self.pcfg.max_seqs, self.spec_window, self.pcfg.page_size
        window = np.zeros((R, W), np.int32)
        caps = np.zeros((R,), np.int32)
        for seq in self.sched.running.values():
            window[seq.slot, 0] = seq.last_token
            window[seq.slot, 1:] = self._propose(seq)
            remaining = seq.req.max_new - len(seq.emitted)
            caps[seq.slot] = max(0, min(
                W - 1,
                remaining - 1,                       # a+1 <= max_new budget
                len(seq.pages) * bs - seq.length - 1))   # KV on real pages
        g, a, self.cache = self._verify(
            self.params, jnp.asarray(window), self.cache,
            jnp.asarray(self._active), jnp.asarray(caps))
        g, a = np.asarray(g, np.int32), np.asarray(a, np.int32)
        n_tokens = 0
        for seq in list(self.sched.running.values()):
            ar = int(a[seq.slot])
            toks = list(window[seq.slot, 1:ar + 1]) + [int(g[seq.slot, ar])]
            if self.scfg.eos_id >= 0 and self.scfg.eos_id in toks:
                toks = toks[: toks.index(self.scfg.eos_id) + 1]
            seq.emitted.extend(int(t) for t in toks)
            seq.last_token = seq.emitted[-1]
            seq.length += ar + 1        # matches the device-side bump
            n_tokens += len(toks)
            self._spec_accepted += ar
            self._spec_proposed += int(caps[seq.slot]) if W > 1 else 0
            if (len(seq.emitted) >= seq.req.max_new
                    or seq.emitted[-1] == self.scfg.eos_id
                    or seq.length + 1 > self.pcfg.tokens_per_seq):
                self._step_finished.append(seq.rid)
                self._finish(seq)
        return n_tokens

    def _step_mixed(self) -> dict:
        """One packed mixed-phase step: admit, COW-split, then ONE jitted
        call over [token_budget] lanes carrying prefill chunks and
        decode/spec windows together.

        The host packs segments (decode rows first — round-robin, with
        ``prefill_reserve`` lanes held back for chunks — then FCFS prefill
        chunks), runs ``self._mixed`` once, and walks the segment map:
        the tail lane of a prompt's last chunk yields its first token
        (TTFT), decode windows go through the same greedy accept rule as
        the batched verify step.
        """
        t0 = time.perf_counter()
        self._step_finished: list[int] = []
        self._step_ttfts: list[float] = []
        self._spec_accepted = self._spec_proposed = 0
        plan = self.sched.schedule()
        if plan.cow:
            # duplicate shared pages BEFORE any packed write lands on them
            self._apply_cow(plan.cow)
        segs = self.sched.plan_mixed(self.spec_window)
        N, W, bs = self.scfg.token_budget, self.spec_window, self.pcfg.page_size
        tok = np.zeros((N,), np.int32)
        sg = np.full((N,), -1, np.int32)
        ps = np.zeros((N,), np.int32)
        dc = np.zeros((N,), bool)
        vd = np.zeros((N,), bool)
        caps: dict[int, int] = {}
        off = 0
        prefill_tokens = decode_lanes = 0
        for s in segs:
            s.offset, n = off, s.n
            sg[off:off + n] = s.seq.slot
            ps[off:off + n] = s.positions
            vd[off:off + n] = True
            if s.kind == "decode":
                dc[off:off + n] = True
                tok[off] = s.seq.last_token
                if W > 1:
                    tok[off + 1:off + W] = self._propose(s.seq)
                remaining = s.seq.req.max_new - len(s.seq.emitted)
                caps[s.seq.slot] = max(0, min(
                    W - 1,
                    remaining - 1,
                    len(s.seq.pages) * bs - s.seq.length - 1))
                decode_lanes += n
            else:
                tok[off:off + n] = s.tokens
                prefill_tokens += n
            off += n
        n_tokens = 0
        if segs:
            # tables go up every step: block tables shift under admission
            # / growth / COW, and the packed step reads positions directly
            # (cache lengths are advanced host-side only)
            bt, lengths, active, last = self.sched.tables()
            self.cache = kv.set_tables(self.cache, bt, lengths)
            self._active = active
            self._tables_dirty = False
            g, self.cache = self._mixed(
                self.params, jnp.asarray(tok), jnp.asarray(sg),
                jnp.asarray(ps), jnp.asarray(dc), jnp.asarray(vd),
                self.cache)
            g = np.asarray(g, np.int32)
            now = time.perf_counter()
            for s in segs:
                seq = s.seq
                if s.kind == "chunk":
                    if s.last:
                        tok0 = int(g[s.offset + s.n - 1])
                        seq.emitted = [tok0]
                        seq.last_token = tok0
                        ttft = now - seq.req.submit_time
                        self.ttfts[seq.rid] = ttft
                        self._step_ttfts.append(ttft)
                        n_tokens += 1
                    # full blocks become prefix-cache hits as they land,
                    # not only once the whole prompt is in
                    self.sched.register_chunks(seq)
                    if seq.emitted and (
                            len(seq.emitted) >= seq.req.max_new
                            or seq.emitted[-1] == self.scfg.eos_id):
                        self._step_finished.append(seq.rid)
                        self._finish(seq)
                else:
                    w = tok[s.offset:s.offset + s.n]
                    gr = g[s.offset:s.offset + s.n]
                    cap = caps[seq.slot]
                    ar = 0
                    while ar < cap and w[ar + 1] == gr[ar]:
                        ar += 1
                    toks = [int(t) for t in w[1:ar + 1]] + [int(gr[ar])]
                    if self.scfg.eos_id >= 0 and self.scfg.eos_id in toks:
                        toks = toks[: toks.index(self.scfg.eos_id) + 1]
                    seq.emitted.extend(toks)
                    seq.last_token = seq.emitted[-1]
                    seq.length += ar + 1
                    n_tokens += len(toks)
                    if W > 1:
                        self._spec_accepted += ar
                        self._spec_proposed += cap
                    if (len(seq.emitted) >= seq.req.max_new
                            or seq.emitted[-1] == self.scfg.eos_id
                            or seq.length + 1 > self.pcfg.tokens_per_seq):
                        self._step_finished.append(seq.rid)
                        self._finish(seq)
        elif self.sched.running:
            raise RuntimeError("mixed step planned no segments while rows "
                               "are running — scheduler liveness bug")
        self._step_idx += 1
        alloc = self.sched.alloc
        return {
            "step": self._step_idx,
            "admitted": [s.rid for s in plan.admitted],
            "preempted": plan.preempted,
            "finished": self._step_finished,
            "active": len(self.sched.running),
            "waiting": len(self.sched.waiting),
            "new_tokens": n_tokens,
            "decoded": decode_lanes > 0,
            "decode_rows": decode_lanes // W,
            "page_utilization": alloc.utilization,
            "cow_splits": len(plan.cow),
            "cache_hit_tokens": sum(s.cached_tokens for s in plan.admitted),
            "pages_allocated_total": alloc.pages_allocated,
            "pages_shared_total": alloc.pages_shared,
            "pages_window_evicted": self.sched.window_evictions,
            "spec_proposed": self._spec_proposed,
            "spec_accepted": self._spec_accepted,
            "rns_ops": self._rns_ops(0),
            # phase mix of this packed step + first-token latency
            "prefill_tokens": prefill_tokens,
            "decode_tokens": n_tokens,
            "ttft_ms": (1e3 * float(np.mean(self._step_ttfts))
                        if self._step_ttfts else 0.0),
            "step_time_s": time.perf_counter() - t0,
        }

    def step(self) -> dict:
        """One scheduler step: admit/evict, prefill admits, COW-split
        shared pages, then decode (or draft+verify) every running row.

        Returns a stats dict: admitted/preempted/finished rids, tokens
        generated, page utilization, prefix-cache and speculative
        counters, and the structural ``rns_ops``.

        With ``chunked_prefill`` on, this dispatches to the packed
        mixed-phase step instead (same stats contract, plus chunked
        admission semantics).
        """
        if self.chunked:
            return self._step_mixed()
        t0 = time.perf_counter()
        self._step_finished: list[int] = []
        self._step_ttfts: list[float] = []
        self._spec_accepted = self._spec_proposed = 0
        plan = self.sched.schedule()
        if plan.admitted or plan.preempted or plan.grew or plan.cow:
            self._tables_dirty = True
        for seq in plan.admitted:
            self._do_prefill(seq)
        if plan.cow:
            # duplicate shared pages BEFORE any decode write lands on them
            self._apply_cow(plan.cow)
        # admission already produced one token per new row: those rows may
        # already be done (max_new=1 or eos on the first token)
        for seq in list(self.sched.running.values()):
            if seq.emitted and (
                    len(seq.emitted) >= seq.req.max_new
                    or seq.emitted[-1] == self.scfg.eos_id):
                self._step_finished.append(seq.rid)
                self._finish(seq)

        n_tokens = 0
        decoded = bool(self.sched.running)
        decode_rows = len(self.sched.running)
        if self.sched.running:
            bt, lengths, active, last = self.sched.tables()
            if self._tables_dirty or not np.array_equal(active, self._active):
                # topology changed: push fresh tables/lengths; otherwise the
                # decode step's own active-masked length bump already matches
                # the host counters and the upload is skipped
                self.cache = kv.set_tables(self.cache, bt, lengths)
                self._active = active
                self._tables_dirty = False
            if self.scfg.spec_decode:
                n_tokens = self._decode_spec(last)
            else:
                n_tokens = self._decode_vanilla(last)
        self._step_idx += 1
        alloc = self.sched.alloc
        return {
            "step": self._step_idx,
            "admitted": [s.rid for s in plan.admitted],
            "preempted": plan.preempted,
            "finished": self._step_finished,
            "active": len(self.sched.running),
            "waiting": len(self.sched.waiting),
            "new_tokens": n_tokens,
            "decoded": decoded,
            "decode_rows": decode_rows,
            "page_utilization": alloc.utilization,
            # prefix-cache accounting (cumulative counters + this plan)
            "cow_splits": len(plan.cow),
            "cache_hit_tokens": sum(s.cached_tokens for s in plan.admitted),
            "pages_allocated_total": alloc.pages_allocated,
            "pages_shared_total": alloc.pages_shared,
            "pages_window_evicted": self.sched.window_evictions,
            # speculative accounting (this step)
            "spec_proposed": self._spec_proposed,
            "spec_accepted": self._spec_accepted,
            "rns_ops": self._rns_ops(len(plan.admitted)),
            # phase accounting (whole-prompt prefill counts padded work
            # at admission; decode tokens are this step's emissions)
            "prefill_tokens": sum(len(s.req.tokens) for s in plan.admitted),
            "decode_tokens": n_tokens,
            "ttft_ms": (1e3 * float(np.mean(self._step_ttfts))
                        if self._step_ttfts else 0.0),
            "step_time_s": time.perf_counter() - t0,
        }

    def run(self, prompts=None, max_new: int | None = None):
        """Serve until drained.  Returns (results {rid: tokens}, stats).

        ``prompts``: optional list of 1-D prompt arrays to submit first.
        Delivered results are *drained* from the engine (a long-lived
        engine does not accumulate them); latency percentiles cover
        submit -> finish, queue wait included.  Streaming users driving
        ``submit()``/``step()`` directly read — and should pop —
        ``engine.results`` / ``engine.latencies`` themselves.
        """
        rids = [self.submit(p, max_new) for p in (prompts or [])]
        t0 = time.perf_counter()
        steps = []
        while self.sched.has_work:
            steps.append(self.step())
        dt = time.perf_counter() - t0
        done = rids if rids else list(self.results)
        out = {r: self.results.pop(r) for r in done if r in self.results}
        lat = [self.latencies.pop(r) for r in done if r in self.latencies]
        ttft = [self.ttfts.pop(r) for r in done if r in self.ttfts]
        total = sum(len(v) for v in out.values())
        decode_rows = sum(s["decode_rows"] for s in steps)
        new_in_decode = sum(s["new_tokens"] for s in steps)
        proposed = sum(s["spec_proposed"] for s in steps)
        accepted = sum(s["spec_accepted"] for s in steps)
        stats = {
            "n_requests": len(done),
            "n_steps": len(steps),
            "total_new_tokens": total,
            "wall_s": dt,
            "tokens_per_s": total / dt if dt > 0 else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)) if lat else 0.0,
            "latency_p99_s": float(np.percentile(lat, 99)) if lat else 0.0,
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "ttft_p95_s": float(np.percentile(ttft, 95)) if ttft else 0.0,
            "mean_page_utilization": float(
                np.mean([s["page_utilization"] for s in steps])) if steps
            else 0.0,
            "n_preemptions": sum(len(s["preempted"]) for s in steps),
            # speculative decoding: mean decoded tokens per ROW per decode
            # step (> 1 iff drafts are being accepted) and the acceptance
            # rate over eligible (cap-respecting) drafts
            "tokens_per_step": (new_in_decode / decode_rows
                                if decode_rows else 0.0),
            "acceptance_rate": accepted / proposed if proposed else 0.0,
            # prefix caching: cumulative allocator/COW traffic
            "cache_hit_tokens": sum(s["cache_hit_tokens"] for s in steps),
            "cow_splits": sum(s["cow_splits"] for s in steps),
            "pages_allocated": self.sched.alloc.pages_allocated,
            "pages_shared": self.sched.alloc.pages_shared,
            # sliding window: cumulative pages recycled by eviction
            "pages_window_evicted": self.sched.window_evictions,
            "steps": steps,
        }
        return out, stats
