"""Batched serving engine: prefill + greedy decode with per-row stopping.

Batches are grouped by exact prompt length (bucketed batching); decode is a
jitted step over the shared cache with per-row lengths, so rows that hit
EOS simply stop contributing (their token is frozen).

When the model config routes projections through RNS, the engine owns the
execution policy: ``rns_backend`` picks the dispatch backend (reference /
pallas) and ``rns_defer`` turns on the residue-domain MLP chain — serving
is forward-only, so deferral is free (no vjp concerns) and drops the
slow-normalize count per block.  ``rns_op_counts`` reports the structural
convert/matmul/normalize tallies of one prefill, the serving-side view of
the paper's one-normalize-per-summation claim.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dispatch
from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_cache: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early
    cache_dtype: str = "float32"
    # RNS execution policy overrides (None: keep the model config's)
    rns_backend: str | None = None   # reference|pallas|pallas_interpret|auto
    rns_defer: bool | None = None    # residue-domain MLP chaining


def _apply_rns_policy(model_cfg, scfg: ServeConfig):
    if model_cfg.rns is None or (
            scfg.rns_backend is None and scfg.rns_defer is None):
        return model_cfg
    rns = model_cfg.rns
    if scfg.rns_backend is not None:
        rns = dataclasses.replace(rns, backend=scfg.rns_backend)
    if scfg.rns_defer is not None:
        rns = dataclasses.replace(rns, defer=scfg.rns_defer)
    return dataclasses.replace(model_cfg, rns=rns)


class Engine:
    def __init__(self, params, model_cfg, scfg: ServeConfig):
        self.params = params
        self.cfg = _apply_rns_policy(model_cfg, scfg)
        self.scfg = scfg
        self._prefill = jax.jit(
            functools.partial(M.prefill, cfg=self.cfg, S_max=scfg.max_cache,
                              cache_dtype=jnp.dtype(scfg.cache_dtype)),
            static_argnames=())
        self._decode = jax.jit(
            lambda params, tok, cache: M.decode_step(
                params, self.cfg, tok, cache))

    def rns_op_counts(self, B: int = 1, T: int = 8) -> dispatch.OpCounts:
        """Structural RNS primitive counts for one [B, T] prefill trace."""
        batch = {"tokens": jnp.zeros((B, T), jnp.int32)}
        return dispatch.trace_op_counts(
            lambda p, b: M.prefill(p, self.cfg, b, S_max=self.scfg.max_cache),
            self.params, batch)

    def generate(self, prompts: np.ndarray, frontend: np.ndarray | None = None,
                 max_new: int | None = None):
        """prompts [B, T] int32 (equal lengths). Returns [B, n_new] tokens."""
        max_new = max_new or self.scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        logits, cache = self._prefill(self.params, batch=batch)
        B = prompts.shape[0]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        out = [tok]
        for _ in range(max_new - 1):
            done = done | (tok[:, 0] == self.scfg.eos_id)
            logits, cache = self._decode(self.params, tok, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok = jnp.where(done[:, None], tok, nxt)
            out.append(tok)
            if bool(jnp.all(done)):
                break
        return np.asarray(jnp.concatenate(out, axis=1))
