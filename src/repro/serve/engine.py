"""Batched serving engine: prefill + greedy decode with per-row stopping.

Batches are grouped by exact prompt length (bucketed batching); decode is a
jitted step over the shared cache with per-row lengths, so rows that hit
EOS simply stop contributing (their token is frozen).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class ServeConfig:
    max_cache: int = 512
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stops early
    cache_dtype: str = "float32"


class Engine:
    def __init__(self, params, model_cfg, scfg: ServeConfig):
        self.params = params
        self.cfg = model_cfg
        self.scfg = scfg
        self._prefill = jax.jit(
            functools.partial(M.prefill, cfg=model_cfg, S_max=scfg.max_cache,
                              cache_dtype=jnp.dtype(scfg.cache_dtype)),
            static_argnames=())
        self._decode = jax.jit(
            lambda params, tok, cache: M.decode_step(
                params, model_cfg, tok, cache))

    def generate(self, prompts: np.ndarray, frontend: np.ndarray | None = None,
                 max_new: int | None = None):
        """prompts [B, T] int32 (equal lengths). Returns [B, n_new] tokens."""
        max_new = max_new or self.scfg.max_new_tokens
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if frontend is not None:
            batch["frontend"] = jnp.asarray(frontend)
        logits, cache = self._prefill(self.params, batch=batch)
        B = prompts.shape[0]
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        done = jnp.zeros((B,), bool)
        out = [tok]
        for _ in range(max_new - 1):
            done = done | (tok[:, 0] == self.scfg.eos_id)
            logits, cache = self._decode(self.params, tok, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            tok = jnp.where(done[:, None], tok, nxt)
            out.append(tok)
            if bool(jnp.all(done)):
                break
        return np.asarray(jnp.concatenate(out, axis=1))
