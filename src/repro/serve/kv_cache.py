"""Paged KV cache: fixed-size pages + per-sequence block tables.

The bucketed engine's dense cache couples cache capacity to the *batch*:
every row owns ``[S_max]`` slots whether it uses 7 of them or 120.  The
paged cache decouples the two (the vLLM/TensorRT-LLM in-flight-batching
layout): one physical pool of ``n_pages`` pages of ``page_size`` tokens
each, and per-sequence **block tables** mapping logical block ``t //
page_size`` to a physical page.  Mixed-length sequences then share one
jitted decode step — the step's shapes depend only on ``(max_seqs,
max_blocks, page_size)``, never on any prompt length — and a finished
row's pages return to the pool immediately.

Layout per attention layer (leading ``n_periods`` dim added by the scan
stacking, exactly like the dense cache):

  * ``attn``: ``k_pages`` / ``v_pages``  ``[n_pages, page_size, Hk, D]``
  * ``mla``:  ``ckv_pages`` ``[n_pages, page_size, r]``,
              ``krope_pages`` ``[n_pages, page_size, dr]``
  * both:     ``block_table`` ``[max_seqs, max_blocks]`` int32,
              ``lengths`` ``[max_seqs]`` int32

Physical page 0 is the **trash page**: the block-table entries of empty
slots (and of logical blocks past a sequence's end) point at it, so every
gather/scatter stays in bounds with no per-row branching — reads through
it are masked by ``lengths`` and writes to it are discarded garbage.

The device-side helpers here (:func:`gather_pages`, :func:`write_token`,
:func:`write_prompt_pages`) are pure jnp and are consumed by
``models/attention.py``; the host-side :class:`PageAllocator` free list
is consumed by ``serve/scheduler.py``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "TRASH_PAGE",
    "PagedCacheConfig",
    "PageAllocator",
    "make_paged_cache",
    "set_tables",
    "gather_pages",
    "write_token",
    "write_prompt_pages",
]

#: physical page reserved as the write-target / read-source of inactive
#: rows; never handed out by the allocator, never read unmasked.
TRASH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static shape of the paged pool.

    ``max_blocks * page_size`` is the per-sequence capacity (the paged
    analogue of the dense cache's ``S_max``); ``n_pages`` bounds the
    *total* tokens resident across all sequences — the knob that trades
    memory for concurrency.  Page 0 is reserved (trash), so the usable
    pool is ``n_pages - 1`` pages.
    """

    page_size: int = 16
    n_pages: int = 129          # 128 usable + trash
    max_seqs: int = 8           # decode slots (R)
    max_blocks: int = 8         # logical blocks per sequence

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 2:
            raise ValueError("need page_size >= 1 and n_pages >= 2")
        if self.n_pages - 1 < self.max_blocks:
            raise ValueError(
                f"pool of {self.n_pages - 1} usable pages cannot hold even "
                f"one full sequence ({self.max_blocks} blocks)")

    @property
    def tokens_per_seq(self) -> int:
        return self.page_size * self.max_blocks


class PageAllocator:
    """Host-side free list over physical pages 1..n_pages-1 (0 = trash).

    ``free`` is IDEMPOTENT: a page already on the free list is skipped
    rather than raised on.  The scheduler can preempt a sequence in the
    same engine step that it finishes (growth runs before the finished
    check), and the preemption path and the completion path both release
    pages — releasing twice must not corrupt the free list or hand one
    physical page to two sequences.  Out-of-range ids still raise: those
    are real bugs, not benign races.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        # LIFO reuse keeps the working set of hot pages small
        self._free = list(range(n_pages - 1, TRASH_PAGE, -1))
        self._free_set = set(self._free)    # O(1) idempotence check

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        usable = self.n_pages - 1
        return (usable - len(self._free)) / max(usable, 1)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages, or None (and no change) if not enough."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        for pg in pages:
            if not (TRASH_PAGE < pg < self.n_pages):
                raise ValueError(f"bad page id {pg}")
            if pg in self._free_set:
                continue                    # already free: idempotent
            self._free.append(pg)
            self._free_set.add(pg)


# ------------------------------------------------------- device pytrees ---
def _layer_pages(cfg, lt: str, pcfg: PagedCacheConfig, dtype):
    P, bs = pcfg.n_pages, pcfg.page_size
    if lt == "attn":
        kv = (P, bs, cfg.n_kv_heads, cfg.d_head)
        return {"k_pages": jnp.zeros(kv, dtype), "v_pages": jnp.zeros(kv, dtype)}
    if lt == "mla":
        m = cfg.mla
        return {
            "ckv_pages": jnp.zeros((P, bs, m.kv_lora_rank), dtype),
            "krope_pages": jnp.zeros((P, bs, m.qk_rope_dim), dtype),
        }
    raise NotImplementedError(
        f"paged serving supports attn/mla layers only, got {lt!r} "
        "(SSM states are fixed-size per sequence — nothing to page)")


def make_paged_cache(cfg, pcfg: PagedCacheConfig, *, dtype=jnp.bfloat16):
    """Zero paged decode cache, periods-stacked like ``model.make_cache``."""
    p = cfg.period
    n_periods = cfg.n_layers // p

    def one_period():
        per = {}
        for j in range(p):
            c = _layer_pages(cfg, cfg.layer_types[j], pcfg, dtype)
            c["block_table"] = jnp.zeros(
                (pcfg.max_seqs, pcfg.max_blocks), jnp.int32)
            c["lengths"] = jnp.zeros((pcfg.max_seqs,), jnp.int32)
            per[f"l{j}"] = c
        return per

    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape),
        one_period())


def set_tables(cache, block_tables, lengths):
    """Overwrite every layer's block table + lengths from host arrays.

    The scheduler owns both as numpy state; the engine pushes them into
    the device cache right before each decode step (tiny transfers — the
    page pool itself never leaves the device).
    """
    bt = jnp.asarray(block_tables, jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32)

    def walk(d):
        out = {}
        for k, v in d.items():
            if k == "block_table":
                out[k] = jnp.broadcast_to(bt[None], (v.shape[0],) + bt.shape)
            elif k == "lengths":
                out[k] = jnp.broadcast_to(ln[None], (v.shape[0],) + ln.shape)
            elif isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = v
        return out

    return walk(cache)


# ------------------------------------------------------ gather / scatter --
def gather_pages(pages, block_table):
    """[P, bs, ...] pages + [R, nb] table -> dense [R, nb*bs, ...] view.

    Logical token position t of row r lives at
    ``pages[block_table[r, t // bs], t % bs]``; the gather lays rows out
    contiguously so downstream attention is *identical* to the dense-cache
    path (bit for bit — asserted in tests/test_kv_cache.py).
    """
    R = block_table.shape[0]
    g = pages[block_table]                      # [R, nb, bs, ...]
    return g.reshape((R, -1) + pages.shape[2:])


def write_token(pages, block_table, lengths, vals):
    """Scatter one new token per row at its current length.

    ``vals`` [R, ...]: row r goes to page ``block_table[r, lengths[r] //
    bs]`` offset ``lengths[r] % bs``.  Rows whose tables point at the
    trash page (inactive slots) write garbage there harmlessly — and a
    row somehow past capacity (block index >= nb) is *redirected* to the
    trash page rather than clipped onto a real page, so a scheduler bug
    can never corrupt a live token.
    """
    bs = pages.shape[1]
    blk = lengths // bs
    page = jnp.take_along_axis(block_table, blk[:, None], axis=1,
                               mode="fill", fill_value=TRASH_PAGE)[:, 0]
    return pages.at[page, lengths % bs].set(vals.astype(pages.dtype))


def write_prompt_pages(pages, block_row, planes):
    """Blit one prefilled prompt into its pages (periods-stacked).

    ``pages`` [n_periods, P, bs, ...]; ``block_row`` [nbp] physical page
    per logical block (trash for blocks past the prompt); ``planes``
    [n_periods, 1, Tpad, ...] with ``Tpad == nbp * bs``.  Whole pages are
    overwritten — positions past the prompt length hold garbage that
    ``lengths`` masks at read time.
    """
    npr, P, bs = pages.shape[:3]
    nbp = block_row.shape[0]
    v = planes.reshape((npr, nbp, bs) + planes.shape[3:])
    return pages.at[:, block_row].set(v.astype(pages.dtype))
