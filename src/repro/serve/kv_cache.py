"""Paged KV cache: fixed-size pages + per-sequence block tables.

The bucketed engine's dense cache couples cache capacity to the *batch*:
every row owns ``[S_max]`` slots whether it uses 7 of them or 120.  The
paged cache decouples the two (the vLLM/TensorRT-LLM in-flight-batching
layout): one physical pool of ``n_pages`` pages of ``page_size`` tokens
each, and per-sequence **block tables** mapping logical block ``t //
page_size`` to a physical page.  Mixed-length sequences then share one
jitted decode step — the step's shapes depend only on ``(max_seqs,
max_blocks, page_size)``, never on any prompt length — and a finished
row's pages return to the pool immediately.

Layout per attention layer (leading ``n_periods`` dim added by the scan
stacking, exactly like the dense cache):

  * ``attn``: ``k_pages`` / ``v_pages``  ``[n_pages, page_size, Hk, D]``
  * ``mla``:  ``ckv_pages`` ``[n_pages, page_size, r]``,
              ``krope_pages`` ``[n_pages, page_size, dr]``
  * both:     ``block_table`` ``[max_seqs, max_blocks]`` int32,
              ``lengths`` ``[max_seqs]`` int32

Physical page 0 is the **trash page**: the block-table entries of empty
slots (and of logical blocks past a sequence's end) point at it, so every
gather/scatter stays in bounds with no per-row branching — reads through
it are masked by ``lengths`` and writes to it are discarded garbage.

The device-side helpers here (:func:`gather_pages`, :func:`write_token`,
:func:`write_prompt_pages`) are pure jnp and are consumed by
``models/attention.py``; the host-side :class:`PageAllocator` free list
is consumed by ``serve/scheduler.py``.
"""

from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TRASH_PAGE",
    "PagedCacheConfig",
    "PageAllocator",
    "PrefixCache",
    "make_paged_cache",
    "set_tables",
    "gather_pages",
    "write_token",
    "write_token_window",
    "write_packed_tokens",
    "write_prompt_pages",
    "copy_pages",
]

#: physical page reserved as the write-target / read-source of inactive
#: rows; never handed out by the allocator, never read unmasked.
TRASH_PAGE = 0


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """Static shape of the paged pool.

    ``max_blocks * page_size`` is the per-sequence capacity (the paged
    analogue of the dense cache's ``S_max``); ``n_pages`` bounds the
    *total* tokens resident across all sequences — the knob that trades
    memory for concurrency.  Page 0 is reserved (trash), so the usable
    pool is ``n_pages - 1`` pages.

    ``resident_blocks`` (optional) caps how many of a sequence's blocks
    are ever physically resident at once: sliding-window serving evicts
    pages behind the window, so a pool far smaller than ``max_blocks``
    can still serve arbitrarily long rows.  It only relaxes the
    feasibility check here — block tables keep ``max_blocks`` columns
    (positions stay absolute; evicted entries point at trash).
    """

    page_size: int = 16
    n_pages: int = 129          # 128 usable + trash
    max_seqs: int = 8           # decode slots (R)
    max_blocks: int = 8         # logical blocks per sequence
    resident_blocks: int | None = None   # physical bound (None = max_blocks)

    def __post_init__(self):
        if self.page_size < 1 or self.n_pages < 2:
            raise ValueError("need page_size >= 1 and n_pages >= 2")
        need = self.max_blocks if self.resident_blocks is None \
            else min(self.max_blocks, self.resident_blocks)
        if self.n_pages - 1 < need:
            raise ValueError(
                f"pool of {self.n_pages - 1} usable pages cannot hold even "
                f"one resident sequence ({need} blocks)")

    @property
    def tokens_per_seq(self) -> int:
        return self.page_size * self.max_blocks


class PageAllocator:
    """Refcounted host-side free list over pages 1..n_pages-1 (0 = trash).

    Prefix caching maps several sequences' block tables (plus the prefix
    index itself) onto one physical page, so every allocated page carries
    a reference count: :meth:`alloc` hands out pages at refcount 1,
    :meth:`incref` registers another holder, and :meth:`free` releases
    ONE holder's reference — the page returns to the free list only when
    the last holder lets go.

    ``free`` stays IDEMPOTENT for fully-released pages: a page already on
    the free list is skipped rather than raised on.  The scheduler can
    preempt a sequence in the same engine step that it finishes (growth
    runs before the finished check), and the preemption path and the
    completion path both release pages — releasing twice must not corrupt
    the free list, hand one physical page to two sequences, or drive a
    *shared* page's count below its other holders' (the scheduler zeroes
    a stale state's page list at its first release, so a double release
    can only ever see an already-free page).  Out-of-range ids still
    raise: those are real bugs, not benign races.
    """

    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        # LIFO reuse keeps the working set of hot pages small
        self._free = list(range(n_pages - 1, TRASH_PAGE, -1))
        self._free_set = set(self._free)    # O(1) idempotence check
        self._ref: dict[int, int] = {}      # allocated page -> #holders
        # cumulative traffic counters (the zero-redundant-write assertions
        # in tests/benchmarks read these)
        self.pages_allocated = 0            # fresh pages handed out
        self.pages_shared = 0               # increfs (block-table reuse)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def utilization(self) -> float:
        usable = self.n_pages - 1
        return (usable - len(self._free)) / max(usable, 1)

    def refcount(self, pg: int) -> int:
        """Current holder count (0 for free pages)."""
        return self._ref.get(pg, 0)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages at refcount 1, or None (no change) if short."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(pages)
        for pg in pages:
            self._ref[pg] = 1
        self.pages_allocated += n
        return pages

    def incref(self, pages: list[int]) -> None:
        """Register another holder of already-allocated pages."""
        for pg in pages:
            if self._ref.get(pg, 0) < 1:
                raise ValueError(f"incref of unallocated page {pg}")
            self._ref[pg] += 1
        self.pages_shared += len(pages)

    def free(self, pages: list[int]) -> None:
        for pg in pages:
            if not (TRASH_PAGE < pg < self.n_pages):
                raise ValueError(f"bad page id {pg}")
            if pg in self._free_set:
                continue                    # already free: idempotent
            self._ref[pg] -= 1
            if self._ref[pg] > 0:
                continue                    # other holders keep it alive
            del self._ref[pg]
            self._free.append(pg)
            self._free_set.add(pg)


class PrefixCache:
    """Content-addressed prefix -> physical-page index (host side).

    Causal attention makes a page's KV a pure function of the token
    prefix ending at that page, so a page can be keyed by the *exact
    bytes* of that prefix: ``key(i) = tokens[: (i+1)*page_size]`` for a
    full block, ``key = tokens[:T]`` for a prompt's partial last block.
    Exact byte keys mean lookups can never alias distinct prefixes — two
    different prefixes have different keys, full stop (no hashing
    collisions to reason about; python's dict hashing is an
    implementation detail behind exact key equality).

    Entries hold one allocator reference each (the index is a holder like
    any sequence), so a cached page survives its producer and is
    reclaimed by :meth:`evict` (LRU) when the pool runs dry.  Partial
    entries expose ``valid`` tokens; an adopting sequence reads only
    positions < ``valid`` (masked by its lengths) and COW-splits the page
    on its first write into it (see serve/scheduler.py).

    **Liveness guard.**  Every hit is re-validated against the allocator
    before it is returned: an entry whose page shows refcount 0 is STALE
    — some holder over-released and the page went back to the pool (from
    where it may be handed to an unrelated row and rewritten) while the
    index still pointed at it.  Returning it to a byte-identical resubmit
    would silently serve foreign KV.  Stale entries are dropped on sight
    (:meth:`lookup`/:meth:`_get`, :meth:`evict`), skipped by
    :meth:`peek_cached_tokens`, and refused by :meth:`insert` (a trash or
    unallocated page is never indexed); ``stale_drops`` counts the
    self-heals so tests can assert the guard fired.
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        self.alloc = alloc
        self.bs = page_size
        # key -> (page, valid_tokens); ordered = LRU (oldest first)
        self._entries: collections.OrderedDict[bytes, tuple[int, int]] = (
            collections.OrderedDict())
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_drops = 0    # entries dropped by the liveness guard
        #: bumped whenever the entry set changes — peek results are only
        #: valid within one generation (the scheduler memoizes on it)
        self.generation = 0

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _bytes(tokens: np.ndarray, n: int) -> bytes:
        return np.ascontiguousarray(tokens[:n], np.int32).tobytes()

    def _get(self, key: bytes):
        e = self._entries.get(key)
        if e is None:
            return None
        if self.alloc.refcount(e[0]) < 1:
            # stale: the page was over-released back to the pool while
            # the index held it — drop the entry so a byte-identical
            # resubmit misses cleanly instead of adopting a page that
            # may since have been reallocated and rewritten
            del self._entries[key]
            self.stale_drops += 1
            self.generation += 1
            return None
        self._entries.move_to_end(key)      # LRU touch
        return e

    def lookup(self, tokens: np.ndarray):
        """Per-block share map for a prompt: ([page_or_None per block],
        n_cached_tokens).  Blocks are independent — the key of block i
        embeds the whole prefix, so a later block can hit even if an
        earlier one was evicted (the admitting sequence recomputes and
        blits the misses; the hits are adopted read-only).  The last
        partial block hits only on an exact whole-prompt match.  Pages
        are returned WITHOUT a reference; the adopter increfs.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        T = len(tokens)
        shared: list[int | None] = []
        n_cached = 0
        for i in range(T // self.bs):
            e = self._get(self._bytes(tokens, (i + 1) * self.bs))
            shared.append(e[0] if e is not None else None)
            if e is not None:
                n_cached += self.bs
                self.hits += 1
            else:
                self.misses += 1
        if T % self.bs:
            e = self._get(self._bytes(tokens, T))
            shared.append(e[0] if e is not None else None)
            if e is not None:
                n_cached += T % self.bs
                self.hits += 1
            else:
                self.misses += 1
        return shared, n_cached

    def peek_cached_tokens(self, tokens: np.ndarray) -> int:
        """Cached-token count for a prompt WITHOUT touching LRU order or
        the hit/miss counters — the scheduler's admission-preference scan
        probes every waiting request and must not pollute either."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        T = len(tokens)
        n = 0

        def live(key):      # liveness-checked, mutation-free probe
            e = self._entries.get(key)
            return e is not None and self.alloc.refcount(e[0]) >= 1

        for i in range(T // self.bs):
            if live(self._bytes(tokens, (i + 1) * self.bs)):
                n += self.bs
        if T % self.bs and live(self._bytes(tokens, T)):
            n += T % self.bs
        return n

    def insert(self, tokens: np.ndarray, pages: list[int]) -> int:
        """Register a prefilled prompt's blocks; returns #new entries.

        Every full block (and the partial tail, if any) is keyed by its
        prefix bytes and increfs its page.  Keys that already exist are
        left alone — by content addressing the existing page holds the
        identical KV.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        T = len(tokens)
        added = 0
        ends = [(i + 1) * self.bs for i in range(T // self.bs)]
        if T % self.bs:
            ends.append(T)
        for i, end in enumerate(ends):
            key = self._bytes(tokens, end)
            if key in self._entries or i >= len(pages):
                continue
            if pages[i] == TRASH_PAGE or self.alloc.refcount(pages[i]) < 1:
                continue    # evicted/placeholder block: never index it
            self.alloc.incref([pages[i]])
            self._entries[key] = (pages[i], end)
            self._entries.move_to_end(key)
            added += 1
        if added:
            self.generation += 1
        return added

    def evict(self, n_pages: int) -> int:
        """Release up to ``n_pages`` LRU entries whose page would
        actually return to the pool (refcount 1 — index-only holders);
        entries still shared by running sequences are kept (hot).
        Returns the number of pages freed."""
        freed = 0
        dropped = 0
        for key in list(self._entries):
            if freed >= n_pages:
                break
            page, _ = self._entries[key]
            rc = self.alloc.refcount(page)
            if rc == 0:                 # stale (over-released): self-heal
                del self._entries[key]
                self.stale_drops += 1
                dropped += 1
                continue
            if rc != 1:
                continue
            del self._entries[key]
            self.alloc.free([page])
            self.evictions += 1
            freed += 1
        if freed or dropped:
            self.generation += 1
        return freed


# ------------------------------------------------------- device pytrees ---
def _layer_pages(cfg, lt: str, pcfg: PagedCacheConfig, dtype):
    P, bs = pcfg.n_pages, pcfg.page_size
    if lt == "attn":
        kv = (P, bs, cfg.n_kv_heads, cfg.d_head)
        return {"k_pages": jnp.zeros(kv, dtype), "v_pages": jnp.zeros(kv, dtype)}
    if lt == "mla":
        m = cfg.mla
        return {
            "ckv_pages": jnp.zeros((P, bs, m.kv_lora_rank), dtype),
            "krope_pages": jnp.zeros((P, bs, m.qk_rope_dim), dtype),
        }
    raise NotImplementedError(
        f"paged serving supports attn/mla layers only, got {lt!r} "
        "(SSM states are fixed-size per sequence — nothing to page)")


def make_paged_cache(cfg, pcfg: PagedCacheConfig, *, dtype=jnp.bfloat16):
    """Zero paged decode cache, periods-stacked like ``model.make_cache``."""
    p = cfg.period
    n_periods = cfg.n_layers // p

    def one_period():
        per = {}
        for j in range(p):
            c = _layer_pages(cfg, cfg.layer_types[j], pcfg, dtype)
            c["block_table"] = jnp.zeros(
                (pcfg.max_seqs, pcfg.max_blocks), jnp.int32)
            c["lengths"] = jnp.zeros((pcfg.max_seqs,), jnp.int32)
            per[f"l{j}"] = c
        return per

    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_periods,) + x.shape),
        one_period())


def set_tables(cache, block_tables, lengths):
    """Overwrite every layer's block table + lengths from host arrays.

    The scheduler owns both as numpy state; the engine pushes them into
    the device cache right before each decode step (tiny transfers — the
    page pool itself never leaves the device).
    """
    bt = jnp.asarray(block_tables, jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32)

    def walk(d):
        out = {}
        for k, v in d.items():
            if k == "block_table":
                out[k] = jnp.broadcast_to(bt[None], (v.shape[0],) + bt.shape)
            elif k == "lengths":
                out[k] = jnp.broadcast_to(ln[None], (v.shape[0],) + ln.shape)
            elif isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = v
        return out

    return walk(cache)


# ------------------------------------------------------ gather / scatter --
def gather_pages(pages, block_table):
    """[P, bs, ...] pages + [R, nb] table -> dense [R, nb*bs, ...] view.

    Logical token position t of row r lives at
    ``pages[block_table[r, t // bs], t % bs]``; the gather lays rows out
    contiguously so downstream attention is *identical* to the dense-cache
    path (bit for bit — asserted in tests/test_kv_cache.py).
    """
    R = block_table.shape[0]
    g = pages[block_table]                      # [R, nb, bs, ...]
    return g.reshape((R, -1) + pages.shape[2:])


def write_token(pages, block_table, lengths, vals):
    """Scatter one new token per row at its current length.

    ``vals`` [R, ...]: row r goes to page ``block_table[r, lengths[r] //
    bs]`` offset ``lengths[r] % bs``.  Rows whose tables point at the
    trash page (inactive slots) write garbage there harmlessly — and a
    row somehow past capacity (block index >= nb) is *redirected* to the
    trash page rather than clipped onto a real page, so a scheduler bug
    can never corrupt a live token.
    """
    bs = pages.shape[1]
    blk = lengths // bs
    page = jnp.take_along_axis(block_table, blk[:, None], axis=1,
                               mode="fill", fill_value=TRASH_PAGE)[:, 0]
    return pages.at[page, lengths % bs].set(vals.astype(pages.dtype))


def write_token_window(pages, block_table, lengths, vals):
    """Scatter W consecutive tokens per row starting at its length.

    ``vals`` [R, W, ...] (a speculative-verify window): token i of row r
    goes to logical position ``lengths[r] + i``.  Like
    :func:`write_token`, positions past the row's table (block index >=
    nb) or on unallocated blocks redirect to the trash page, so draft
    tokens past a row's pages lose their KV harmlessly — the engine caps
    acceptance to what landed on real pages.
    """
    bs = pages.shape[1]
    W = vals.shape[1]
    pos = lengths[:, None] + jnp.arange(W)[None]            # [R, W]
    page = jnp.take_along_axis(block_table, pos // bs, axis=1,
                               mode="fill", fill_value=TRASH_PAGE)
    return pages.at[page, pos % bs].set(vals.astype(pages.dtype))


def write_packed_tokens(pages, block_table, seg, pos, vals):
    """Scatter N packed tokens at explicit (segment, position) coords.

    ``vals`` [N, ...] (a mixed chunked-prefill/decode step): token i goes
    to logical position ``pos[i]`` of row ``seg[i]`` — physical page
    ``block_table[seg[i], pos[i] // bs]`` offset ``pos[i] % bs``.  Unlike
    :func:`write_token`/:func:`write_token_window`, each token carries
    its own segment and position, so one scatter serves any mix of
    prefill chunks and decode rows.  Pad lanes carry ``seg = -1`` and
    are redirected to the trash page, as are positions past a row's
    table (block index >= nb) — invalid lanes lose their KV harmlessly.
    """
    bs = pages.shape[1]
    R = block_table.shape[0]
    segc = jnp.clip(seg, 0, R - 1)
    page = jnp.take_along_axis(block_table[segc], (pos // bs)[:, None],
                               axis=1, mode="fill",
                               fill_value=TRASH_PAGE)[:, 0]
    page = jnp.where(seg >= 0, page, TRASH_PAGE)
    return pages.at[page, pos % bs].set(vals.astype(pages.dtype))


def copy_pages(pages, src, dst):
    """Copy page contents src[i] -> dst[i] (periods-stacked pool).

    ``pages`` [n_periods, P, bs, ...]; ``src``/``dst`` [m] int32.  The
    copy-on-write split: a sequence about to write into a shared page
    first duplicates it onto a fresh page and repoints its block table.
    No-op rows pass ``src = dst = TRASH_PAGE`` (trash copies onto trash),
    which keeps the jitted copy's shapes fixed — one compile ever.
    """
    return pages.at[:, dst].set(pages[:, src])


def write_prompt_pages(pages, block_row, planes):
    """Blit one prefilled prompt into its pages (periods-stacked).

    ``pages`` [n_periods, P, bs, ...]; ``block_row`` [nbp] physical page
    per logical block (trash for blocks past the prompt); ``planes``
    [n_periods, 1, Tpad, ...] with ``Tpad == nbp * bs``.  Whole pages are
    overwritten — positions past the prompt length hold garbage that
    ``lengths`` masks at read time.
    """
    npr, P, bs = pages.shape[:3]
    nbp = block_row.shape[0]
    v = planes.reshape((npr, nbp, bs) + planes.shape[3:])
    return pages.at[:, block_row].set(v.astype(pages.dtype))
