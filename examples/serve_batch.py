"""Batched serving: prefill + greedy decode with per-row stopping.

    PYTHONPATH=src python examples/serve_batch.py --arch tinyllama-1.1b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(
        max_cache=args.prompt_len + args.new + 8, max_new_tokens=args.new))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))
    frontend = None
    if cfg.frontend:
        frontend = rng.standard_normal(
            (args.batch, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)

    out = eng.generate(prompts.astype(np.int32), frontend=frontend)  # compile
    t0 = time.perf_counter()
    out = eng.generate(prompts.astype(np.int32), frontend=frontend)
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"new={out.shape[1]}")
    print(f"warm throughput: {out.size/dt:.1f} tok/s (CPU, smoke config)")
    print("first row:", out[0][:16], "...")


if __name__ == "__main__":
    main()
