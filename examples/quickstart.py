"""Quickstart: the RNS-TPU datapath in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import dispatch, mrc, rns
from repro.core.moduli import get_profile
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_matmul_res
from repro.core.tensor import rt_decode, rt_encode, rt_matmul

# 1. A working register: 9 pairwise-coprime moduli <= 128 (8-bit words),
#    ~62 bits of dynamic range — the Rez-9/18-class register of the paper.
p = get_profile("rns9")
print(f"moduli = {p.moduli}")
print(f"range  = {p.range_bits:.1f} bits; M = {p.M}")

# 2. Carry-free PAC arithmetic: every digit operates independently.
a, b = np.int32(123456789), np.int32(-987654)
ra, rb = rns.encode_int32(p, a), rns.encode_int32(p, b)
prod = rns.rns_mul(p, ra, rb)
print(f"{a} * {b} = {int(rns.decode_exact(p, np.asarray(prod)))} (exact, "
      "computed in 9 parallel 8-bit lanes, no carries)")

# 3. The paper's core claim: an entire product summation is PAC; the one
#    "slow" normalization (mixed-radix conversion) happens once at the end.
rng = np.random.default_rng(0)
D = 65536
x = rng.integers(-32767, 32768, (1, D)).astype(np.int32)
w = rng.integers(-32767, 32768, (D, 1)).astype(np.int32)
res = rns_matmul_res("rns9", rns.encode_int32(p, x), rns.encode_int32(p, w))
exact = int(rns.decode_exact(p, np.asarray(res))[0, 0])
want = int((x.astype(object) @ w.astype(object))[0, 0])
f32 = int(float((x.astype(np.float32) @ w.astype(np.float32))[0, 0]))
print(f"\n65536-term dot of int16 operands:")
print(f"  python-int oracle : {want}")
print(f"  RNS digit slices  : {exact}   (error {exact - want})")
print(f"  float32 MAC       : {f32}   (error {f32 - want})")

# 4. Drop-in float matmul through the digit-sliced datapath (custom_vjp
#    makes it trainable; backward matmuls run in RNS too).
xf = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
wf = jnp.asarray(rng.standard_normal((256, 8)), jnp.float32)
y = rns_dot(xf, wf, RnsDotConfig(profile="rns9", qx=16, qw=16))
ref = xf @ wf
print(f"\nrns_dot vs float matmul: max rel err = "
      f"{float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref))):.2e} "
      "(16-bit quantization, exact accumulation)")

# 5. Cross-op deferral: RnsTensor keeps a CHAIN of linears in residues —
#    three matmuls, ONE slow normalization (vs one per matmul above).
ws = [jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
      for _ in range(3)]
xc = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
with dispatch.count_ops() as ops:
    ht = rt_encode(xc, "rns9", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns9", bits=8))
    yc = rt_decode(ht)  # <- the chain's single MRC
refc = xc
for w in ws:
    refc = refc @ w
print(f"\n3-linear residue chain: {ops.matmuls} matmuls, "
      f"{ops.normalizes} normalization ({ops.normalizes_per_matmul:.2f} "
      f"slow ops/matmul); max err vs float chain = "
      f"{float(jnp.max(jnp.abs(yc - refc))):.3f}")

# 6. Serving the datapath: the continuous-batching engine decodes
#    mixed-length prompts through ONE jitted step over a paged KV cache —
#    no per-length recompiles, pages freed the moment a row finishes.
#    (docs/serving.md has the full design.)
import jax

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, ServeConfig

cfg = get_config("smollm-135m", smoke=True)
params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
engine = ContinuousEngine(params, cfg, ServeConfig(
    max_cache=64, max_new_tokens=6, page_size=16, max_seqs=3))
prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
           for L in (5, 17, 40)]
results, stats = engine.run(prompts)
print(f"\ncontinuous serving, prompt lengths (5, 17, 40): "
      f"{stats['n_requests']} requests in {stats['n_steps']} steps, "
      f"{stats['tokens_per_s']:.0f} tok/s, page util "
      f"{stats['mean_page_utilization']:.2f}, decode compiles = "
      f"{engine._decode._cache_size()}")
print("tokens:", {r: t.tolist() for r, t in sorted(results.items())})

# 7. Residue channels as a mesh axis: digits are carry-free and mutually
#    independent, so the SAME chain runs sharded over a device mesh —
#    each device owns a group of moduli, convert/matmul are local, only
#    the decode's MRC gathers digits.  On this process's single device
#    the mesh is 1-wide (nothing to partition, identical bits); run with
#    XLA_FLAGS=--xla_force_host_platform_device_count=8 to watch rns16
#    split 2 digits per device (docs/distributed.md, BENCH_dist.json).
from repro.distributed.sharding import use_digit_sharding
from repro.launch.mesh import make_digit_mesh


def chain_sharded(x, ws):   # fresh def: jax's trace cache is per-function
    ht = rt_encode(x, "rns16", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns16", bits=8))
    return rt_decode(ht)


def chain_ref(x, ws):
    ht = rt_encode(x, "rns16", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns16", bits=8))
    return rt_decode(ht)


mesh = make_digit_mesh()        # every local device on the "model" axis
with use_digit_sharding(mesh):
    y_sh = jax.jit(chain_sharded)(xc, tuple(ws))
y_ref = jax.jit(chain_ref)(xc, tuple(ws))
print(f"\ndigit-sharded chain over {mesh.shape['model']} device(s): "
      f"bit-identical to single-device = {bool(jnp.all(y_sh == y_ref))}")

# 8. Fused kernels: the whole Fig. 5 pipeline — encode -> digit matmul
#    -> MRC normalize — as ONE Pallas pass (backend "pallas_fused").
#    Residues only ever exist in VMEM; the float result is bit-identical
#    to the unfused chain, and the op counters show the same logical ops
#    plus the composite `fused` tally (docs/kernels.md).
from repro.core import dispatch
from repro.core.rns_matmul import RnsDotConfig, rns_dot

cfg_ref = RnsDotConfig(profile="rns9", qx=12, qw=12)
cfg_fused = RnsDotConfig(profile="rns9", qx=12, qw=12,
                         backend="pallas_fused")
xq = jnp.asarray(rng.standard_normal((8, 96)), jnp.float32)
wq = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
y_unfused = rns_dot(xq, wq, cfg_ref)
y_fused = rns_dot(xq, wq, cfg_fused)
with dispatch.count_ops() as ops8:
    jax.eval_shape(lambda a, b: rns_dot(a, b, cfg_fused), xq, wq)
print(f"\nfused datapath: bit-identical to unfused = "
      f"{bool(jnp.all(y_fused == y_unfused))}; counts: "
      f"converts={ops8.converts} matmuls={ops8.matmuls} "
      f"normalizes={ops8.normalizes} fused={ops8.fused}")

# 9. Production serving levers on the same paged cache (docs/serving.md):
#    copy-on-write prefix caching (sequences sharing a prompt prefix
#    share physical KV pages; refcounts + content-addressed index) and
#    EXACT speculative decoding (self-drafted n-grams verified in one
#    [R, k+1] window; greedy accept keeps tokens identical to vanilla).
engine = ContinuousEngine(params, cfg, ServeConfig(
    max_cache=64, max_new_tokens=6, page_size=16, max_seqs=2,
    prefix_cache=True, spec_decode=True, spec_k=3))
shared = rng.integers(1, cfg.vocab, (24,)).astype(np.int32)
multi_turn = [shared.copy(), shared.copy(),
              np.concatenate([shared, rng.integers(1, cfg.vocab, (6,))
                              .astype(np.int32)])]
results9, stats9 = engine.run(multi_turn)
print(f"\nprefix cache + spec decode: cache_hit_tokens="
      f"{stats9['cache_hit_tokens']} pages_shared={stats9['pages_shared']} "
      f"cow_splits={stats9['cow_splits']} "
      f"tokens/step={stats9['tokens_per_step']:.2f} "
      f"acceptance={stats9['acceptance_rate']:.2f} "
      f"verify compiles = {engine._verify._cache_size()}")
