"""End-to-end driver: train a ~135M-param LM for a few hundred steps.

Default runs the reduced smoke config on CPU in a couple of minutes;
``--full`` uses the real SmolLM-135M geometry (same code path, slower);
``--rns`` routes every MLP matmul through the paper's digit-sliced RNS
datapath (training included: backward matmuls are RNS too).

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 50 --rns
"""

import argparse
import dataclasses
import logging

from repro.configs.base import get_config
from repro.core.rns_matmul import RnsDotConfig
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--full", action="store_true",
                    help="real 135M geometry instead of the smoke config")
    ap.add_argument("--rns", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_config("smollm-135m", smoke=not args.full)
    if args.full:
        cfg = dataclasses.replace(cfg, remat="none")
    if args.rns:
        cfg = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile="rns9", qx=16, qw=16),
            rns_targets="mlp")

    trainer = Trainer(
        cfg,
        AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                    total_steps=args.steps, weight_decay=0.0),
        TrainerConfig(total_steps=args.steps, ckpt_every=args.steps // 2,
                      ckpt_dir=args.ckpt_dir, log_every=10),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, branch=4, noise=0.05),
    )
    state, hist = trainer.run()
    print(f"\nloss: {hist[0]:.4f} -> {hist[-1]:.4f} over {len(hist)} steps "
          f"({'RNS' if args.rns else 'bf16/f32'} matmul datapath)")


if __name__ == "__main__":
    main()
