"""Continuous batching: mixed-length traffic through one jitted decode.

Submits a stream of mixed-length prompts, steps the scheduler by hand so
the in-flight behaviour is visible (admissions, evictions, page
utilization), then drains and prints the aggregate serving stats.

    PYTHONPATH=src python examples/serve_continuous.py --arch smollm-135m
"""

import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=14,
                    help="small pool on purpose: watch preemption happen")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    engine = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=144, max_new_tokens=args.new, page_size=16, max_seqs=4,
        n_pages=args.n_pages))

    rng = np.random.default_rng(0)
    lens = [7, 33, 120, 25, 60, 9]
    for L in lens:
        engine.submit(rng.integers(1, cfg.vocab, (L,)).astype(np.int32))

    print(f"{len(lens)} requests, prompt lengths {lens}, "
          f"pool={args.n_pages - 1} usable pages x 16 tokens")
    while engine.sched.has_work:
        s = engine.step()
        tags = []
        if s["admitted"]:
            tags.append(f"admit{s['admitted']}")
        if s["preempted"]:
            tags.append(f"EVICT{s['preempted']}")
        if s["finished"]:
            tags.append(f"done{s['finished']}")
        print(f"  step {s['step']:3d}: active={s['active']} "
              f"waiting={s['waiting']} pages={s['page_utilization']:.2f} "
              f"{' '.join(tags)}")

    print(f"\nserved {len(engine.results)} requests — evicted rows "
          f"re-prefill from their prompt, and greedy decode makes the "
          f"replay token-identical to a solo run "
          f"(tests/test_serve_continuous.py asserts it)")
    print(f"decode compiles: {engine._decode._cache_size()} "
          f"(one step for every length mix)")
    for rid, toks in sorted(engine.results.items()):
        print(f"  request {rid} (prompt {lens[rid]:3d} tokens): "
              f"{toks[:8].tolist()}...")


if __name__ == "__main__":
    main()
