"""The paper's own demo (Fig. 3): Mandelbrot via fractional RNS.

"Complex number calculations are performed entirely in residue format
using the newly developed fractional residue arithmetic.  The threshold
comparison is also in residue." — and with the rns18 profile the fixed
point carries ~55 fractional bits, exceeding float64's 53-bit mantissa
(the paper: "exceeds the range of extended precision floating point").

    PYTHONPATH=src python examples/mandelbrot_rns.py [--deep]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fractional as fr
from repro.core.moduli import get_profile

CHARS = " .:-=+*#%@"


def mandelbrot_rns(profile, cr, ci, iters):
    p = get_profile(profile)
    shape = cr.shape
    zr = fr.fr_encode(p, np.zeros(shape, np.float32))
    zi = fr.fr_encode(p, np.zeros(shape, np.float32))
    fcr = fr.fr_encode(p, cr.astype(np.float32))
    fci = fr.fr_encode(p, ci.astype(np.float32))
    esc = jnp.full(shape, iters, jnp.int32)

    @jax.jit
    def step(state, it):
        zr, zi, esc = state
        rr = fr.fr_mul_raw(p, zr, zr)      # PAC products at scale M_f^2
        ii = fr.fr_mul_raw(p, zi, zi)
        ri = fr.fr_mul_raw(p, zr, zi)
        # |z|^2 >= 4 tested IN RESIDUE on the raw (deferred) value
        escaped = fr.fr_ge_const(p, fr.fr_add(p, rr, ii), 4.0, raw=True)
        esc = jnp.where((esc == iters) & escaped, it, esc)
        # one slow normalization per term (deferred normalization)
        zr2 = fr.fr_add(p, fr.fr_normalize(p, fr.fr_sub(p, rr, ii)), fcr)
        zi2 = fr.fr_add(p, fr.fr_normalize(p, fr.fr_add(p, ri, ri)), fci)
        return (zr2, zi2, esc), None

    state = (zr, zi, esc)
    for it in range(iters):
        state, _ = step(state, it)
    return np.asarray(state[2])


def deep_precision_proof():
    """Beyond-float64: two c values 1e-19 apart are THE SAME float64 number
    but distinct RNS fixed-point values with visibly different orbits."""
    from fractions import Fraction

    import jax.numpy as jnp

    from repro.core import fractional as fr
    from repro.core.moduli import RnsProfile, greedy_coprime_moduli

    deep = RnsProfile("rns24_deep", greedy_coprime_moduli(128, 24), 10)
    print(f"profile rns24_deep: {deep.n_digits} digit slices, "
          f"{deep.range_bits:.1f}-bit register, "
          f"{np.log2(float(deep.M_f)):.1f} fractional bits "
          "(float64 mantissa: 53)")
    c0 = Fraction(-743643887037151, 10**15)   # a deep-zoom neighbourhood
    eps = Fraction(1, 10**19)
    cs = [c0, c0 + eps]
    as_f64 = [float(c) for c in cs]
    print(f"  c1 - c0 = 1e-19;  float64(c1) == float64(c0): "
          f"{as_f64[0] == as_f64[1]}")
    enc = jnp.asarray(fr.fr_encode_exact(deep, np.asarray(cs, dtype=object)))
    # M_f ~ 2**69 exceeds device-float encode range: use the exact host path
    zeros = np.asarray([Fraction(0), Fraction(0)], dtype=object)
    zr = jnp.asarray(fr.fr_encode_exact(deep, zeros))
    zi = jnp.asarray(fr.fr_encode_exact(deep, zeros))
    ci_frac = Fraction(1318259042053300, 10**16)
    ci = jnp.asarray(fr.fr_encode_exact(
        deep, np.asarray([ci_frac, ci_frac], dtype=object)))
    for it in range(30):
        rr = fr.fr_mul_raw(deep, zr, zr)
        ii = fr.fr_mul_raw(deep, zi, zi)
        ri = fr.fr_mul_raw(deep, zr, zi)
        zr = fr.fr_add(deep, fr.fr_normalize(deep, fr.fr_sub(deep, rr, ii)), enc)
        zi = fr.fr_add(deep, fr.fr_normalize(deep, fr.fr_add(deep, ri, ri)), ci)
    diff = fr.fr_decode_exact(deep, np.asarray(fr.fr_sub(deep, zr[:, 0:1],
                                                         zr[:, 1:2])))
    print(f"  after 30 RNS iterations the two orbits differ by "
          f"{float(diff[0]):.3e} (exact residue arithmetic); float64 cannot "
          "distinguish the two c values at all")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=100)
    ap.add_argument("--height", type=int, default=32)
    ap.add_argument("--iters", type=int, default=48)
    ap.add_argument("--deep", action="store_true",
                    help="rns18 render + a 69-fractional-bit precision "
                         "proof beyond float64")
    args = ap.parse_args()

    if args.deep:
        deep_precision_proof()
        print()
    profile = "rns12"  # render profile (device-encodable M_f)
    p = get_profile(profile)
    print(f"profile {profile}: {p.n_digits} digit slices, "
          f"M_f = {p.M_f} (~{np.log2(float(p.M_f)):.1f} fractional bits)")

    xs = np.linspace(-2.2, 0.8, args.width)
    ys = np.linspace(-1.2, 1.2, args.height)
    cr = np.repeat(xs[None, :], args.height, 0)
    ci = np.repeat(ys[:, None], args.width, 1)

    t0 = time.perf_counter()
    esc = mandelbrot_rns(profile, cr, ci, args.iters)
    dt = time.perf_counter() - t0
    for row in esc:
        print("".join(CHARS[min(int(v) * len(CHARS) // args.iters,
                                len(CHARS) - 1)] for v in row))
    print(f"\n{args.width*args.height} pixels x {args.iters} iters of "
          f"sustained fractional RNS in {dt:.1f}s "
          f"({args.width*args.height*args.iters/dt:.0f} RNS complex "
          "iterations/s on CPU)")

    # cross-check against float64 on a shallow region
    zr = np.zeros_like(cr)
    zi = np.zeros_like(ci)
    esc64 = np.full(cr.shape, args.iters, np.int64)
    with np.errstate(over="ignore", invalid="ignore"):
        for it in range(args.iters):
            mag = zr * zr + zi * zi
            esc64 = np.where((esc64 == args.iters) & (mag >= 4.0), it, esc64)
            zr, zi = zr * zr - zi * zi + cr, 2 * zr * zi + ci
    agree = float(np.mean(esc64 == esc))
    print(f"escape-iteration agreement with float64: {agree:.3f} "
          "(boundary pixels differ by quantization)")


if __name__ == "__main__":
    main()
