"""Backend-differential serve matrix: every scenario, bit-identical.

Demirkiran et al. (2023) argue RNS datapaths live or die by exactness at
the boundaries; this matrix pins it operationally — the SAME serving
scenario run through the jnp reference, the Pallas kernels (interpret),
and the fused composite kernels (interpret) must produce token-identical
streams AND the identical structural (converts, matmuls, normalizes)
op-count triple, scenario by scenario:

  * ragged prefill + mixed-length batched decode,
  * recompute preemption + readmission under a tiny pool,
  * copy-on-write prefix sharing,
  * speculative (n-gram) draft + verify windows,
  * packed mixed-phase steps (chunked prefill interleaved with decode,
    budget-truncated chunk boundaries splitting a KV page, prefix-cache
    hits landing mid-prompt, spec windows sharing the packed budget).

``fused``/``fallbacks`` tallies legitimately differ per backend (they
count composite launches and visible downgrades); the structural triple
may not.  The CI backend-matrix job runs this file standalone.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.core.rns_matmul import RnsDotConfig
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

BACKENDS = ("reference", "pallas_interpret", "pallas_fused_interpret")

SCENARIOS = {
    # scenario -> (prompt lens, engine kwargs, min expected preemptions)
    "ragged_prefill_mixed_decode": dict(
        lens=(5, 12), kw=dict(max_seqs=2)),
    "preempt_readmit": dict(
        lens=(10, 9, 6), kw=dict(max_seqs=3, n_pages=8, page_size=4,
                                 max_new_tokens=6),
        preempts=True),
    "prefix_share_cow": dict(
        lens=(10, 10, 13), same_prefix=True,
        kw=dict(max_seqs=1, prefix_cache=True)),
    "spec_decode": dict(
        lens=(5, 12), kw=dict(max_seqs=2, spec_decode=True, spec_k=3)),
    "resident_weights": dict(
        lens=(5, 12), kw=dict(max_seqs=2, resident_weights=True)),
    "resident_per_layer": dict(
        lens=(5, 12), kw=dict(max_seqs=2, resident_weights=True,
                              per_layer_profiles=True)),
    # packed mixed-phase steps: a short prompt finishes its single chunk
    # and decodes while the long prompt is still streaming chunks in —
    # both phases share one packed step (asserted via the mixed flag)
    "chunked_interleave": dict(
        lens=(5, 18), mixed=True,
        kw=dict(max_seqs=2, chunked_prefill=True, token_budget=16,
                chunk_size=8)),
    # two rows chunk concurrently under a budget that is NOT a multiple
    # of the chunk size: the second row's chunk is truncated to 4 tokens,
    # so its chunk boundary lands mid-page (the page's low half is
    # written one step before its high half)
    "chunked_page_split": dict(
        lens=(17, 18), mixed=True,
        kw=dict(max_seqs=2, chunked_prefill=True, token_budget=12,
                chunk_size=8)),
    # prefix-cache adoption under chunking: later rows adopt the shared
    # leading blocks and their first chunk starts mid-prompt (max_seqs=1
    # serializes rows so earlier prompts are stashed before later ones
    # admit — no mixed step here, the point is the mid-prompt hit)
    "chunked_prefix_hit": dict(
        lens=(10, 10, 13), same_prefix=True,
        kw=dict(max_seqs=1, prefix_cache=True, chunked_prefill=True,
                token_budget=16, chunk_size=8)),
    # speculative windows and prefill chunks sharing the packed budget
    "chunked_spec_mix": dict(
        lens=(5, 18), mixed=True,
        kw=dict(max_seqs=2, chunked_prefill=True, token_budget=16,
                chunk_size=8, spec_decode=True, spec_k=3)),
    # sliding-window attention with cyclic KV page reuse: rows outgrow
    # the 8-token window mid-decode, the scheduler frees the dead pages
    # (block-table entries point at trash), and attention masks the
    # evicted positions with exact zeros — the stream must stay
    # bit-identical across backends while pages are being recycled
    "window_decode": dict(
        lens=(5, 12), evicts=True,
        kw=dict(max_seqs=2, window_tokens=8, max_new_tokens=6)),
    # window eviction racing chunked prefill: the long prompt's early
    # chunks write pages that die before its decode begins
    "window_chunked": dict(
        lens=(5, 18), mixed=True, evicts=True,
        kw=dict(max_seqs=2, chunked_prefill=True, token_budget=16,
                chunk_size=8, window_tokens=8)),
}


@pytest.fixture(scope="module")
def rns_model():
    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    return cfg, M.init_model(jax.random.PRNGKey(0), cfg)[0]


def _prompts(spec, vocab):
    rng = np.random.default_rng(17)
    lens = spec["lens"]
    if spec.get("same_prefix"):
        base = rng.integers(1, vocab, (max(lens),)).astype(np.int32)
        return [np.concatenate([base[:L - 3],
                                rng.integers(1, vocab, (3,)).astype(np.int32)])
                if i == len(lens) - 1 else base[:L].copy()
                for i, L in enumerate(lens)]
    return [rng.integers(1, vocab, (L,)).astype(np.int32) for L in lens]


def _run(cfg, params, spec, backend):
    kw = dict(spec["kw"])
    kw.setdefault("page_size", 8)
    kw.setdefault("max_new_tokens", 3)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=24, rns_backend=backend, **kw))
    res, stats = eng.run(_prompts(spec, cfg.vocab))
    ops = stats["steps"][-1]["rns_ops"]
    triple = (ops.converts, ops.matmuls, ops.normalizes)
    return {r: v.tolist() for r, v in res.items()}, triple, stats


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_backend_matrix_token_identical(rns_model, scenario):
    cfg, params = rns_model
    spec = SCENARIOS[scenario]
    ref_res, ref_ops, ref_stats = _run(cfg, params, spec, "reference")
    if spec.get("preempts"):
        assert ref_stats["n_preemptions"] > 0    # scenario really fired
    if spec.get("evicts"):
        assert ref_stats["pages_window_evicted"] > 0
    if spec.get("same_prefix"):
        assert ref_stats["cache_hit_tokens"] > 0
        assert ref_stats["cow_splits"] > 0
    if "spec_decode" in spec["kw"]:
        assert ref_stats["tokens_per_step"] >= 1.0
    if spec.get("mixed"):
        # at least one packed step really carried both phases at once
        assert any(s["prefill_tokens"] > 0 and s["decode_tokens"] > 0
                   for s in ref_stats["steps"]), "no mixed-phase step fired"
    if spec["kw"].get("chunked_prefill"):
        assert ref_stats["ttft_p95_s"] > 0.0
    for backend in BACKENDS[1:]:
        res, ops, _ = _run(cfg, params, spec, backend)
        assert res == ref_res, (scenario, backend)
        assert ops == ref_ops, (scenario, backend)


@pytest.mark.parametrize("chunked", [False, True])
@pytest.mark.parametrize("family", ["float_gqa", "rns_gqa", "float_mla"])
def test_windowed_token_identity(rns_model, family, chunked):
    """Windowed continuous serving vs a windowed SOLO run: bit-identical.

    The solo bucketed engine keeps every position resident in its dense
    cache and masks outside the window; the continuous engine has
    physically recycled the evicted pages (block-table entries point at
    the trash page, whose contents are arbitrary).  Identity between the
    two proves the exact-zero masking — any leakage of an evicted
    position would read trash and move tokens.  float/rns x gqa/mla x
    chunked on/off; the rns family runs all three backends.
    """
    W, max_new = 8, 6
    if family == "rns_gqa":
        cfg, params = rns_model
        backends = BACKENDS
    elif family == "float_gqa":
        cfg = get_config("smollm-135m", smoke=True)
        params = M.init_model(jax.random.PRNGKey(0), cfg)[0]
        backends = BACKENDS[:1]
    else:
        cfg = dataclasses.replace(get_config("deepseek-v2-236b", smoke=True),
                                  mlp_types=("dense",) * 4, moe=None)
        params = M.init_model(jax.random.PRNGKey(1), cfg)[0]
        backends = BACKENDS[:1]
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 12)]
    kw = dict(max_seqs=2, page_size=8, max_new_tokens=max_new,
              window_tokens=W)
    if chunked:
        kw.update(chunked_prefill=True, token_budget=16, chunk_size=8)
    ref = None
    for backend in backends:
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=24, rns_backend=backend, **kw))
        res, stats = eng.run(prompts)
        assert stats["pages_window_evicted"] > 0   # pages really recycled
        toks = {i: v.tolist() for i, v in res.items()}
        if ref is None:
            solo = Engine(params, cfg, ServeConfig(
                max_cache=24, max_new_tokens=max_new, window_tokens=W,
                rns_backend=backend))
            for i, p in enumerate(prompts):
                assert toks[i] == solo.generate(p[None])[0].tolist(), (
                    family, chunked, i)
            ref = toks
        else:
            assert toks == ref, (family, chunked, backend)


@pytest.mark.parametrize("defer", [False, True])
def test_resident_vs_reencode_token_identical(rns_model, defer):
    """Resident serving must be a pure re-layout of the re-encode path:
    identical token streams and identical structural op counts once the
    (now absent) weight conversions are subtracted out."""
    cfg, params = rns_model
    spec = dict(lens=(5, 12), kw=dict(max_seqs=2, rns_defer=defer))
    base_res, _, base_stats = _run(cfg, params, spec, "reference")
    base_ops = base_stats["steps"][-1]["rns_ops"]
    assert base_ops.weight_converts > 0          # re-encode really converts
    for extra in (dict(resident_weights=True),
                  dict(resident_weights=True, per_layer_profiles=True)):
        spec_r = dict(lens=spec["lens"], kw=dict(spec["kw"], **extra))
        res, _, stats = _run(cfg, params, spec_r, "reference")
        ops = stats["steps"][-1]["rns_ops"]
        assert res == base_res, extra
        assert ops.weight_converts == 0, extra
        assert ((ops.activation_converts, ops.matmuls, ops.normalizes)
                == (base_ops.activation_converts, base_ops.matmuls,
                    base_ops.normalizes)), extra


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_audit_predicts_runtime_counts(rns_model, scenario):
    """The static auditor's structural predictions and the engine's traced
    OpCounts are claims about the same program (``_trace_specs``): for
    every serve scenario the graph-derived counts must match the traced
    tallies, and the audited phases must be exactly the phases the step
    counter caches."""
    from repro.analysis.graph import COUNT_FIELDS
    from repro.analysis.ledger_audit import audit_engine

    cfg, params = rns_model
    kw = dict(SCENARIOS[scenario]["kw"])
    kw.setdefault("page_size", 8)
    kw.setdefault("max_new_tokens", 3)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=24, rns_backend="reference", **kw))
    report = audit_engine(eng)
    assert report.ok, report.summary()
    eng._rns_ops(1)                              # populate the step cache
    assert {p.name for p in report.phases} == set(eng._op_cache)
    for p in report.phases:
        assert p.counts_match, (scenario, p.name)
        traced = {f: getattr(eng._op_cache[p.name], f) for f in COUNT_FIELDS}
        assert p.counts == traced, (scenario, p.name)
