"""Resident residue-domain weights: exactness, ledger proofs, perf deltas.

The tentpole contract, pinned operationally:

  * resident forwards are BIT-identical to the re-encode path (per-op,
    deferred, gated/ungated, stacked-scan) — the weights' residues are the
    same integers either way, so the only legal difference is *where* the
    conversion happens (build time vs trace time);
  * per-layer narrow profiles stay exact: the quantized-weight column-sum
    ledger bound is checked against a python-int oracle running the same
    chain in unbounded integers;
  * the perf claim is HLO-visible: on the 128x512x128 kernel shape the
    resident program costs measurably fewer FLOPs and HBM bytes than the
    re-encode program (hlo_cost);
  * resident engines keep the zero-per-length-recompile contract
    (``_cache_size() == 1``) and resident params round-trip the
    checkpointer bit-identically.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import dispatch
from repro.core.moduli import get_profile
from repro.core.quantize import absmax_scale, quantize_with_scale
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_resident_dot
from repro.core.tensor import RnsTensor
from repro.models import model as M
from repro.models import resident as R
from repro.models.layers import mlp
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig

RNS8 = RnsDotConfig(profile="rns9", qx=8, qw=8)


def _mlp_params(key, d=32, ff=64, gated=True, periods=None):
    ks = jax.random.split(key, 3)
    shp = lambda a, b: (periods, a, b) if periods else (a, b)
    p = {"wi": {"w": jax.random.normal(ks[0], shp(d, ff)) * 0.05},
         "wo": {"w": jax.random.normal(ks[2], shp(ff, d)) * 0.05}}
    if gated:
        p["wg"] = {"w": jax.random.normal(ks[1], shp(d, ff)) * 0.05}
    return p


class _Cfg:
    """Minimal model-config stand-in for encode_resident/attach_resident."""
    rns_targets = "mlp"

    def __init__(self, rns):
        self.rns = rns


# ------------------------------------------------------------ exactness ---
@pytest.mark.parametrize("gated", [True, False])
@pytest.mark.parametrize("defer", [False, True])
@pytest.mark.parametrize("per_layer", [False, True])
def test_resident_mlp_bit_identical(gated, defer, per_layer):
    p = _mlp_params(jax.random.PRNGKey(0), gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32))
    rns = dataclasses.replace(RNS8, defer=defer)
    y0 = mlp(p, x, gated=gated, act="silu", rns=rns)
    pr = R.encode_resident({"mlp": p}, _Cfg(rns),
                           per_layer_profiles=per_layer)["mlp"]
    assert R.has_resident({"mlp": pr})
    y1 = mlp(pr, x, gated=gated, act="silu", rns=rns)
    assert jnp.array_equal(y0, y1)


def test_resident_stacked_scan_bit_identical():
    """Period-major stacked residents slice through lax.scan into valid
    per-period RnsTensors — the scanned-transformer layout."""
    p = _mlp_params(jax.random.PRNGKey(2), periods=3)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 32))
    step = lambda h, lp: (mlp(lp, h, gated=True, act="silu", rns=RNS8), None)
    y0, _ = jax.lax.scan(step, x, p)
    pr = R.encode_resident({"mlp": p}, _Cfg(RNS8))["mlp"]
    assert pr["wi"]["w_res"].digits.ndim == 4        # [P, K, d, ff]
    assert pr["wi"]["w_res"].scale.shape == (3,)     # per-period grids
    y1, _ = jax.lax.scan(step, x, pr)
    assert jnp.array_equal(y0, y1)
    # jit round-trip with the resident pytree as an argument
    y2, _ = jax.jit(lambda xx, pp: jax.lax.scan(step, xx, pp))(x, pr)
    assert jnp.array_equal(y0, y2)


def test_drop_masters_serves_without_floats():
    p = _mlp_params(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32))
    y0 = mlp(p, x, gated=True, act="silu", rns=RNS8)
    pr = R.encode_resident({"mlp": p}, _Cfg(RNS8), drop_masters=True)["mlp"]
    assert "w" not in pr["wi"]
    y1 = mlp(pr, x, gated=True, act="silu", rns=RNS8)
    assert jnp.array_equal(y0, y1)


def test_strip_resident_restores_reencode_path():
    p = _mlp_params(jax.random.PRNGKey(6))
    pr = R.encode_resident({"mlp": p}, _Cfg(RNS8))
    ps = R.strip_resident(pr)
    assert not R.has_resident(ps)
    assert jnp.array_equal(ps["mlp"]["wi"]["w"], p["wi"]["w"])


# -------------------------------------------------- per-layer narrow path --
def test_narrow_profile_vs_python_int_oracle():
    """The narrow-profile resident chain must equal unbounded python-int
    arithmetic on the same quantized operands — the ledger's exactness
    claim, checked end to end through a narrow moduli set."""
    p = _mlp_params(jax.random.PRNGKey(7), d=16, ff=24, gated=False)
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 16))
    cfg = _Cfg(RNS8)
    pr = R.encode_resident({"mlp": p}, cfg, per_layer_profiles=True)["mlp"]
    prof = get_profile(pr["wi"]["w_res"].profile)
    assert prof.range_bits < get_profile("rns9").range_bits  # really narrow

    sx = absmax_scale(x, 8)
    sw = absmax_scale(p["wi"]["w"], 8)
    qx = np.asarray(quantize_with_scale(x, sx, 8), object)
    qw = np.asarray(quantize_with_scale(p["wi"]["w"], sw, 8), object)
    exact = qx @ qw                                  # unbounded python ints
    assert all(abs(int(v)) * 2 < prof.M for v in exact.ravel())
    y = rns_resident_dot(x, pr["wi"]["w_res"],
                         dataclasses.replace(RNS8, profile=prof.name))
    # mirror the datapath's float32 rescale op for op (bit-identity needs
    # the same IEEE operations, not just the same real value)
    recip = np.float32(1.0) / (np.float32(sx) * np.float32(sw))
    want = exact.astype(np.float64).astype(np.float32) * recip
    np.testing.assert_array_equal(np.asarray(y), want)


def test_amortized_ledger_bound_is_safe_and_tight():
    """Resident mag_bits reconstruct the column-sum bound through the
    existing ledger formula, and the selected profile covers it."""
    import math

    p = _mlp_params(jax.random.PRNGKey(9))
    pr = R.encode_resident({"mlp": p}, _Cfg(RNS8),
                           per_layer_profiles=True)["mlp"]
    for name in ("wi", "wg", "wo"):
        res = pr[name]["w_res"]
        w = pr[name]["w"]
        s = absmax_scale(w, 8)
        q = np.asarray(quantize_with_scale(w, s, 8), np.int64)
        colsum = int(np.abs(q).sum(axis=-2).max())
        D = w.shape[-2]
        # ledger reconstruction: a.mag + w.mag + log2(D) == (qx-1)+log2(colsum)
        got = 7.0 + res.mag_bits + math.log2(D)
        want = 7.0 + math.log2(colsum)
        assert got == pytest.approx(want, abs=1e-9)
        prof = get_profile(res.profile)
        assert want + 1.0 <= prof.signed_bits        # headroom survives


def test_resident_profile_mismatch_without_master_raises():
    p = _mlp_params(jax.random.PRNGKey(10))
    pr = R.encode_resident({"mlp": p}, _Cfg(RNS8), per_layer_profiles=True,
                           drop_masters=True)["mlp"]
    from repro.models.layers import _encode_weight

    wide = dataclasses.replace(RNS8, profile="rns16")
    with pytest.raises(ValueError, match="float master was dropped"):
        _encode_weight(pr["wi"], wide)


def test_per_layer_requires_resident_in_serve_config():
    with pytest.raises(ValueError, match="requires resident_weights"):
        ServeConfig(per_layer_profiles=True)


# --------------------------------------------------------- encode cache ---
def test_eager_encode_cache_hits_on_param_identity():
    from repro.models import layers as L

    L._ENCODE_CACHE.clear()
    w = jax.random.normal(jax.random.PRNGKey(11), (16, 16))
    p = {"w": w}
    r1 = L._encode_weight(p, RNS8)
    r2 = L._encode_weight(p, RNS8)
    assert r1 is r2                                  # identity-keyed hit
    r3 = L._encode_weight({"w": w + 0}, RNS8)        # new array, new encode
    assert r3 is not r1
    assert jnp.array_equal(r3.digits, r1.digits)
    # different profile/bits never collide
    r4 = L._encode_weight(p, dataclasses.replace(RNS8, profile="rns6"))
    assert r4.profile == "rns6"
    assert L._encode_weight(p, RNS8) is r1


def test_eager_encode_cache_bypasses_tracers():
    from repro.models import layers as L

    L._ENCODE_CACHE.clear()
    w = jax.random.normal(jax.random.PRNGKey(12), (8, 8))

    @jax.jit
    def f(w):
        return L._encode_weight({"w": w}, RNS8).digits

    f(w)
    assert not L._ENCODE_CACHE                       # tracer never cached


# ---------------------------------------------------------- train path ----
def test_train_step_resident_weights_updates_masters():
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import init_train_state, make_train_step

    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RNS8, rns_targets="mlp")
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    step = make_train_step(cfg, AdamWConfig(lr=1e-3), resident_weights=True)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    w0 = state["params"]["blocks"]["l0"]["mlp"]["wi"]["w"]
    w1 = new_state["params"]["blocks"]["l0"]["mlp"]["wi"]["w"]
    assert not jnp.array_equal(w0, w1)               # masters really moved
    assert not R.has_resident(new_state["params"])   # digits never persisted


# ------------------------------------------------------------- hlo cost ---
def test_hlo_cost_resident_beats_reencode_128x512x128():
    """The acceptance shape: resident encode(x)-only programs must cost
    measurably fewer FLOPs and HBM bytes than encode(x)+encode(w)."""
    from repro.launch.hlo_cost import analyze_hlo

    x = jax.random.normal(jax.random.PRNGKey(13), (128, 512))
    w = jax.random.normal(jax.random.PRNGKey(14), (512, 128)) * 0.05
    w_res = R._encode_one(w, "rns9", 8, 7.0)

    def lowered(fn, *args):
        return jax.jit(fn).lower(*args).compile().as_text()

    h_re = lowered(lambda x, w: rns_dot(x, w, RNS8), x, w)
    h_res = lowered(lambda x, r: rns_resident_dot(x, r, RNS8), x, w_res)
    c_re, c_res = analyze_hlo(h_re), analyze_hlo(h_res)
    # the dot FLOPs are identical by construction (same matmuls, same
    # digits); what residency deletes is the weight conversion — the
    # quantize float ops over the [512, 128] weight and the HBM traffic
    # of re-materializing its residues every call
    assert c_res["flops"] <= c_re["flops"], (c_res, c_re)
    assert c_res["hbm_bytes"] < c_re["hbm_bytes"], (c_res, c_re)
    assert c_res["hbm_write_bytes"] < c_re["hbm_write_bytes"], (c_res, c_re)

    def weight_quantize_ops(hlo):
        return sum("round" in l and "512,128" in l for l in hlo.splitlines())

    assert weight_quantize_ops(h_re) > 0      # re-encode quantizes w inline
    assert weight_quantize_ops(h_res) == 0    # resident never touches w


# ------------------------------------------------------- serving engines ---
@pytest.fixture(scope="module")
def serve_model():
    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RNS8, rns_targets="mlp")
    return cfg, M.init_model(jax.random.PRNGKey(0), cfg)[0]


def test_continuous_engine_resident_compile_pin(serve_model):
    cfg, params = serve_model
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=24, max_new_tokens=4, max_seqs=2,
        rns_backend="reference", resident_weights=True))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in (5, 9, 3)]
    res, stats = eng.run(prompts)
    assert eng._prefill._cache_size() == 1
    assert eng._decode._cache_size() == 1
    ops = stats["steps"][-1]["rns_ops"]
    assert ops.weight_converts == 0
    assert ops.activation_converts > 0


def test_bucketed_engine_resident_token_identical(serve_model):
    cfg, params = serve_model
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab, (2, 6)).astype(np.int32)
    kw = dict(max_cache=16, max_new_tokens=4, rns_backend="reference")
    out0 = Engine(params, cfg, ServeConfig(**kw)).generate(prompts)
    eng = Engine(params, cfg, ServeConfig(resident_weights=True, **kw))
    assert R.has_resident(eng.params)
    out1 = eng.generate(prompts)
    np.testing.assert_array_equal(out0, out1)
    ops = eng.rns_op_counts(B=2, T=6)
    assert ops.weight_converts == 0


# --------------------------------------------------------- checkpointing ---
def test_checkpoint_roundtrip_resident_params(tmp_path, serve_model):
    from repro.checkpoint import checkpointer as C

    cfg, params = serve_model
    pr = R.encode_resident(params, cfg, per_layer_profiles=True)
    step_dir = C.save(str(tmp_path), 7, pr)
    restored, extra, step = C.restore(step_dir, jax.eval_shape(lambda: pr))
    assert step == 7

    flat0 = jax.tree_util.tree_flatten_with_path(pr)[0]
    flat1 = {jax.tree_util.keystr(k): v
             for k, v in jax.tree_util.tree_flatten_with_path(restored)[0]}
    n_res = 0
    for k, v in flat0:
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(flat1[jax.tree_util.keystr(k)]),
                                      err_msg=jax.tree_util.keystr(k))
        n_res += "w_res" in jax.tree_util.keystr(k)
    assert n_res > 0                                 # residents were in play

    def probe(tree, path=()):
        if isinstance(tree, dict):
            for k, v in tree.items():
                yield from probe(v, path + (k,))
        elif isinstance(tree, RnsTensor):
            yield path, tree

    res0 = dict(probe(pr))
    res1 = dict(probe(restored))
    assert set(res0) == set(res1) and res0
    for k in res0:
        # static aux (profile name, ledger state) rides the treedef
        assert res1[k].profile == res0[k].profile
        assert res1[k].mag_bits == res0[k].mag_bits
        assert res1[k].frac_exp == res0[k].frac_exp
        assert jnp.array_equal(res1[k].digits, res0[k].digits)
        assert jnp.array_equal(res1[k].scale, res0[k].scale)


# ------------------------------------------------------- digit sharding ---
def test_resident_digit_sharded_token_identical(serve_model):
    from jax.sharding import Mesh

    cfg, params = serve_model
    mesh = Mesh(np.array(jax.devices()[:1]), ("model",))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in (5, 9)]
    kw = dict(max_cache=24, max_new_tokens=4, max_seqs=2,
              rns_backend="reference")
    res0, _ = ContinuousEngine(params, cfg, ServeConfig(**kw)).run(prompts)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        mesh=mesh, resident_weights=True, **kw))
    res1, _ = eng.run(prompts)
    assert all(np.array_equal(res0[k], res1[k]) for k in res0)
