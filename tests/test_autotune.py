"""Block-size autotuner: bucketing, lookup, measure -> persist -> reuse."""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache()
    yield path
    autotune.clear_cache()


def test_shape_bucket_pow2():
    assert autotune.shape_bucket((1, 100, 512)) == (8, 128, 512)
    assert autotune.shape_bucket((129,)) == (256,)
    # bucketing is what keys the cache: nearby shapes share a row
    k1 = autotune._key("rns_matmul", "rns9", (100, 500, 100), "cpu")
    k2 = autotune._key("rns_matmul", "rns9", (128, 512, 128), "cpu")
    assert k1 == k2


def test_get_blocks_defaults_without_cache(tmp_cache):
    blk = autotune.get_blocks("rns_matmul", "rns9", (64, 256, 64))
    assert blk == {"bm": 128, "bn": 128, "bk": 512}
    assert autotune.get_blocks("rns_normalize", "rns9", (100,)) == {"bt": 1024}
    assert not tmp_cache.exists()      # pure lookup never writes


def test_tune_picks_argmin_and_persists(tmp_cache):
    """Injected cost model: tune must select its argmin and write the
    versioned JSON row; a fresh in-memory cache then serves the row."""
    want = {"bm": 64, "bn": 256, "bk": 256}

    def fake_bench(blocks):
        return 0.001 if blocks == want else 1.0

    got = autotune.tune("rns_matmul", "rns9", (64, 256, 64),
                        bench_fn=fake_bench, repeats=1)
    assert {k: got[k] for k in want} == want
    data = json.loads(tmp_cache.read_text())
    assert data["version"] == 1
    (key, entry), = data["entries"].items()
    assert key.startswith("rns_matmul|rns9|64x256x64|")
    assert entry["blocks"] == want

    autotune.clear_cache()             # force a reload from disk
    assert autotune.get_blocks("rns_matmul", "rns9", (64, 256, 64)) == dict(
        autotune.DEFAULTS["rns_matmul"], **want)
    # a different bucket still gets defaults
    assert autotune.get_blocks("rns_matmul", "rns9", (512, 512, 512)) == \
        autotune.DEFAULTS["rns_matmul"]


def test_tune_real_bench_smoke(tmp_cache, monkeypatch):
    """The built-in micro-bench path runs end-to-end (tiny shape, pruned
    candidate set) and produces kernel-legal blocks."""
    monkeypatch.setitem(autotune.CANDIDATES, "rns_matmul",
                        [{"bm": 64, "bn": 128, "bk": 256},
                         {"bm": 128, "bn": 128, "bk": 512}])
    blk = autotune.tune("rns_matmul", "rns9", (16, 64, 16), repeats=1)
    assert set(blk) == {"bm", "bn", "bk"}
    assert blk["bm"] % 8 == 0 and blk["bn"] % 128 == 0
    assert tmp_cache.exists()


@pytest.mark.parametrize("payload", [
    "{not json",                                         # invalid JSON
    "[1, 2, 3]",                                         # wrong top level
    '{"version": 99, "entries": {}}',                    # future version
    '{"version": 1, "entries": 5}',                      # entries wrong type
    '{"version": 1, "entries": {"k": "junk"}}',          # row wrong type
    '{"version": 1, "entries": {"k": {"us": 1.0}}}',     # row missing blocks
    '{"version": 1, "entries": {"k": {"blocks": ["bm"]}}}',
    '{"version": 1, "entries": {"k": {"blocks": {"bm": "big"}}}}',
    '{"version": 1, "entries": {"k": {"blocks": {"evil": 8}}}}',
    '{"version": 1, "entries": {"k": {"blocks": {"bm": -8}}}}',
    '{"version": 1, "entries": {"k": {"blocks": {"bm": true}}}}',
])
def test_corrupt_cache_falls_back_to_defaults(tmp_cache, payload):
    """A poisoned/corrupt/mismatched cache file must never crash a
    lookup and never leak junk tile sizes into a kernel launch — every
    malformed shape degrades to the hardcoded defaults."""
    tmp_cache.write_text(payload)
    autotune.clear_cache()
    blk = autotune.get_blocks("rns_matmul", "rns9", (64, 256, 64))
    assert blk == autotune.DEFAULTS["rns_matmul"]
    assert autotune.get_blocks("rns_normalize", "rns9", (100,)) == \
        autotune.DEFAULTS["rns_normalize"]


def test_corrupt_cache_survives_partial_poisoning(tmp_cache):
    """Valid rows next to junk rows: the junk is dropped, the good row
    still serves (per-row validation, not all-or-nothing)."""
    good_key = autotune._key("rns_matmul", "rns9", (64, 256, 64), "cpu")
    tmp_cache.write_text(json.dumps({
        "version": 1,
        "entries": {
            good_key: {"blocks": {"bm": 64, "bn": 256, "bk": 256}},
            "bad-row": {"blocks": {"bm": "nope"}},
            3: {"blocks": {"bm": 64}},
        }}))
    autotune.clear_cache()
    blk = autotune.get_blocks("rns_matmul", "rns9", (64, 256, 64),
                              backend="cpu")
    assert blk == {"bm": 64, "bn": 256, "bk": 256}


def test_tune_rewrites_corrupt_cache(tmp_cache):
    """tune() over a corrupt file persists a fresh valid file (the
    measure -> persist path self-heals)."""
    tmp_cache.write_text("{definitely not json")
    autotune.clear_cache()
    want = {"bm": 64, "bn": 128, "bk": 256}
    autotune.tune("rns_matmul", "rns9", (32, 64, 32),
                  bench_fn=lambda b: 0.0 if b == want else 1.0, repeats=1)
    data = json.loads(tmp_cache.read_text())     # valid JSON again
    assert data["version"] == 1
    (entry,) = data["entries"].values()
    assert entry["blocks"] == want
    autotune.clear_cache()
    got = autotune.get_blocks("rns_matmul", "rns9", (32, 64, 32))
    assert {k: got[k] for k in want} == want


def test_wrappers_consult_tuned_blocks(tmp_cache):
    """A tuned row changes the wrapper's compiled tiling (observable via
    the jit cache) without changing results."""
    from repro.core.rns import encode_int32
    from repro.kernels.rns_normalize.kernel import rns_normalize_tiles
    from repro.kernels.rns_normalize.ops import rns_normalize
    from repro.kernels.rns_normalize.ref import rns_normalize_ref

    res = jnp.asarray(encode_int32(
        "rns9", np.arange(-50, 50, dtype=np.int32)))
    autotune.tune("rns_normalize", "rns9", (100,),
                  bench_fn=lambda b: 0.0 if b["bt"] == 256 else 1.0,
                  repeats=1)
    before = rns_normalize_tiles._cache_size()
    out = rns_normalize("rns9", res)
    assert rns_normalize_tiles._cache_size() == before + 1  # bt=256 cell
    assert np.array_equal(np.asarray(out),
                          np.asarray(rns_normalize_ref(res, profile="rns9")))
