"""Chunked prefill + packed mixed-phase batching, pinned differentially.

The continuous engine's chunked mode replaces the separate [1, Tpad]
prefill and [R, 1]/[R, W] decode programs with ONE jitted packed step
over a fixed token budget.  These tests pin the two contracts that make
that safe:

  * **token identity** — every request's emitted stream equals the solo
    bucketed run (same params, same prompt, no batching), for float and
    RNS datapaths (defer on/off), gqa and MLA attention, prefix cache
    on/off, speculative decoding on/off, across preemption/readmission,
    and for prompts longer than any whole-prompt prefill could admit;
  * **one compile** — the mixed step recompiles zero times across phase
    mixes (``_mixed._cache_size() == 1`` after arbitrarily varied
    traffic), because its shapes depend only on the token budget and
    the page geometry.

Plus the ServeConfig cross-feature validation (named-field errors) and
the per-step TTFT / phase accounting the scheduler's bounded-TTFT
guarantee is observed through.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.core.rns_matmul import RnsDotConfig
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


@pytest.fixture(scope="module")
def gqa_model():
    cfg = get_config("smollm-135m", smoke=True)
    return cfg, M.init_model(jax.random.PRNGKey(0), cfg)[0]


@pytest.fixture(scope="module")
def mla_model():
    cfg = dataclasses.replace(get_config("deepseek-v2-236b", smoke=True),
                              mlp_types=("dense",) * 4, moe=None)
    return cfg, M.init_model(jax.random.PRNGKey(1), cfg)[0]


def _rns(cfg, defer=False):
    return dataclasses.replace(
        cfg, rns=RnsDotConfig(profile="rns9", qx=8, qw=8, defer=defer),
        rns_targets="mlp")


def _prompts(vocab, lens=(13, 21, 5, 9), seed=11):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, (t,)).astype(np.int32) for t in lens]


def _solo(params, cfg, prompts, max_new):
    eng = Engine(params, cfg, ServeConfig(max_cache=64,
                                          max_new_tokens=max_new))
    return {i: eng.generate(p[None])[0].tolist()
            for i, p in enumerate(prompts)}


def _chunked(params, cfg, prompts, max_new=6, **kw):
    base = dict(max_cache=48, max_seqs=4, page_size=8,
                max_new_tokens=max_new, chunked_prefill=True,
                token_budget=16, chunk_size=8)
    base.update(kw)
    eng = ContinuousEngine(params, cfg, ServeConfig(**base))
    out, stats = eng.run(prompts)
    return eng, {r: v.tolist() for r, v in out.items()}, stats


# --------------------------------------------------- identity matrix ---
GQA_CASES = {
    "float": (False, None, {}),
    "float_spec_prefix": (False, None, dict(spec_decode=True, spec_k=3,
                                            prefix_cache=True)),
    "rns": (True, False, {}),
    "rns_defer_spec_prefix": (True, True, dict(spec_decode=True, spec_k=2,
                                               prefix_cache=True)),
}


@pytest.mark.parametrize("case", sorted(GQA_CASES))
def test_chunked_token_identical_to_solo_gqa(gqa_model, case):
    cfg, params = gqa_model
    use_rns, defer, kw = GQA_CASES[case]
    if use_rns:
        cfg = _rns(cfg, defer=defer)
    prompts = _prompts(cfg.vocab)
    want = _solo(params, cfg, prompts, 6)
    eng, got, stats = _chunked(params, cfg, prompts, **kw)
    assert got == want, case
    assert eng._mixed._cache_size() == 1
    # at least one packed step really mixed both phases
    assert any(s["prefill_tokens"] > 0 and s["decode_tokens"] > 0
               for s in stats["steps"]), case


MLA_CASES = {
    "float": (False, {}),
    "rns_spec": (True, dict(spec_decode=True, spec_k=2)),
}


@pytest.mark.parametrize("case", sorted(MLA_CASES))
def test_chunked_token_identical_to_solo_mla(mla_model, case):
    cfg, params = mla_model
    use_rns, kw = MLA_CASES[case]
    if use_rns:
        cfg = _rns(cfg)
    prompts = _prompts(cfg.vocab)
    want = _solo(params, cfg, prompts, 6)
    eng, got, _ = _chunked(params, cfg, prompts, **kw)
    assert got == want, case
    assert eng._mixed._cache_size() == 1


def test_chunked_admits_prompts_beyond_prompt_pad(gqa_model):
    """Whole-prompt prefill rejects prompts longer than prompt_pad;
    chunked mode streams them in and still matches the solo run."""
    cfg, params = gqa_model
    prompts = _prompts(cfg.vocab, lens=(21,))
    with pytest.raises(ValueError, match="prompt"):
        ContinuousEngine(params, cfg, ServeConfig(
            max_cache=48, prompt_pad=8)).submit(prompts[0])
    want = _solo(params, cfg, prompts, 6)
    _, got, _ = _chunked(params, cfg, prompts, prompt_pad=8)
    assert got == want


def test_chunked_preempt_readmit_token_identical(gqa_model):
    """A pool too small for the full load preempts rows mid-stream
    (possibly mid-prefill); greedy recompute readmission keeps every
    stream equal to its uninterrupted solo run."""
    cfg, params = gqa_model
    prompts = _prompts(cfg.vocab, lens=(10, 9, 6), seed=17)
    want = _solo(params, cfg, prompts, 6)
    _, got, stats = _chunked(params, cfg, prompts, max_seqs=3, n_pages=8,
                             page_size=4, max_cache=24, token_budget=8,
                             chunk_size=4)
    assert stats["n_preemptions"] > 0        # the scenario really fired
    assert got == want


def test_one_mixed_compile_across_phase_mixes(gqa_model):
    """Zero per-mix recompiles: wildly different traffic shapes reuse
    the one mixed-step executable."""
    cfg, params = gqa_model
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=48, max_seqs=4, page_size=8, max_new_tokens=4,
        chunked_prefill=True, token_budget=16, chunk_size=8))
    for lens in ((13, 21, 5, 9), (3,), (17, 2), (8, 8, 8, 8)):
        eng.run(_prompts(cfg.vocab, lens=lens))
    assert eng._mixed._cache_size() == 1


def test_ttft_and_phase_stats(gqa_model):
    cfg, params = gqa_model
    prompts = _prompts(cfg.vocab)
    _, _, stats = _chunked(params, cfg, prompts)
    assert 0.0 < stats["ttft_p50_s"] <= stats["ttft_p95_s"]
    assert stats["ttft_p95_s"] <= stats["latency_p99_s"]
    for s in stats["steps"]:
        assert s["prefill_tokens"] + s["decode_tokens"] >= 0
        assert s["ttft_ms"] >= 0.0
    # chunked prefill touches each non-shared prompt token exactly once
    assert (sum(s["prefill_tokens"] for s in stats["steps"])
            == sum(len(p) for p in prompts))
    assert (sum(s["decode_tokens"] for s in stats["steps"])
            == stats["total_new_tokens"])


# ------------------------------------------- cross-feature validation ---
@pytest.mark.parametrize("kw,field", [
    (dict(chunked_prefill=True, token_budget=0), "token_budget"),
    (dict(chunked_prefill=True, spec_decode=True, spec_k=8,
          token_budget=4), "token_budget"),
    (dict(chunked_prefill=True, cache_dtype="bfloat16"), "cache_dtype"),
    (dict(chunk_size=8), "chunk_size"),
    (dict(chunked_prefill=True, chunk_size=0), "chunk_size"),
    (dict(chunked_prefill=True, chunk_size=12, page_size=8),
     "chunk_size"),
    (dict(chunked_prefill=True, chunk_size=32, token_budget=16),
     "chunk_size"),
    (dict(prefill_reserve=4), "prefill_reserve"),
    (dict(chunked_prefill=True, prefill_reserve=16, token_budget=16),
     "prefill_reserve"),
])
def test_serve_config_cross_feature_errors(kw, field):
    """Incoherent chunked configs fail fast, naming the bad field."""
    with pytest.raises(ValueError, match=field):
        ServeConfig(max_cache=48, **kw)


def test_chunked_mla_rns_all_rejected(mla_model):
    """Packed chunk tokens re-expand gathered latents; with
    rns_targets='all' the original quantization grids are gone, so the
    combination is refused up front rather than silently drifting."""
    cfg, params = mla_model
    cfg = dataclasses.replace(
        cfg, rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
        rns_targets="all")
    with pytest.raises(NotImplementedError, match="rns_targets"):
        ContinuousEngine(params, cfg, ServeConfig(
            max_cache=48, chunked_prefill=True, token_budget=16))
