"""Fused residue-datapath kernels (kernels/rns_fused) + dispatch routing.

The load-bearing claims, executed (interpret mode):

  * each fused kernel is BIT-identical to the unfused chain it replaces
    (pallas chain and reference chain), including non-tile-multiple
    tails and per-sequence scale rows;
  * the pallas_fused backend is bit-identical to the reference backend
    on the 3-linear oracle test (rns_linear_chain) and on a
    continuous-serve mixed-length run;
  * op counters gain ``fused`` entries while the structural
    convert/matmul/normalize tallies stay backend-independent;
  * remaining backend downgrades are VISIBLE (``fallbacks``), never
    silent.
"""

import dataclasses

import numpy as np
import pytest
from _hypothesis_stub import given, st

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core.quantize import absmax_scale, token_mask
from repro.core.rns import encode_int32
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_multi_dot
from repro.kernels.rns_fused.ops import (
    rns_fused_dot,
    rns_fused_encode_matmul,
    rns_fused_matmul_normalize,
)
from repro.kernels.rns_fused.ref import (
    rns_fused_dot_ref,
    rns_fused_encode_matmul_ref,
    rns_fused_matmul_normalize_ref,
)

PROFILES = ["rns5", "rns9"]


def _operands(profile, shape, bits=12, seed=0):
    rng = np.random.default_rng(seed)
    *lead, D, N = shape
    x = jnp.asarray(rng.standard_normal(tuple(lead) + (D,)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((D, N)), jnp.float32)
    sx = absmax_scale(x, bits)
    sw = absmax_scale(w, bits)
    w_res = dispatch.convert(profile, w, sw, bits=bits,
                             backend="pallas_interpret")
    return x, sx, w_res


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("shape", [(4, 32, 8), (3, 5, 70, 13), (1, 1, 1),
                                   (130, 700, 150)])
def test_fused_kernels_match_refs(profile, shape):
    x, sx, w_res = _operands(profile, shape, seed=hash(shape) % 2**31)
    got = rns_fused_encode_matmul(profile, x, sx, w_res, bits=12,
                                  interpret=True)
    want = rns_fused_encode_matmul_ref(profile, x, sx, w_res, bits=12)
    assert np.array_equal(np.asarray(got), np.asarray(want))

    a_res = dispatch.convert(profile, x, sx, bits=12,
                             backend="pallas_interpret")
    gotf = rns_fused_matmul_normalize(profile, a_res, w_res, interpret=True)
    wantf = rns_fused_matmul_normalize_ref(profile, a_res, w_res)
    assert np.array_equal(np.asarray(gotf), np.asarray(wantf))

    gotd = rns_fused_dot(profile, x, sx, w_res, bits=12, interpret=True)
    wantd = rns_fused_dot_ref(profile, x, sx, w_res, bits=12)
    assert np.array_equal(np.asarray(gotd), np.asarray(wantd))


def test_fused_dot_equals_unfused_pallas_chain():
    """Same kernels, three launches vs one: bit-identical floats."""
    x, sx, w_res = _operands("rns9", (6, 200, 12), seed=2)
    y_f = rns_fused_dot("rns9", x, sx, w_res, bits=12, interpret=True)
    r = dispatch.convert("rns9", x, sx, bits=12, backend="pallas_interpret")
    o = dispatch.matmul("rns9", r, w_res, backend="pallas_interpret")
    y_u = dispatch.normalize("rns9", o, backend="pallas_interpret")
    assert np.array_equal(np.asarray(y_f), np.asarray(y_u))


def test_fused_per_sequence_scale_rows():
    """Block-indexed s_ref: every row quantizes on ITS grid, exactly as
    the reference broadcast-multiply rule."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((3, 5, 24)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (3, 5)).astype(bool))
    s_rows = absmax_scale(x, 12, mask=mask)          # [3, 1, 1] per-seq grid
    assert s_rows.shape == (3, 1, 1)
    _, _, w_res = _operands("rns9", (3, 5, 24, 7), seed=3)
    got = rns_fused_dot("rns9", x, s_rows, w_res, bits=12, interpret=True)
    want = rns_fused_dot_ref("rns9", x, s_rows, w_res, bits=12)
    assert np.array_equal(np.asarray(got), np.asarray(want))
    got_em = rns_fused_encode_matmul("rns9", x, s_rows, w_res, bits=12,
                                     interpret=True)
    want_em = rns_fused_encode_matmul_ref("rns9", x, s_rows, w_res, bits=12)
    assert np.array_equal(np.asarray(got_em), np.asarray(want_em))


@given(st.integers(1, 40), st.integers(1, 90), st.integers(1, 20),
       st.sampled_from(PROFILES))
def test_fused_dot_property(M, D, N, profile):
    """Arbitrary (tail-heavy) shapes: fused == unfused reference chain."""
    x, sx, w_res = _operands(profile, (M, D, N), seed=M * 1000 + D * 10 + N)
    got = rns_fused_dot(profile, x, sx, w_res, bits=10, interpret=True)
    want = rns_fused_dot_ref(profile, x, sx, w_res, bits=10)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------ dispatch layer ----
def test_fused_backend_routes_and_counts():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((6, 200)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    cfg = RnsDotConfig(profile="rns9", qx=14, qw=14)
    y_ref = rns_dot(x, w, cfg)
    y_f = rns_dot(x, w, dataclasses.replace(cfg, backend="pallas_fused"))
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_f))
    with dispatch.count_ops() as c:
        jax.eval_shape(lambda x, w: rns_dot(
            x, w, dataclasses.replace(cfg, backend="pallas_fused")), x, w)
    # logical ops unchanged (x encode fused into the kernel; w encode
    # separate), plus ONE composite launch, zero silent downgrades
    assert (c.converts, c.matmuls, c.normalizes) == (2, 1, 1)
    assert c.fused == 1 and c.fallbacks == 0


def test_fused_multi_dot_shares_grid():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((4, 96)), jnp.float32)
    ws = tuple(jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
               for _ in range(3))
    cfg = RnsDotConfig(profile="rns9", qx=10, qw=10)
    cfg_f = dataclasses.replace(cfg, backend="pallas_fused")
    y_ref = rns_multi_dot(x, ws, cfg)
    y_f = rns_multi_dot(x, ws, cfg_f)
    for a, b in zip(y_ref, y_f):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # the one-conversion-per-block contract is backend-independent:
    # x counts once (shared_encode), each weight once
    c = {be: dispatch.trace_op_counts(
        lambda x, c=c_: rns_multi_dot(x, ws, c), x)
        for be, c_ in (("ref", cfg), ("fused", cfg_f))}
    assert c["fused"].converts == c["ref"].converts == 4
    assert c["fused"].matmuls == c["ref"].matmuls == 3
    assert c["fused"].fused == 3


def test_three_linear_oracle_fused_bit_identical():
    """The 3-linear oracle chain: pallas_fused == reference, bitwise."""
    from repro.models.layers import rns_linear_chain

    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    ws = tuple(jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
               for _ in range(3))
    cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
    y_ref = rns_linear_chain(x, ws, cfg)
    y_f = rns_linear_chain(
        x, ws, dataclasses.replace(cfg, backend="pallas_fused"))
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_f))


def test_deferred_mlp_fused_bit_identical_same_slow_ops():
    from repro.models.layers import init_mlp, mlp

    rng = np.random.default_rng(7)
    p, _ = init_mlp(jax.random.PRNGKey(0), 64, 128, gated=True)
    x = jnp.asarray(rng.standard_normal((4, 8, 64)), jnp.float32)
    cfg = RnsDotConfig(profile="rns9", qx=8, qw=8, defer=True)
    cfg_f = dataclasses.replace(cfg, backend="pallas_fused")
    y = mlp(p, x, gated=True, act="silu", rns=cfg)
    y_f = mlp(p, x, gated=True, act="silu", rns=cfg_f)
    assert np.array_equal(np.asarray(y), np.asarray(y_f))
    with dispatch.count_ops() as c:
        jax.eval_shape(lambda x: mlp(p, x, gated=True, act="silu", rns=cfg_f),
                       x)
    # the deferred slow-op budget survives fusion: 3 matmuls, 2 normalizes
    # (gate nonlinearity + main path), 3 composite launches, and the
    # SAME 5 conversions as the unfused deferred path (x once — wg's
    # composite marks it shared — 3 weights, 1 gate re-encode)
    assert (c.matmuls, c.normalizes, c.fused) == (3, 2, 3)
    assert c.converts == 5 and c.fallbacks == 0


def test_rt_fused_helpers_match_unfused():
    from repro.core.tensor import (
        rt_decode, rt_dot, rt_encode, rt_encode_matmul, rt_matmul,
        rt_matmul_decode)

    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((5, 48)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((48, 9)), jnp.float32)
    wt = rt_encode(w, "rns9", bits=10, backend="pallas_fused")
    xt = rt_encode(x, "rns9", bits=10, backend="pallas_fused")
    want_res = rt_matmul(xt, wt, backend="pallas_fused")
    got_res = rt_encode_matmul(x, wt, bits=10, backend="pallas_fused")
    assert np.array_equal(np.asarray(got_res.digits),
                          np.asarray(want_res.digits))
    assert got_res.mag_bits == want_res.mag_bits
    want_y = rt_decode(want_res, backend="pallas_fused")
    assert np.array_equal(
        np.asarray(rt_matmul_decode(xt, wt, backend="pallas_fused")),
        np.asarray(want_y))
    assert np.array_equal(
        np.asarray(rt_dot(x, wt, bits=10, backend="pallas_fused")),
        np.asarray(want_y))


# -------------------------------------------------- fallback visibility ---
def test_non_row_scale_falls_back_visibly():
    """A per-COLUMN grid cannot fold into the row operand: the composite
    decomposes and says so."""
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    s_col = jnp.asarray(rng.uniform(1, 30, (1, 16)), jnp.float32)
    _, _, w_res = _operands("rns9", (4, 16, 5), seed=9)
    with dispatch.count_ops() as c:
        got = dispatch.fused_dot("rns9", x, s_col, w_res, bits=10,
                                 backend="pallas_fused_interpret")
    assert c.fallbacks == 1 and c.fused == 0
    assert (c.converts, c.matmuls, c.normalizes) == (1, 1, 1)
    want = rns_fused_dot_ref("rns9", x, s_col, w_res, bits=10)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_out_of_range_inv_scale_counts_fallback():
    res = jnp.asarray(encode_int32("rns9", np.arange(8, dtype=np.int32)))
    inv = float(2.0 ** -140)       # below the pallas post-multiply range
    with dispatch.count_ops() as c:
        out = dispatch.normalize("rns9", res, inv_scale=inv,
                                 backend="pallas_interpret")
    assert c.fallbacks == 1
    # the downgrade routes to the reference path — bit-identical to
    # asking for it explicitly (which tallies NO fallback)
    with dispatch.count_ops() as c_ref:
        want = dispatch.normalize("rns9", res, inv_scale=inv,
                                  backend="reference")
    assert c_ref.fallbacks == 0
    assert np.array_equal(np.asarray(out), np.asarray(want))


def test_convert_per_sequence_scale_no_fallback():
    """Satellite 3: the pallas convert path covers non-scalar scales —
    no silent reference downgrade, no fallback tally."""
    from repro.core.quantize import quantize_with_scale

    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((3, 5, 11)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, (3, 5)).astype(bool))
    s = absmax_scale(x, 12, mask=mask)
    with dispatch.count_ops() as c:
        got = dispatch.convert("rns9", x, s, bits=12,
                               backend="pallas_interpret")
    assert c.fallbacks == 0 and c.converts == 1
    want = encode_int32("rns9", quantize_with_scale(x, s, 12))
    assert np.array_equal(np.asarray(got, np.int32), np.asarray(want))


def test_digit_sharded_context_decomposes_exactly():
    """Fused backend under a 1-wide digit mesh: the shard_map path wins
    and stays bit-identical (no fused kernels inside shard_map)."""
    from repro.distributed.sharding import use_digit_sharding
    from repro.launch.mesh import make_digit_mesh

    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    cfg = RnsDotConfig(profile="rns9", qx=10, qw=10, backend="pallas_fused")
    y_plain = rns_dot(x, w, cfg)
    mesh = make_digit_mesh()

    def fused_under_mesh(x, w):   # fresh def: trace cache is per-function
        return rns_dot(x, w, cfg)

    with use_digit_sharding(mesh):
        y_mesh = jax.jit(fused_under_mesh)(x, w)
    assert np.array_equal(np.asarray(y_plain), np.asarray(y_mesh))


# ------------------------------------------------------------- serving ----
def test_continuous_serve_fused_token_identical():
    """Acceptance: pallas_fused on a mixed-length continuous-serve run is
    token-identical to the reference backend, with fused ops counted and
    zero fallbacks (ragged prefill's per-seq grids are covered)."""
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serve.engine import ContinuousEngine, ServeConfig

    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(12)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 17, 40)]
    toks = {}
    for be in ("reference", "pallas_fused"):
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=64, max_new_tokens=4, page_size=16, max_seqs=3,
            rns_backend=be))
        res, stats = eng.run(prompts)
        toks[be] = {r: t.tolist() for r, t in res.items()}
        ops = stats["steps"][-1]["rns_ops"]
        if be == "pallas_fused":
            assert ops.fused > 0 and ops.fallbacks == 0
            assert eng._decode._cache_size() == 1
            assert eng._prefill._cache_size() == 1
    assert toks["reference"] == toks["pallas_fused"]
