"""Pallas flash-attention kernel vs oracle (interpret mode, shape sweep)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.attention import dense_attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "shape", [(1, 128, 128, 2, 1, 16), (2, 96, 200, 4, 2, 32),
              (1, 17, 33, 2, 2, 64),
              # non-tile-multiple tails on BOTH sequence axes (bq/bk = 64
              # below: 130 -> two tiles + tail, 5/7 -> sub-tile ragged)
              (1, 130, 257, 2, 1, 32), (2, 7, 5, 2, 2, 16),
              (1, 65, 64, 2, 1, 16)])
def test_flash_kernel_matches_refs(causal, shape):
    B, Tq, Tk, H, Hk, D = shape
    rng = np.random.default_rng(hash((causal,) + shape) % 2**32)
    q = jnp.asarray(rng.standard_normal((B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Tk, Hk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Tk, Hk, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    # oracle 1: kernel-layout ref
    G = H // Hk
    kb = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, D)
    vb = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, D)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, D)
    want = flash_attention_ref(qb, kb, vb, causal=causal, tk_valid=Tk)
    want = want.reshape(B, H, Tq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    # oracle 2: the model's dense attention (self-attn case only)
    if Tq == Tk:
        want2 = dense_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want2),
                                   atol=2e-5)


@given(st.integers(1, 70), st.integers(1, 70), st.booleans())
def test_flash_kernel_property_ragged(Tq, Tk, causal):
    """Arbitrary ragged (Tq, Tk): padded tiles mask out exactly."""
    B, H, Hk, D = 1, 2, 1, 16
    rng = np.random.default_rng(Tq * 97 + Tk * 3 + causal)
    q = jnp.asarray(rng.standard_normal((B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, Tk, Hk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, Tk, Hk, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    kb = jnp.repeat(k, H // Hk, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, D)
    vb = jnp.repeat(v, H // Hk, 2).transpose(0, 2, 1, 3).reshape(B * H, Tk, D)
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, D)
    want = flash_attention_ref(qb, kb, vb, causal=causal, tk_valid=Tk)
    want = want.reshape(B, H, Tq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
