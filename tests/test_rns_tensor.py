"""RnsTensor: pytree round-trips, deferred chains vs python-int oracle,
and the one-normalize-per-chain op-count contract."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core.moduli import get_profile
from repro.core.rns import decode_exact
from repro.core.rns_matmul import RnsDotConfig
from repro.core.tensor import (
    RnsTensor,
    rt_add,
    rt_decode,
    rt_encode,
    rt_encode_int,
    rt_matmul,
    rt_mul,
)

PROFILE = "rns9"


def _mk_rt(rng, shape=(3, 4), bits=8):
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    return x, rt_encode(x, PROFILE, bits=bits)


# ------------------------------------------------------------- pytree -----
class TestPytree:
    def test_flatten_unflatten_roundtrip(self):
        rng = np.random.default_rng(0)
        _, rt = _mk_rt(rng)
        leaves, treedef = jax.tree_util.tree_flatten(rt)
        rt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert isinstance(rt2, RnsTensor)
        assert rt2.profile == rt.profile
        assert rt2.mag_bits == rt.mag_bits
        assert rt2.frac_exp == rt.frac_exp
        assert np.array_equal(np.asarray(rt2.digits), np.asarray(rt.digits))
        assert float(rt2.scale) == float(rt.scale)

    def test_jit_identity_and_consume(self):
        rng = np.random.default_rng(1)
        x, rt = _mk_rt(rng)

        @jax.jit
        def through(t: RnsTensor) -> RnsTensor:
            return t

        rt2 = through(rt)
        assert isinstance(rt2, RnsTensor) and rt2.profile == rt.profile
        assert np.array_equal(np.asarray(rt2.digits), np.asarray(rt.digits))

        @jax.jit
        def decode(t):
            return rt_decode(t)

        got = np.asarray(decode(rt))
        # 8-bit grid: |err| <= 0.5/scale (+f32 reconstruction slack)
        assert np.max(np.abs(got - np.asarray(x))) <= 0.51 / float(rt.scale)

    def test_jit_produces_rnstensor(self):
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.standard_normal((2, 5)), jnp.float32)
        rt = jax.jit(lambda x: rt_encode(x, PROFILE, bits=8))(x)
        assert isinstance(rt, RnsTensor)
        np.testing.assert_allclose(np.asarray(rt_decode(rt)), np.asarray(x),
                                   atol=0.5 / float(rt.scale))

    def test_vmap_over_batch_axis(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((4, 3, 5)), jnp.float32)
        rt = rt_encode(x, PROFILE, bits=8)  # digits [K, 4, 3, 5]
        axes = RnsTensor(digits=1, scale=None, profile=rt.profile,
                         mag_bits=rt.mag_bits, frac_exp=rt.frac_exp)
        ys = jax.vmap(rt_decode, in_axes=(axes,))(rt)
        np.testing.assert_allclose(
            np.asarray(ys), np.asarray(rt_decode(rt)), rtol=1e-6)


# ------------------------------------------------- deferred chain oracle ---
class TestDeferredChain:
    def test_three_linear_chain_matches_per_op_bit_for_bit(self):
        """Acceptance: >=3 chained RNS linears, ONE MRC normalization,
        decode bit-identical to the per-op-normalized reference."""
        rng = np.random.default_rng(4)
        p = get_profile(PROFILE)
        xi = rng.integers(-7, 8, (2, 8)).astype(np.int32)
        ws = [rng.integers(-7, 8, (8, 8)).astype(np.int32) for _ in range(2)]
        ws.append(rng.integers(-7, 8, (8, 4)).astype(np.int32))

        # deferred: stay in residues across all three matmuls
        with dispatch.count_ops() as c_def:
            ht = rt_encode_int(xi, PROFILE, mag_bits=3)
            for w in ws:
                ht = rt_matmul(ht, rt_encode_int(w, PROFILE, mag_bits=3))
            deferred = decode_exact(p, np.asarray(ht.digits.astype(jnp.int32)))

        # per-op: normalize (exact int decode) and re-encode after EVERY op
        with dispatch.count_ops() as c_per:
            ht = rt_encode_int(xi, PROFILE, mag_bits=3)
            for w in ws:
                ht = rt_matmul(ht, rt_encode_int(w, PROFILE, mag_bits=3))
                ints = decode_exact(p, np.asarray(ht.digits.astype(jnp.int32)))
                dispatch.normalize(  # count the slow op the re-entry pays
                    PROFILE, ht.digits.astype(jnp.int32))
                ht = rt_encode_int(
                    np.asarray(ints, np.int64).astype(np.int32), PROFILE,
                    mag_bits=30)
            per_op = decode_exact(p, np.asarray(ht.digits.astype(jnp.int32)))

        want = xi.astype(object)
        for w in ws:
            want = want @ w.astype(object)
        assert np.array_equal(deferred, want)
        assert np.array_equal(per_op, want)
        assert np.array_equal(deferred, per_op)  # bit-for-bit
        # the structural claim: 3 matmuls, 0 normalizations in-residues
        # (the single final decode_exact is the chain's one slow op) vs
        # one normalization per matmul on the per-op path
        assert c_def.matmuls == 3 and c_def.normalizes == 0
        assert c_per.matmuls == 3 and c_per.normalizes == 3

    def test_chain_single_normalize_through_decode(self):
        """Float chain: one rt_decode == exactly one dispatch.normalize."""
        rng = np.random.default_rng(5)
        cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
        x = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
        ws = tuple(jnp.asarray(rng.standard_normal((16, 16)) / 4, jnp.float32)
                   for _ in range(3))

        from repro.models.layers import rns_linear_chain

        with dispatch.count_ops() as c:
            y = rns_linear_chain(x, ws, cfg)
        assert c.matmuls == 3
        assert c.normalizes == 1  # ONE MRC for the whole chain
        ref = x
        for w in ws:
            ref = ref @ w
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=0.35)

    def test_op_count_under_jit_trace(self):
        rng = np.random.default_rng(6)
        cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
        x = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
        ws = tuple(jnp.asarray(rng.standard_normal((16, 16)) / 4, jnp.float32)
                   for _ in range(3))
        from repro.models.layers import rns_linear_chain

        c = dispatch.trace_op_counts(
            jax.jit(lambda x: rns_linear_chain(x, ws, cfg)), x)
        assert (c.matmuls, c.normalizes) == (3, 1)
        assert c.normalizes_per_matmul == pytest.approx(1 / 3)

    def test_ledger_inserts_renormalize_on_overflow(self):
        """Magnitude bookkeeping: a chain that would exceed the profile's
        exact range triggers an automatic mid-chain renormalization."""
        rng = np.random.default_rng(7)
        cfg = RnsDotConfig(profile="rns5", qx=12, qw=12)  # ~34.8 bits only
        x = jnp.asarray(rng.standard_normal((2, 64)), jnp.float32)
        ws = tuple(jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
                   for _ in range(3))
        from repro.models.layers import rns_linear_chain

        with dispatch.count_ops() as c:
            y = rns_linear_chain(x, ws, cfg)
        assert c.matmuls == 3
        assert 1 < c.normalizes <= 3  # ledger-forced renorms + final decode
        ref = x
        for w in ws:
            ref = ref @ w
        err = np.max(np.abs(np.asarray(y) - np.asarray(ref)))
        assert err < 0.1 * float(jnp.max(jnp.abs(ref)) + 1.0)

    def test_elementwise_mul_and_add_defer(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((32,)), jnp.float32)
        with dispatch.count_ops() as c:
            xt = rt_encode(x, PROFILE, bits=12)
            yt = rt_encode(y, PROFILE, bits=12)
            pt = rt_mul(xt, yt)
            st = rt_add(pt, pt)
            out = np.asarray(rt_decode(st))
        assert c.normalizes == 1  # product+sum normalized once
        np.testing.assert_allclose(out, np.asarray(2 * x * y), atol=2e-2)


# -------------------------------------------------------- model datapath ---
class TestModelDatapaths:
    def test_deferred_mlp_fewer_normalizes_and_close(self):
        from repro.models.layers import init_mlp, mlp

        rng = np.random.default_rng(9)
        key = jax.random.PRNGKey(0)
        d, d_ff = 16, 32
        p, _ = init_mlp(key, d, d_ff, gated=True)
        x = jnp.asarray(rng.standard_normal((2, 6, d)), jnp.float32)
        per_op = RnsDotConfig(profile="rns9", qx=8, qw=8)
        deferred = dataclasses.replace(per_op, defer=True)

        with dispatch.count_ops() as c_p:
            y_p = mlp(p, x, gated=True, act="silu", rns=per_op)
        with dispatch.count_ops() as c_d:
            y_d = mlp(p, x, gated=True, act="silu", rns=deferred)
        # per-op: one normalize per matmul; deferred: gate + final only
        assert c_p.normalizes == 3 and c_p.matmuls == 3
        assert c_d.normalizes == 2 and c_d.matmuls == 3
        # shared conversion on the per-op path: one convert for x + wi + wg
        # + h + wo = 5, vs 6 when every matmul converts both operands
        assert c_p.converts == 5
        y_ref = mlp(p, x, gated=True, act="silu")
        tol = 0.15 * float(jnp.max(jnp.abs(y_ref)) + 1e-3)
        assert np.max(np.abs(np.asarray(y_p) - np.asarray(y_ref))) < tol
        assert np.max(np.abs(np.asarray(y_d) - np.asarray(y_ref))) < tol

    def test_deferred_mlp_grads(self):
        from repro.models.layers import init_mlp, mlp

        rng = np.random.default_rng(10)
        p, _ = init_mlp(jax.random.PRNGKey(1), 8, 16, gated=True)
        x = jnp.asarray(rng.standard_normal((3, 8)), jnp.float32)
        cfg = RnsDotConfig(profile="rns9", qx=8, qw=8, defer=True)

        def loss(p, x):
            return jnp.sum(mlp(p, x, gated=True, act="silu", rns=cfg) ** 2)

        gp, gx = jax.grad(loss, argnums=(0, 1))(p, x)
        gp_ref, gx_ref = jax.grad(
            lambda p, x: jnp.sum(mlp(p, x, gated=True, act="silu") ** 2),
            argnums=(0, 1))(p, x)
        for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gp_ref)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b),
                atol=0.2 * float(jnp.max(jnp.abs(b)) + 1e-3))
        assert bool(jnp.all(jnp.isfinite(gx)))

    def test_linear_consumes_and_produces_rnstensor(self):
        from repro.models.layers import init_linear, linear

        rng = np.random.default_rng(11)
        cfg = RnsDotConfig(profile="rns9", qx=8, qw=8)
        p1, _ = init_linear(jax.random.PRNGKey(2), 12, 12, axes=(None, None))
        p2, _ = init_linear(jax.random.PRNGKey(3), 12, 6, axes=(None, None))
        x = jnp.asarray(rng.standard_normal((4, 12)), jnp.float32)
        with dispatch.count_ops() as c:
            xt = rt_encode(x, cfg.profile, bits=cfg.qx)
            h = linear(p1, xt, cfg)     # RnsTensor in ...
            assert isinstance(h, RnsTensor)
            y = linear(p2, h, cfg)      # ... RnsTensor out, still deferred
            out = rt_decode(y)
        assert c.normalizes == 1
        ref = x @ p1["w"] @ p2["w"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=0.25 * float(jnp.max(jnp.abs(ref))))

    def test_profile_mismatch_raises(self):
        rng = np.random.default_rng(12)
        x, rt = _mk_rt(rng, (2, 4))
        other = rt_encode(x, "rns12", bits=8)
        with pytest.raises(ValueError, match="profile mismatch"):
            rt_matmul(rt, other)


# ----------------------------------------------------------- train/serve ---
def test_measure_rns_ops_counts_mlp_matmuls():
    from repro.configs.base import get_config
    from repro.train.train_step import measure_rns_ops

    cfg = get_config("smollm-135m", smoke=True)
    cfg = dataclasses.replace(
        cfg, rns=RnsDotConfig(profile="rns9", qx=14, qw=14),
        rns_targets="mlp")
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32)}
    c = measure_rns_ops(cfg, batch)
    assert c.matmuls > 0
    assert c.normalizes_per_matmul <= 1.0
