"""Property tests for the refcounted allocator + prefix cache invariants.

The four invariants the PR-5 sharing machinery stands on:
  * a page's refcount is never negative and never goes stale — free
    pages + referenced pages always partition the usable pool;
  * ``free`` is idempotent under sharing: once a page has fully returned
    to the pool, further frees are no-ops (and a shared page only drops
    ONE holder per free);
  * a COW split preserves the gathered KV of every OTHER holder
    bit-for-bit (the frozen original is untouched; the copy is exact);
  * prefix-hash lookup never aliases distinct prefixes — a hit on block
    ``b`` implies the querying prompt's prefix through block ``b`` is
    byte-identical to the registered one.

Runs under hypothesis when installed (the CI extra); collects and skips
cleanly without it (tests/_hypothesis_stub.py).
"""

import numpy as np
import pytest
from _hypothesis_stub import given, st

import jax.numpy as jnp

from repro.serve.kv_cache import (
    TRASH_PAGE,
    PageAllocator,
    PrefixCache,
    copy_pages,
    gather_pages,
)


# ---------------------------------------------------------- allocator -----
@given(st.lists(st.tuples(st.sampled_from(["alloc", "incref", "free"]),
                          st.integers(min_value=1, max_value=4)),
                max_size=60))
def test_allocator_refcount_invariants(ops):
    """Random alloc/incref/free interleavings against a reference model:
    counts stay exact, non-negative, and conservation holds."""
    n_pages = 9
    a = PageAllocator(n_pages)
    model: dict[int, int] = {}          # page -> refcount (allocated only)
    held: list[int] = []                # multiset of references we hold
    rng = np.random.default_rng(0)
    for op, k in ops:
        if op == "alloc":
            got = a.alloc(k)
            if len(model) + k > n_pages - 1:
                assert got is None      # over-capacity: no partial grants
                continue
            assert got is not None and len(got) == k
            for pg in got:
                assert pg not in model  # never hand out a live page twice
                model[pg] = 1
                held.append(pg)
        elif op == "incref" and held:
            pg = held[int(rng.integers(len(held)))]
            a.incref([pg])
            model[pg] += 1
            held.append(pg)
        elif op == "free" and held:
            pg = held.pop(int(rng.integers(len(held))))
            a.free([pg])
            model[pg] -= 1
            if model[pg] == 0:
                del model[pg]
        # the invariants, after every single operation:
        for pg in range(1, n_pages):
            assert a.refcount(pg) == model.get(pg, 0)
            assert a.refcount(pg) >= 0
        assert a.n_free == (n_pages - 1) - len(model)


@given(st.integers(min_value=2, max_value=5))
def test_free_idempotent_under_sharing(extra_refs):
    """A shared page drops exactly one holder per free; once fully
    released, further frees are silent no-ops (never negative, never a
    duplicate free-list entry)."""
    a = PageAllocator(6)
    (pg,) = a.alloc(1)
    a.incref([pg] * (extra_refs - 1))
    for expect in range(extra_refs - 1, -1, -1):
        a.free([pg])
        assert a.refcount(pg) == expect
    assert a.n_free == 5
    for _ in range(3):
        a.free([pg])                    # already free: idempotent
        assert a.refcount(pg) == 0
        assert a.n_free == 5
        assert sorted(a._free) == [1, 2, 3, 4, 5]   # no duplicates


def test_incref_of_free_page_raises():
    a = PageAllocator(4)
    with pytest.raises(ValueError, match="incref"):
        a.incref([2])


# ------------------------------------------------------------- COW copy ---
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_cow_copy_preserves_other_holders_bitwise(seed):
    """The COW split: after copying a shared page to a fresh one and
    repointing ONE holder's table, the other holder's gathered dense
    view is bit-identical to before, and the mover's view is too (the
    copy is exact) — divergence only begins with the first post-split
    write."""
    rng = np.random.default_rng(seed)
    npr, P, bs, d = 2, 6, 4, 3
    pages = jnp.asarray(rng.standard_normal((npr, P, bs, d)), jnp.float32)
    bt_a = np.array([[1, 2]], np.int32)          # A shares page 2 with B
    bt_b = np.array([[3, 2]], np.int32)
    before_a = np.asarray(gather_pages(pages[0], jnp.asarray(bt_a)))
    before_b = np.asarray(gather_pages(pages[0], jnp.asarray(bt_b)))
    # split for B: copy page 2 -> fresh page 4, repoint B only
    src = jnp.asarray([2, TRASH_PAGE], jnp.int32)
    dst = jnp.asarray([4, TRASH_PAGE], jnp.int32)
    pages2 = copy_pages(pages, src, dst)
    bt_b2 = np.array([[3, 4]], np.int32)
    after_a = np.asarray(gather_pages(pages2[0], jnp.asarray(bt_a)))
    after_b = np.asarray(gather_pages(pages2[0], jnp.asarray(bt_b2)))
    assert (before_a == after_a).all()           # frozen original intact
    assert (before_b == after_b).all()           # the copy is exact
    # and a write into B's copy leaves A untouched
    pages3 = pages2.at[:, 4].set(0.0)
    assert (np.asarray(gather_pages(pages3[0], jnp.asarray(bt_a)))
            == before_a).all()


# ----------------------------------------------------------- no aliasing --
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=24),
       st.integers(min_value=1, max_value=24))
def test_prefix_lookup_never_aliases_distinct_prefixes(seed, la, lb):
    """Register prompt A's blocks, look up random prompt B: every hit
    block's prefix must be byte-identical to A's — a differing token
    anywhere in the covered prefix kills the hit for that block and all
    later blocks whose keys embed it."""
    bs = 4
    rng = np.random.default_rng(seed)
    a_tok = rng.integers(0, 4, (la,)).astype(np.int32)   # tiny vocab:
    b_tok = rng.integers(0, 4, (lb,)).astype(np.int32)   # collisions likely
    alloc = PageAllocator(32)
    cache = PrefixCache(alloc, bs)
    n_blocks_a = -(-la // bs)
    pages_a = alloc.alloc(n_blocks_a)
    cache.insert(a_tok, pages_a)
    shared, n_cached = cache.lookup(b_tok)
    for b, pg in enumerate(shared):
        if pg is None:
            continue
        end = min((b + 1) * bs, lb)
        assert end <= la
        assert (b_tok[:end] == a_tok[:end]).all(), (a_tok, b_tok, b)
        assert pg == pages_a[b]
    # and the cached-token count is consistent with the hits
    assert n_cached == sum(
        min((b + 1) * bs, lb) - b * bs
        for b, pg in enumerate(shared) if pg is not None)


# ------------------------------------------------------------ liveness ----
def test_stale_entries_self_heal_and_resubmit_misses():
    """Regression (staleness under eviction): lookup results must be
    backed by live, refcounted pages.  Adversarial trace — register a
    prompt, yank the index's own references out from under it (the
    over-free bug class the scheduler's ``_release`` discipline now
    prevents at the source), then resubmit: the recycled pages must
    never be served as cached KV; the dead entries self-heal instead."""
    bs = 4
    alloc = PageAllocator(8)
    cache = PrefixCache(alloc, bs)
    tok = np.arange(1, 9, dtype=np.int32)        # exactly 2 full blocks
    pages = alloc.alloc(2)
    cache.insert(tok, pages)                     # index: one ref per block
    alloc.free(pages)                            # the producer departs
    assert [alloc.refcount(p) for p in pages] == [1, 1]
    live, n = cache.lookup(tok)
    assert live == pages and n == 8              # healthy: still served
    alloc.free(pages)                            # adversarial over-free
    assert alloc.n_free == 7                     # pages back in the pool
    gen = cache.generation
    assert cache.peek_cached_tokens(tok) == 0    # probe sees them dead...
    assert cache.generation == gen               # ...without mutating
    shared, n = cache.lookup(tok)
    assert shared == [None, None] and n == 0     # stale: dropped, not served
    assert cache.stale_drops == 2
    assert cache.generation > gen                # peek memos invalidated
    # resubmission re-registers cleanly: served again, live refs
    # (producer's + the index's)
    cache.insert(tok, alloc.alloc(2))
    shared, n = cache.lookup(tok)
    assert n == 8 and all(alloc.refcount(p) == 2 for p in shared)


# ---------------------------------------------- scheduler-level sharing ---
@given(st.lists(st.integers(1, 14), min_size=2, max_size=5),
       st.sampled_from([None, 5, 8]),
       st.booleans())
def test_refcounts_never_negative_under_evict_cow_preempt(lens, window,
                                                          same_prefix):
    """Interleaved window evictions, COW splits and LIFO preemptions (a
    pool of 5 pages for up to 5 rows forces all three) against the
    allocator invariants: no refcount ever dips negative, every page a
    running row's block table points at stays live, and free pages +
    referenced pages partition the pool after every scheduler call.
    ``window=None`` runs the prefix-sharing/COW side; a set window runs
    the eviction side (where registration is disabled by design)."""
    from repro.serve.kv_cache import PagedCacheConfig
    from repro.serve.scheduler import Request, Scheduler

    bs, max_blocks = 4, 4
    pcfg = PagedCacheConfig(page_size=bs, n_pages=1 + max_blocks + 1,
                            max_seqs=2, max_blocks=max_blocks,
                            resident_blocks=None if window is None else 3)
    sched = Scheduler(pcfg, prefix_cache=window is None, chunked=True,
                      token_budget=6, chunk_size=bs, prefill_reserve=3,
                      window_tokens=window)
    rng = np.random.default_rng(11)
    base = rng.integers(1, 9, (14,)).astype(np.int32)
    for i, L in enumerate(lens):
        tok = (base[:L].copy() if same_prefix
               else rng.integers(1, 99, (L,)).astype(np.int32))
        sched.submit(Request(rid=i, tokens=tok, max_new=2))

    def check():
        for s in sched.running.values():
            for pg in s.pages:
                if pg != TRASH_PAGE:
                    assert sched.alloc.refcount(pg) >= 1, pg
        n_ref = 0
        for pg in range(1, pcfg.n_pages):
            rc = sched.alloc.refcount(pg)
            assert rc >= 0, pg
            n_ref += rc > 0
        assert sched.alloc.n_free + n_ref == pcfg.n_pages - 1

    steps = 0
    while sched.has_work:
        steps += 1
        assert steps <= 400, "scheduler loop did not terminate"
        sched.schedule()
        check()
        for s in sched.plan_mixed(1):
            seq = s.seq
            if s.kind == "chunk":
                sched.register_chunks(seq)
                if s.last:
                    seq.emitted = [1]
                    seq.last_token = 1
            else:
                seq.emitted.append(1)
                seq.length += 1
        for seq in list(sched.running.values()):
            if seq.emitted and len(seq.emitted) >= seq.req.max_new:
                sched.complete(seq)
                check()
    check()


@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prefix_partial_tail_requires_exact_whole_prompt(seed):
    """The partial-tail entry hits only on an exact whole-prompt match:
    a prompt that extends or truncates the registered one differently
    must miss the tail (full-block hits are still allowed)."""
    bs = 4
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 6, (10,)).astype(np.int32)    # 2 full + tail(2)
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc, bs)
    pages = alloc.alloc(3)
    cache.insert(base, pages)
    # same prompt: full hit incl. partial tail
    shared, n = cache.lookup(base)
    assert shared == pages and n == 10
    # one token longer: tail key differs -> tail misses
    longer = np.concatenate([base, [1]]).astype(np.int32)
    shared, n = cache.lookup(longer)
    assert shared[:2] == pages[:2] and shared[2] is None and n == 8
    # divergent last token: tail misses
    div = base.copy()
    div[-1] = (div[-1] + 1) % 6
    shared, n = cache.lookup(div)
    assert shared[2] is None and n == 8
