"""Attention equivalences: flash == chunked == dense; decode == full."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, st

from repro.models.attention import (
    chunked_attention,
    decode_attention,
    dense_attention,
    flash_attention,
    rope,
)


def _qkv(rng, B=2, T=96, H=8, Hk=2, D=16, Dv=None):
    q = jnp.asarray(rng.standard_normal((B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, T, Hk, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, T, Hk, Dv or D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Dv", [16, 24])
def test_chunked_and_flash_match_dense(causal, Dv):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, Dv=Dv)
    # position 0 always valid: a fully-masked row is undefined behaviour in
    # any softmax-attention implementation
    mask = jnp.asarray(rng.random((2, 96)) > 0.2).at[:, 0].set(True)
    d = dense_attention(q, k, v, causal=causal, kv_mask=mask)
    c = chunked_attention(q, k, v, causal=causal, kv_mask=mask, chunk=17)
    f = flash_attention(q, k, v, causal=causal, kv_mask=mask, q_chunk=32,
                        kv_chunk=17)
    np.testing.assert_allclose(np.asarray(d), np.asarray(c), atol=2e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(f), atol=2e-5)


def test_flash_gradients_match_dense():
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, T=64)

    def loss(fn, q, k, v):
        return jnp.sum(fn(q, k, v) ** 2)

    gd = jax.grad(lambda q, k, v: loss(
        lambda *a: dense_attention(*a, causal=True), q, k, v), (0, 1, 2))(q, k, v)
    gf = jax.grad(lambda q, k, v: loss(
        lambda *a: flash_attention(*a, causal=True, q_chunk=16, kv_chunk=16),
        q, k, v), (0, 1, 2))(q, k, v)
    for a, b in zip(gd, gf):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_decode_matches_dense_last_row():
    rng = np.random.default_rng(2)
    B, T, H, Hk, D = 2, 33, 6, 3, 8
    q, k, v = _qkv(rng, B, T, H, Hk, D)
    full = dense_attention(q, k, v, causal=True)
    # decode the last position against a padded cache with ragged lengths
    S = 48
    kc = jnp.zeros((B, S, Hk, D)).at[:, :T].set(k)
    vc = jnp.zeros((B, S, Hk, D)).at[:, :T].set(v)
    out, lse = decode_attention(q[:, -1:], kc, vc, jnp.full((B,), T))
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1:]),
                               atol=2e-5)


def test_decode_lse_combine_over_seq_shards():
    """Flash-decoding invariant: shard KV on seq, combine partials via LSE."""
    rng = np.random.default_rng(3)
    B, T, H, Hk, D = 2, 64, 4, 2, 8
    q, k, v = _qkv(rng, B, T, H, Hk, D)
    full, _ = decode_attention(q[:, -1:], k, v, jnp.full((B,), T))
    o1, l1 = decode_attention(q[:, -1:], k[:, :40], v[:, :40],
                              jnp.full((B,), 40))
    # second shard holds positions 40..64 (mask: lengths relative to shard)
    o2, l2 = decode_attention(q[:, -1:], k[:, 40:], v[:, 40:],
                              jnp.full((B,), T - 40))
    w1 = jnp.exp(l1 - jnp.logaddexp(l1, l2))
    w2 = 1.0 - w1
    B_, Hk_, G, Tq = w1.shape
    wf1 = w1.transpose(0, 3, 1, 2).reshape(B_, Tq, Hk_ * G)[..., None]
    wf2 = w2.transpose(0, 3, 1, 2).reshape(B_, Tq, Hk_ * G)[..., None]
    comb = o1 * wf1 + o2 * wf2
    np.testing.assert_allclose(np.asarray(comb), np.asarray(full), atol=2e-5)


@given(st.integers(0, 2**20))
def test_rope_preserves_norm(offset):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 16)), jnp.float32)
    pos = jnp.full((1, 4), offset)
    y = rope(x, pos)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,i), rope(k,j)> depends only on i - j."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 32)), jnp.float32)

    def dot_at(i, j):
        qi = rope(q, jnp.full((1, 1), i))
        kj = rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 7) - dot_at(1000, 1000)) < 1e-3
