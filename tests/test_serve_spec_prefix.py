"""Speculative decoding + COW prefix caching: the PR-5 tentpole, executed.

The load-bearing claims:
  * spec decode emits a token stream IDENTICAL to vanilla continuous
    batching (greedy accept/reject), through ONE jitted [R, W] verify
    step (zero per-length recompiles; the [R, 1] decode jit never even
    compiles);
  * shared-prefix admissions adopt cached pages — zero redundant page
    writes (the prefill blit skips shared blocks; allocator counters
    prove the pages were never re-allocated);
  * a row splits a shared page before its first divergent write (COW),
    leaving the frozen original bit-intact for later adopters;
  * the scheduler's starvation guards bound both repeated preemption
    (preempt shield) and cache-preference queue-jumping (FCFS fallback).
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig
from repro.serve.kv_cache import PagedCacheConfig
from repro.serve.scheduler import Request, Scheduler


def _params(cfg, seed=0):
    return M.init_model(jax.random.PRNGKey(seed), cfg)[0]


def _solo(params, cfg, prompt, max_new, max_cache):
    eng = Engine(params, cfg, ServeConfig(max_cache=max_cache,
                                          max_new_tokens=max_new))
    return eng.generate(prompt[None])[0].tolist()


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m", smoke=True)
    return cfg, _params(cfg)


# ------------------------------------------------------------ speculative --
def test_spec_decode_token_identical_one_verify_compile(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (7, 33, 120)]
    max_new, S = 8, 160
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=4,
        spec_decode=True, spec_k=3))
    res, stats = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, max_new, S), i
    # compile budget: ONE verify cell, ONE prefill cell, and the vanilla
    # decode jit is never traced at all in spec mode
    assert eng._verify._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    assert eng._decode._cache_size() == 0
    assert stats["total_new_tokens"] == 3 * max_new
    assert stats["tokens_per_step"] >= 1.0   # never slower in tokens/step


def test_spec_decode_acceptance_shortens_runs(smollm):
    """A prompt whose greedy continuation the n-gram proposer can
    predict finishes in fewer verify steps than max_new."""
    cfg, params = smollm
    rng = np.random.default_rng(1)
    p = rng.integers(1, cfg.vocab, (12,)).astype(np.int32)
    max_new, S = 24, 96
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=1,
        spec_decode=True, spec_k=4))
    res, stats = eng.run([p])
    assert res[0].tolist() == _solo(params, cfg, p, max_new, S)
    assert len(res[0]) == max_new
    # the run used fewer decode steps than tokens decoded iff some draft
    # was accepted; with this seed the smoke model repeats itself enough
    assert stats["acceptance_rate"] > 0.0
    assert stats["tokens_per_step"] > 1.0


def test_spec_decode_rns_token_identical():
    """Per-token quantization grids keep the [R, W] verify window
    bit-identical per position to solo decode on the RNS path too —
    deferred and per-op normalization both."""
    from repro.core.rns_matmul import RnsDotConfig

    base = dataclasses.replace(get_config("smollm-135m", smoke=True),
                               rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                               rns_targets="mlp")
    params = _params(base)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, base.vocab, (L,)).astype(np.int32)
               for L in (7, 33)]
    max_new, S = 6, 96
    for defer in (False, True):
        eng = ContinuousEngine(params, base, ServeConfig(
            max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=2,
            spec_decode=True, spec_k=3, rns_defer=defer))
        res, _ = eng.run(prompts)
        cfg_i = (base if not defer
                 else dataclasses.replace(
                     base, rns=dataclasses.replace(base.rns, defer=True)))
        for i, p in enumerate(prompts):
            assert res[i].tolist() == _solo(params, cfg_i, p, max_new, S), (
                defer, i)


def test_spec_decode_mla_paged_window():
    cfg = dataclasses.replace(get_config("deepseek-v2-236b", smoke=True),
                              mlp_types=("dense",) * 4, moe=None)
    params = _params(cfg, seed=1)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 21)]
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=64, max_new_tokens=6, page_size=8, max_seqs=2,
        spec_decode=True, spec_k=3))
    res, _ = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, 6, 64), i


def test_spec_decode_eos_stops_row_mid_window(smollm):
    """eos accepted inside a draft run truncates exactly where vanilla
    decode would stop — accepted tokens past eos are discarded."""
    cfg, params = smollm
    rng = np.random.default_rng(7)
    p = rng.integers(1, cfg.vocab, (9,)).astype(np.int32)
    base = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=8, page_size=16, max_seqs=1))
    full, _ = base.run([p])
    toks = full[0].tolist()
    eos = int(toks[2])                      # aim for the 3rd token
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=8, page_size=16, max_seqs=1,
        eos_id=eos, spec_decode=True, spec_k=3))
    res, _ = eng.run([p])
    assert res[0].tolist() == toks[: toks.index(eos) + 1]


def test_spec_validation():
    with pytest.raises(ValueError, match="spec_k"):
        ServeConfig(spec_decode=True, spec_k=0)
    with pytest.raises(ValueError, match="spec_ngram"):
        ServeConfig(spec_decode=True, spec_ngram=0)


# ---------------------------------------------------------- prefix cache --
def test_prefix_cache_identical_prompt_zero_redundant_writes(smollm):
    """The second admission of an identical prompt adopts every block:
    its prefill blits NOTHING (all blocks map to the trash page) and the
    only fresh page it ever takes is the COW split of the partial tail."""
    cfg, params = smollm
    rng = np.random.default_rng(4)
    p = rng.integers(1, cfg.vocab, (40,)).astype(np.int32)   # 2 full + tail
    max_new, S = 6, 64
    want = _solo(params, cfg, p, max_new, S)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=1,
        prefix_cache=True))
    r0 = eng.submit(p.copy())
    while eng.sched.running or (eng.sched.waiting and r0 not in eng.results):
        eng.step()
    alloc_after_first = eng.sched.alloc.pages_allocated
    r1 = eng.submit(p.copy())
    stats = []
    while eng.sched.has_work:
        stats.append(eng.step())
    assert eng.results[r0].tolist() == want
    assert eng.results[r1].tolist() == want
    # the whole prompt was served from cache...
    assert sum(s["cache_hit_tokens"] for s in stats) == 40
    # ...so the second request allocated exactly ONE page: the COW copy
    # of the shared partial tail it writes its first generated KV into
    assert sum(s["cow_splits"] for s in stats) == 1
    assert eng.sched.alloc.pages_allocated == alloc_after_first + 1


def test_prefix_cache_cow_preserves_frozen_page(smollm):
    """Three identical prompts in sequence: every adopter COW-splits
    before writing, so the cached pages stay bit-frozen and each later
    adopter still decodes the exact solo stream."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    p = rng.integers(1, cfg.vocab, (20,)).astype(np.int32)   # 1 full + tail
    max_new, S = 8, 48
    want = _solo(params, cfg, p, max_new, S)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=1,
        prefix_cache=True))
    res, stats = eng.run([p.copy(), p.copy(), p.copy()])
    for i in range(3):
        assert res[i].tolist() == want, i
    assert stats["cow_splits"] == 2          # adopters 2 and 3 each split
    assert stats["cache_hit_tokens"] == 40   # 20 cached tokens x 2 adopters


def test_prefix_cache_divergent_suffix_shares_only_prefix(smollm):
    """Prompts sharing 32 tokens then diverging: full prefix blocks are
    shared, the divergent tail is not, and both streams stay exact."""
    cfg, params = smollm
    rng = np.random.default_rng(6)
    shared = rng.integers(1, cfg.vocab, (32,)).astype(np.int32)
    pa = np.concatenate([shared, rng.integers(1, cfg.vocab, (8,)
                                              ).astype(np.int32)])
    pb = np.concatenate([shared, rng.integers(1, cfg.vocab, (11,)
                                              ).astype(np.int32)])
    max_new, S = 6, 64
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=1,
        prefix_cache=True))
    res, stats = eng.run([pa, pb])
    assert res[0].tolist() == _solo(params, cfg, pa, max_new, S)
    assert res[1].tolist() == _solo(params, cfg, pb, max_new, S)
    assert stats["cache_hit_tokens"] == 32   # exactly the 2 full blocks
    assert stats["cow_splits"] == 0          # divergent tail was fresh


def test_prefix_cache_spec_decode_combined(smollm):
    """Both tentpole features on at once: shared-prefix traffic decodes
    token-identical to vanilla continuous batching."""
    cfg, params = smollm
    rng = np.random.default_rng(8)
    shared = rng.integers(1, cfg.vocab, (32,)).astype(np.int32)
    prompts = [np.concatenate([shared, rng.integers(1, cfg.vocab, (k,)
                                                    ).astype(np.int32)])
               for k in (4, 9, 0)]
    max_new, S = 8, 80
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=2,
        prefix_cache=True, spec_decode=True, spec_k=3))
    res, stats = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, max_new, S), i
    assert eng._verify._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    assert stats["pages_shared"] > 0


def test_prefix_cache_eviction_reclaims_pool(smollm):
    """Cached pages are reclaimed (LRU) when the pool runs dry instead
    of blocking admissions or preempting running rows."""
    cfg, params = smollm
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, cfg.vocab, (24,)).astype(np.int32)
               for _ in range(4)]
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=4, page_size=8, max_seqs=1,
        n_pages=10, prefix_cache=True))        # 9 usable pages
    res, stats = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, 4, 32), i
    assert eng.sched.prefix.evictions > 0      # the pool really cycled
    assert stats["n_preemptions"] == 0         # eviction, not preemption


def test_preempt_before_prefill_never_registers_pages():
    """Regression: a row admitted and preempted within the same
    schedule() call was never prefilled — stashing its (never-blitted)
    pages would poison the index with garbage KV that its own
    readmission would then silently adopt."""
    pcfg = PagedCacheConfig(page_size=4, n_pages=8, max_seqs=2,
                            max_blocks=4)
    sched = Scheduler(pcfg, prefix_cache=True)
    sched.submit(Request(rid=0, tokens=np.arange(6, dtype=np.int32),
                         max_new=4))
    plan = sched.schedule()
    (seq,) = plan.admitted
    assert seq.emitted == []                  # not prefilled yet
    sched._preempt_youngest()                 # evicted before prefill
    assert len(sched.prefix) == 0             # nothing registered
    assert sched.prefix.lookup(seq.req.tokens)[1] == 0
    # whereas a prefilled producer's departure DOES stash its blocks
    plan = sched.schedule()
    (seq2,) = plan.admitted
    seq2.emitted = [1]                        # engine prefilled + decoded
    sched.complete(seq2)
    assert len(sched.prefix) > 0
    assert sched.prefix.lookup(seq2.req.tokens)[1] == 6


# ------------------------------------------------------- starvation guard --
def _drive(sched, steps, trace):
    """Drive the scheduler like the engine: one token per running row
    per step, completing rows at their max_new budget."""
    for _ in range(steps):
        plan = sched.schedule()
        for seq in plan.admitted:
            seq.emitted = [0]                 # prefill token
        for seq in list(sched.running.values()):
            seq.emitted.append(0)
            seq.length += 1
            if len(seq.emitted) >= seq.req.max_new:
                trace.append(("done", seq.rid))
                sched.complete(seq)
        trace.append(("step", [r for r in plan.preempted],
                      sorted(s.rid for s in sched.running.values())))


def test_starvation_guard_bounds_repeated_preemption():
    """Adversarial 3-seq trace: two old rows grow every step on a tiny
    pool; the young third used to be the perpetual LIFO victim (evicted,
    readmitted at the freed pages, evicted again...).  The preempt
    shield caps how often the same request can be bounced, after which
    an unshielded peer is chosen instead — so the victim is readmitted
    within a bounded number of steps AND keeps its slot long enough to
    finish."""
    pcfg = PagedCacheConfig(page_size=2, n_pages=14, max_seqs=3,
                            max_blocks=8)
    sched = Scheduler(pcfg, preempt_shield=2)
    # two page-hungry old rows + one late small row
    sched.submit(Request(rid=0, tokens=np.ones(4, np.int32), max_new=12))
    sched.submit(Request(rid=1, tokens=np.ones(4, np.int32), max_new=12))
    sched.submit(Request(rid=2, tokens=np.ones(2, np.int32), max_new=6))
    trace = []
    _drive(sched, steps=40, trace=trace)
    assert not sched.has_work                 # everyone finished
    assert ("done", 2) in trace
    # the shield bound held: rid 2 was never evicted more than twice
    assert sched_preempts(trace, 2) <= 2
    # and every eviction was followed by a readmission within 2 steps
    gap, waiting = 0, False
    for ev in trace:
        if ev[0] != "step":
            continue
        if 2 in ev[1]:
            waiting, gap = True, 0
        elif waiting:
            gap += 1
            if 2 in ev[2]:
                waiting = False
            assert gap <= 2, trace


def sched_preempts(trace, rid):
    return sum(ev[1].count(rid) for ev in trace if ev[0] == "step")


def test_admission_preference_never_starves_queue_head(smollm):
    """Cache-hit preference may reorder admissions, but the queue head
    is admitted within ``starvation_limit`` steps even while cache-hit
    requests keep arriving behind it."""
    cfg, params = smollm
    rng = np.random.default_rng(10)
    hot = rng.integers(1, cfg.vocab, (16,)).astype(np.int32)
    cold = rng.integers(1, cfg.vocab, (16,)).astype(np.int32)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=2, page_size=16, max_seqs=1,
        prefix_cache=True))
    eng.sched.starvation_limit = 3
    # seed the cache with the hot prompt, then queue: cold head + a
    # stream of hot (cache-hit) requests that would jump it forever
    eng.run([hot.copy()])
    rid_cold = eng.submit(cold.copy())
    for _ in range(4):
        eng.submit(hot.copy())
    admitted_at = {}
    step = 0
    while eng.sched.has_work:
        step += 1
        s = eng.step()
        for rid in s["admitted"]:
            admitted_at[rid] = step
    # hot requests jumped the cold head at first (preference works)...
    assert min(admitted_at[r] for r in admitted_at if r != rid_cold) < \
        admitted_at[rid_cold]
    # ...but the head was admitted within the starvation limit + 1
    assert admitted_at[rid_cold] <= eng.sched.starvation_limit + 2
    assert eng.results[rid_cold].tolist() == _solo(params, cfg, cold, 2, 32)
