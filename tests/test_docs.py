"""Docs stay runnable: the numerics page's doctests are tier-1.

``docs/numerics.md`` is written as doctest text (the CI docs-check step
runs ``python -m doctest`` on it directly); this test keeps it honest
under plain pytest too, and sanity-checks the cross-page links.
"""

import doctest
import pathlib
import re

DOCS = pathlib.Path(__file__).resolve().parent.parent / "docs"


def test_numerics_doctests():
    results = doctest.testfile(
        str(DOCS / "numerics.md"), module_relative=False, verbose=False)
    assert results.attempted >= 20, "numerics.md lost its examples"
    assert results.failed == 0


def test_kernels_doctests():
    results = doctest.testfile(
        str(DOCS / "kernels.md"), module_relative=False, verbose=False)
    assert results.attempted >= 10, "kernels.md lost its examples"
    assert results.failed == 0


def test_serving_doctests():
    """The prefix-cache index and the speculative accept rule are taught
    as runnable examples (no model build — host-side machinery only)."""
    results = doctest.testfile(
        str(DOCS / "serving.md"), module_relative=False, verbose=False)
    assert results.attempted >= 12, "serving.md lost its examples"
    assert results.failed == 0


def test_architecture_doctests():
    """The resident-weight pipeline (encode once, serve forever) is
    taught as runnable examples on the architecture page."""
    results = doctest.testfile(
        str(DOCS / "architecture.md"), module_relative=False, verbose=False)
    assert results.attempted >= 8, "architecture.md lost its examples"
    assert results.failed == 0


def test_analysis_doctests():
    """The static auditor and linter are taught as runnable examples
    (audit_fn proofs, headroom reading, lint suppression)."""
    results = doctest.testfile(
        str(DOCS / "analysis.md"), module_relative=False, verbose=False)
    assert results.attempted >= 15, "analysis.md lost its examples"
    assert results.failed == 0


def test_analysis_cross_linked():
    """The ledger pages point at the static pass that re-proves them."""
    for page in ("numerics.md", "architecture.md"):
        assert "analysis.md" in (DOCS / page).read_text(), page


def test_architecture_references_real_resident_symbols():
    from repro.models.resident import (  # noqa: F401
        attach_resident,
        encode_resident,
        has_resident,
        strip_resident,
    )
    from repro.serve.engine import ServeConfig

    text = (DOCS / "architecture.md").read_text()
    for name in ("encode_resident", "resident_weights", "w_res",
                 "rns_resident_dot", "per_layer_profiles",
                 "narrowest_profile"):
        assert name in text, name
    assert ServeConfig(resident_weights=True,
                       per_layer_profiles=True).resident_weights


def test_docs_cross_links_resolve():
    for page in DOCS.glob("*.md"):
        text = page.read_text()
        for target in re.findall(r"\]\(([a-z_]+\.md)\)", text):
            assert (DOCS / target).exists(), f"{page.name} -> {target}"


def test_docs_reference_real_symbols():
    """Spot-check that the API names the serving doc teaches exist."""
    from repro.serve.engine import ContinuousEngine, ServeConfig
    from repro.serve.kv_cache import PagedCacheConfig, gather_pages
    from repro.serve.scheduler import Scheduler

    text = (DOCS / "serving.md").read_text()
    for name in ("ContinuousEngine", "ServeConfig", "submit", "step",
                 "rns_ops", "page_size", "max_seqs", "gather_pages",
                 "prefix_cache", "spec_decode", "PrefixCache",
                 "copy_pages", "tokens_per_step", "acceptance_rate"):
        assert name in text, name
    assert {ContinuousEngine, ServeConfig, PagedCacheConfig, Scheduler,
            gather_pages}
    # the knobs/stats the doc teaches actually exist
    scfg = ServeConfig(prefix_cache=True, spec_decode=True, spec_k=2)
    assert scfg.spec_ngram >= 1
    from repro.serve.kv_cache import PrefixCache, copy_pages  # noqa: F401
