"""Quantization grids: mask-aware per-sequence scales + degenerate inputs.

Two PR-3 bugfixes, pinned:

* ``absmax_scale`` with a token mask (explicit or via the ``token_mask``
  context) computes each row's scale over its REAL tokens only — the fix
  that makes padded ragged prefill bit-exact on the RNS path (the
  engine-level assertion lives in tests/test_serve_continuous.py).
* an all-zero input used to get scale ``qmax/eps ~ 9e15``; chained
  blocks then overflow the float32 scale product.  Zero absmax now maps
  to scale 1.0 (zero encodes exactly at any scale), and a chain of
  all-zero blocks decodes to exact zeros with finite scales and no
  spurious slow ops.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, st

from repro.core import dispatch
from repro.core.quantize import absmax_scale, quantize_with_scale, token_mask
from repro.core.tensor import rt_decode, rt_encode, rt_matmul, rt_mul


class TestMaskedScale:
    def test_per_row_scale_matches_solo(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 5, 8)), jnp.float32)
        mask = jnp.asarray([[1, 1, 1, 0, 0], [1, 1, 1, 1, 1]], bool)
        s = absmax_scale(x, 8, mask=mask)
        assert s.shape == (2, 1, 1)      # per-sequence, broadcastable
        # row 0's grid ignores its pad tail; row 1 is fully real
        assert jnp.isclose(s[0, 0, 0], absmax_scale(x[0, :3], 8))
        assert jnp.isclose(s[1, 0, 0], absmax_scale(x[1], 8))

    def test_pad_garbage_cannot_move_a_real_rows_grid(self):
        rng = np.random.default_rng(1)
        x = np.asarray(rng.standard_normal((1, 4, 8)), np.float32)
        xpad = np.concatenate(
            [x, 1e6 * np.ones((1, 3, 8), np.float32)], axis=1)
        mask = jnp.asarray([[1, 1, 1, 1, 0, 0, 0]], bool)
        s_solo = absmax_scale(jnp.asarray(x), 8)
        s_pad = absmax_scale(jnp.asarray(xpad), 8, mask=mask)
        assert jnp.isclose(s_pad[0, 0, 0], s_solo)
        # and the quantized REAL tokens are bit-identical to the solo run
        q_solo = quantize_with_scale(jnp.asarray(x), s_solo, 8)
        q_pad = quantize_with_scale(jnp.asarray(xpad), s_pad, 8)[:, :4]
        assert np.array_equal(np.asarray(q_solo), np.asarray(q_pad))

    def test_context_applies_only_to_matching_activations(self):
        rng = np.random.default_rng(2)
        act = jnp.asarray(rng.standard_normal((2, 3, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        mask = jnp.ones((2, 3), bool)
        with token_mask(mask):
            s_act = absmax_scale(act, 8)
            s_w = absmax_scale(w, 8)
        assert s_act.shape == (2, 1, 1)           # activation: per-row
        assert s_w.shape == ()                    # weight: per-tensor
        assert jnp.isclose(s_w, absmax_scale(w, 8))

    def test_context_is_trace_compatible(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((2, 4, 8)), jnp.float32)

        @jax.jit
        def f(x, lengths):
            m = jnp.arange(x.shape[1])[None, :] < lengths[:, None]
            with token_mask(m):
                return absmax_scale(x, 8)

        s = f(x, jnp.asarray([2, 4], jnp.int32))
        assert jnp.isclose(s[0, 0, 0], absmax_scale(x[0, :2], 8))
        assert jnp.isclose(s[1, 0, 0], absmax_scale(x[1], 8))

    def test_fully_masked_row_gets_clamped_scale(self):
        x = jnp.asarray(np.ones((2, 3, 4), np.float32))
        mask = jnp.asarray([[0, 0, 0], [1, 1, 1]], bool)
        s = absmax_scale(x, 8, mask=mask)
        assert float(s[0, 0, 0]) == 1.0           # inactive slot: clamped
        assert jnp.isfinite(s).all()


class TestZeroInputClamp:
    def test_zero_tensor_scale_is_one(self):
        assert float(absmax_scale(jnp.zeros((4, 4)), 8)) == 1.0
        assert float(absmax_scale(jnp.zeros((4, 4)), 16)) == 1.0
        # nonzero inputs keep the absmax grid
        assert float(absmax_scale(jnp.full((2,), 0.5), 8)) == \
            pytest.approx(127 / 0.5)

    def test_sub_eps_block_flushes_to_unit_grid(self):
        # absmax in (0, eps) must not get the ~qmax/eps overflow grid:
        # the whole sub-eps range is the denormal floor, not just 0.0
        s = absmax_scale(jnp.full((4,), 1e-30), 14)
        assert float(s) == 1.0
        s = absmax_scale(jnp.full((4,), 1e-13), 14)      # just below eps
        assert float(s) == 1.0
        s = absmax_scale(jnp.full((4,), 1e-11), 14)      # just above eps
        assert float(s) == pytest.approx((2**13 - 1) / 1e-11, rel=1e-5)

    def test_all_zero_chain_three_deep_14bit(self):
        # deterministic instance of the property below (hypothesis is an
        # optional extra; this one always runs): depth 3 on a 14-bit grid
        # is the regime whose unclamped scales overflowed float32
        self._check_zero_chain(depth=3, bits=14)

    @given(st.integers(min_value=2, max_value=4),
           st.integers(min_value=8, max_value=14))
    def test_all_zero_chain_never_overflows(self, depth, bits):
        """Property: a chain of all-zero blocks keeps finite scales,
        decodes to exact zeros, and pays no spurious slow ops beyond
        what the (static) magnitude ledger already requires."""
        self._check_zero_chain(depth, bits)

    def _check_zero_chain(self, depth, bits):
        z = jnp.zeros((2, 8), jnp.float32)
        wz = jnp.zeros((8, 8), jnp.float32)
        with dispatch.count_ops() as c:
            t = rt_encode(z, "rns9", bits=bits)
            for _ in range(depth):
                t = rt_matmul(t, rt_encode(wz, "rns9", bits=bits))
            t = rt_mul(t, rt_encode(z, "rns9", bits=bits))
            y = rt_decode(t)
        assert np.array_equal(np.asarray(y), np.zeros((2, 8), np.float32))
        assert np.isfinite(float(t.scale))        # used to hit f32 inf
        assert float(t.scale) == 1.0              # clamped grids multiply to 1
        # ledger-scheduled ops only: any mid-chain renormalizes are the
        # static bits-driven ones; they must match a NONZERO run of the
        # same shape/bits (i.e. values never force extra slow ops)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        with dispatch.count_ops() as c_ref:
            t2 = rt_encode(x, "rns9", bits=bits)
            for _ in range(depth):
                t2 = rt_matmul(t2, rt_encode(w, "rns9", bits=bits))
            t2 = rt_mul(t2, rt_encode(x, "rns9", bits=bits))
            rt_decode(t2)
        assert c.normalizes == c_ref.normalizes
        assert c.matmuls == c_ref.matmuls
