"""Optimizer, data pipeline, trainer loop, checkpoint/restart, serving."""

import logging
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import get_config
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    schedule_lr,
)
from repro.train.trainer import Trainer, TrainerConfig

logging.disable(logging.INFO)


# ------------------------------------------------------------- optimizer ---
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, schedule="constant")
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip():
    g = {"a": jnp.asarray([30.0, 40.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 50.0) < 1e-4
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_ratio=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 55, 100]]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < lrs[2] and abs(lrs[4] - 0.1) < 1e-3


# ------------------------------------------------------------------ data ---
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=7)
    d0 = SyntheticLM(cfg)
    b1, b2 = d0.batch(5), d0.batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d0.batch(5)["tokens"], d0.batch(6)["tokens"])
    h0 = SyntheticLM(cfg, host_id=0, n_hosts=2)
    h1 = SyntheticLM(cfg, host_id=1, n_hosts=2)
    assert h0.batch(3)["tokens"].shape == (4, 32)
    assert not np.array_equal(h0.batch(3)["tokens"], h1.batch(3)["tokens"])


# ------------------------------------------------------------ checkpoint ---
def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "n": {"b": jnp.ones((3, 4), jnp.bfloat16), "step": jnp.int32(7)}}
    d = ckpt.save(str(tmp_path), 42, tree, extra={"tag": "x"})
    assert os.path.basename(d) == "step_000000042"
    assert not any(f.startswith(".tmp") for f in os.listdir(tmp_path))
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back, extra, step = ckpt.restore(d, like)
    assert step == 42 and extra == {"tag": "x"}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_latest_valid_skips_corrupt(tmp_path):
    tree = {"w": jnp.arange(100, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 1, tree)
    d2 = ckpt.save(str(tmp_path), 2, tree)
    ckpt.corrupt_for_test(d2)
    latest = ckpt.latest_valid(str(tmp_path))
    assert latest.endswith("step_000000001")


def test_async_checkpoint(tmp_path):
    tree = {"w": jnp.ones((64,))}
    fut = ckpt.save_async(str(tmp_path), 3, tree)
    fut.result()
    assert ckpt.latest_valid(str(tmp_path)).endswith("step_000000003")


def test_elastic_restore_different_sharding(tmp_path):
    """Consolidated leaves restore onto any device layout (elastic)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    d = ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    back, _, _ = ckpt.restore(d, tree, shardings=sh)
    assert back["w"].sharding == sh["w"]
    assert np.array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


# ----------------------------------------------------------- trainer/e2e ---
def _mk_trainer(tmpdir, total_steps, arch="smollm-135m"):
    cfg = get_config(arch, smoke=True)
    return Trainer(
        cfg,
        AdamWConfig(lr=8e-3, warmup_steps=5, total_steps=200,
                    weight_decay=0.0),
        TrainerConfig(total_steps=total_steps, ckpt_every=10,
                      ckpt_dir=tmpdir, log_every=100, async_ckpt=False),
        DataConfig(vocab=get_config(arch, smoke=True).vocab, seq_len=64,
                   global_batch=8, branch=4, noise=0.05))


def test_loss_decreases(tmp_path):
    tr = _mk_trainer(str(tmp_path), 40)
    _, hist = tr.run()
    assert hist[-1] < hist[0] - 0.3, (hist[0], hist[-1])


def test_restart_is_bitwise_resumable(tmp_path):
    """20 straight steps == 10 steps + crash + resume + 10 steps."""
    t_straight = _mk_trainer(str(tmp_path / "a"), 20)
    state_a, hist_a = t_straight.run()

    t1 = _mk_trainer(str(tmp_path / "b"), 10)
    t1.run()
    t2 = _mk_trainer(str(tmp_path / "b"), 20)  # resumes at step 10
    state_b, hist_b = t2.run()
    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_preemption_checkpoints_and_exits(tmp_path):
    tr = _mk_trainer(str(tmp_path), 50)
    tr.preempt.trigger_for_test()
    _, hist = tr.run()
    assert len(hist) == 1  # stopped immediately after one step
    assert ckpt.latest_valid(str(tmp_path)) is not None


# --------------------------------------------------------------- serving ---
def test_engine_matches_stepwise_reference():
    from repro.serve.engine import Engine, ServeConfig
    from repro.models import model as M

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, ServeConfig(max_cache=64, max_new_tokens=6))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (3, 12)).astype(np.int32)
    out = eng.generate(prompts)
    # reference: full forward re-run per step
    toks = jnp.asarray(prompts)
    ref = []
    for _ in range(6):
        logits, _ = M.forward_train(params, cfg, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        ref.append(nxt)
        toks = jnp.concatenate([toks, nxt], axis=1)
    ref = np.asarray(jnp.concatenate(ref, axis=1))
    assert np.array_equal(out, ref)


def test_compressed_gradient_training_converges():
    """int8+EF gradient compression (the cross-pod hop) keeps convergence."""
    import jax
    import jax.numpy as jnp

    from repro.data.pipeline import SyntheticLM
    from repro.train.train_step import init_train_state, make_train_step

    cfg = get_config("smollm-135m", smoke=True)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(
        cfg, AdamWConfig(lr=8e-3, warmup_steps=5, total_steps=100,
                         weight_decay=0.0), compress_dci=True))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=64,
                                  global_batch=8, branch=4, noise=0.05))
    losses = []
    for i in range(25):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch(i).items()})
        losses.append(float(m["loss"]))
    assert "ef" in state
    assert losses[-1] < losses[0] - 0.3
