"""Per-arch smoke: reduced config of the same family, one train step on CPU,
shape + finiteness asserts; prefill/decode == full forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models import model as M

ARCHS = list_archs()


def _batch(cfg, rng, B=2, T=16, extra=0):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T + extra)), jnp.int32)
    batch = {"tokens": toks[:, :T]}
    if cfg.frontend:
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)),
            jnp.float32)
    return batch, toks


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params, specs = M.init_model(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple))
    rng = np.random.default_rng(0)
    batch, _ = _batch(cfg, rng)
    logits, aux = M.forward_train(params, cfg, batch)
    F = cfg.n_frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0
    assert logits.shape == (2, 16 + F, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    (loss, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_match_full_forward(arch):
    cfg = get_config(arch, smoke=True)
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    B, T = 2, 16
    F = cfg.n_frontend_tokens if (cfg.frontend and not cfg.enc_dec) else 0
    batch, toks = _batch(cfg, rng, B, T, extra=1)
    full = dict(batch, tokens=toks)
    logits_full, _ = M.forward_train(params, cfg, full)
    last_prefill, cache = M.prefill(params, cfg, batch, S_max=T + F + 8,
                                    cache_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(last_prefill), np.asarray(logits_full[:, -2]), atol=2e-3)
    logits_dec, cache = M.decode_step(params, cfg, toks[:, T:T + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full[:, -1]), atol=2e-3)
    # a second step keeps the cache consistent (no shape/type drift)
    logits2, cache2 = M.decode_step(params, cfg, toks[:, T:T + 1], cache)
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["smollm-135m", "rwkv6-7b", "jamba-v0.1-52b"])
def test_rns_datapath_trains(arch):
    """The paper's technique as a first-class feature: MLPs through RNS."""
    import dataclasses

    from repro.core.rns_matmul import RnsDotConfig

    cfg = dataclasses.replace(
        get_config(arch, smoke=True),
        rns=RnsDotConfig(profile="rns9", qx=14, qw=14), rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    batch, _ = _batch(cfg, rng, T=8)
    (loss, _), grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_full_configs_construct_and_count():
    """Exact assigned configs: param counts in the advertised ballparks."""
    expect = {
        "smollm-135m": (0.10e9, 0.2e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "granite-3-8b": (6e9, 9e9),
        "qwen2.5-32b": (28e9, 36e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "llama4-scout-17b-a16e": (90e9, 120e9),   # 16 experts x 48L total
        "deepseek-v2-236b": (200e9, 260e9),
        "rwkv6-7b": (6e9, 9e9),
        "paligemma-3b": (2e9, 3.5e9),
        "whisper-medium": (0.6e9, 1.0e9),  # 769M (24+24 layers)
    }
    for arch, (lo, hi) in expect.items():
        cfg = get_config(arch)
        total, active = M.count_params(cfg)
        assert lo <= total <= hi, (arch, total)
        assert active <= total
