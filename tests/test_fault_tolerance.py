"""Straggler detection, heartbeats, elastic planning, gradient compression."""

import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    compress_int8,
    decompress_int8,
    decompress_tree,
    ef_compress_tree,
)
from repro.distributed.fault_tolerance import (
    Heartbeat,
    StragglerMonitor,
    plan_remesh,
)


def test_straggler_detection():
    mon = StragglerMonitor(tau=1.5)
    for step in range(8):
        for h in range(8):
            mon.report(f"host{h}", 1.0 if h != 3 else 2.5)
    assert mon.stragglers() == ["host3"]
    plan = mon.mitigation_plan()
    assert plan["action"] == "checkpoint_and_evict"
    assert "host3" in plan["stragglers"] and "host0" in plan["healthy"]


def test_heartbeat_dead_host(tmp_path):
    hb0 = Heartbeat(str(tmp_path), "h0")
    hb1 = Heartbeat(str(tmp_path), "h1")
    hb0.beat(1, now=1000.0)
    hb1.beat(1, now=1060.0)
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=30, now=1065.0) == ["h0"]
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=120, now=1065.0) == []


def test_plan_remesh():
    assert plan_remesh(512) == (32, 16)
    assert plan_remesh(496) == (31, 16)   # one host of 16 chips lost
    assert plan_remesh(8, model_parallel=16) == (1, 16)


def test_int8_compression_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000), jnp.float32)
    q, s = compress_int8(x)
    err = np.max(np.abs(np.asarray(decompress_int8(q, s) - x)))
    assert err <= float(s) * 0.5 + 1e-7


def test_error_feedback_is_unbiased_over_time():
    """EF: sum of decompressed grads converges to sum of true grads."""
    rng = np.random.default_rng(1)
    grads = [{"w": jnp.asarray(rng.standard_normal(64), jnp.float32)}
             for _ in range(30)]
    err_state = None
    acc_comp = np.zeros(64)
    acc_true = np.zeros(64)
    for g in grads:
        qtree, err_state = ef_compress_tree(g, err_state)
        dec = decompress_tree(qtree)
        acc_comp += np.asarray(dec["w"])
        acc_true += np.asarray(g["w"])
    # residual is bounded by the final error state, not accumulated
    resid = np.max(np.abs(acc_comp - acc_true))
    assert resid <= np.max(np.abs(np.asarray(err_state["w"]))) + 1e-5
