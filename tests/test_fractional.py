"""Olsen fractional RNS vs exact Fraction oracle."""

from fractions import Fraction

import jax.numpy as jnp
import numpy as np
from _hypothesis_stub import given, st

from repro.core import fractional as fr
from repro.core.moduli import get_profile

P = get_profile("rns9")
EPS = 1.0 / P.M_f

floats = st.floats(-100, 100, allow_nan=False, width=32)


@given(st.lists(floats, min_size=1, max_size=16))
def test_encode_decode(xs):
    r = fr.fr_encode(P, np.asarray(xs, np.float32))
    out = np.asarray(fr.fr_decode(P, r))
    np.testing.assert_allclose(out, xs, atol=EPS * (1 + np.abs(xs).max()),
                               rtol=1e-5)


@given(st.lists(st.floats(-50, 50, allow_nan=False, width=32), min_size=1,
                max_size=8),
       st.lists(st.floats(-50, 50, allow_nan=False, width=32), min_size=1,
                max_size=8))
def test_fr_mul_error_bound(xs, ys):
    n = min(len(xs), len(ys))
    xs, ys = np.asarray(xs[:n], np.float32), np.asarray(ys[:n], np.float32)
    fx, fy = fr.fr_encode(P, xs), fr.fr_encode(P, ys)
    fz = fr.fr_mul(P, fx, fy)
    # oracle: quantized ints, exact product, round-half-away-from-zero /M_f
    # (scale_signed rounds the magnitude with a +M_f/2 bias)
    def rhaz(v):
        s = -1 if v < 0 else 1
        return s * ((abs(v) + P.M_f // 2) // P.M_f)

    # mirror fr_encode's float32 arithmetic exactly (f64 rounding can land
    # on a different integer near ties)
    def q32(v):
        return int(np.round(np.float32(v) * np.float32(P.M_f)))

    qx = [q32(v) for v in xs]
    qy = [q32(v) for v in ys]
    want = [rhaz(a * b) for a, b in zip(qx, qy)]
    got = fr.fr_decode_exact(P, np.asarray(fz))
    for g, w in zip(got, want):
        assert g == Fraction(int(w), P.M_f)


def test_deferred_dot_exact_and_single_normalization():
    rng = np.random.default_rng(0)
    n = 64
    xs = rng.uniform(-1, 1, (n, 8)).astype(np.float32)
    ys = rng.uniform(-1, 1, (n, 8)).astype(np.float32)
    fxs = jnp.stack([fr.fr_encode(P, xs[i]) for i in range(n)])
    fys = jnp.stack([fr.fr_encode(P, ys[i]) for i in range(n)])
    out = np.asarray(fr.fr_decode(P, fr.fr_dot_deferred(P, fxs, fys)))
    # oracle on quantized values
    qx = np.round(xs * P.M_f)
    qy = np.round(ys * P.M_f)
    want = (qx.astype(object) * qy.astype(object)).sum(0)
    want = np.asarray([round(Fraction(int(w), P.M_f * P.M_f) * P.M_f) / P.M_f
                       for w in want])
    np.testing.assert_allclose(out, want.astype(np.float64), atol=2 * EPS)


@given(st.lists(floats, min_size=1, max_size=8), st.floats(-90, 90, width=32))
def test_fr_compare(xs, c):
    r = fr.fr_encode(P, np.asarray(xs, np.float32))
    got = np.asarray(fr.fr_ge_const(P, r, float(c)))
    qc = round(Fraction(float(c)) * P.M_f)
    for g, x in zip(got, xs):
        qx = int(np.round(np.float32(x) * np.float32(P.M_f)))
        assert bool(g) == (qx >= qc)


def test_mandelbrot_iteration_matches_float64():
    """The paper's Rez-9 demo: sustained iterative fractional RNS compute."""
    p = get_profile("rns12")
    grid = 8
    xs = np.linspace(-2.0, 0.6, grid)
    ys = np.linspace(-1.2, 1.2, grid)
    cr = np.repeat(xs, grid).astype(np.float32)
    ci = np.tile(ys, grid).astype(np.float32)
    # RNS iteration
    zr, zi = fr.fr_encode(p, np.zeros_like(cr)), fr.fr_encode(p, np.zeros_like(ci))
    fcr, fci = fr.fr_encode(p, cr), fr.fr_encode(p, ci)
    esc_rns = np.full(cr.shape, 99, np.int32)
    zr64 = np.zeros_like(cr, np.float64)
    zi64 = np.zeros_like(ci, np.float64)
    esc_f64 = np.full(cr.shape, 99, np.int32)
    for it in range(20):
        # RNS: z = z^2 + c with deferred normalization per term
        rr = fr.fr_mul_raw(p, zr, zr)
        ii = fr.fr_mul_raw(p, zi, zi)
        ri = fr.fr_mul_raw(p, zr, zi)
        mag_raw = fr.fr_add(p, rr, ii)
        escaped = np.asarray(fr.fr_ge_const(p, mag_raw, 4.0, raw=True))
        esc_rns = np.where((esc_rns == 99) & escaped, it, esc_rns)
        new_zr = fr.fr_add(p, fr.fr_normalize(p, fr.fr_sub(p, rr, ii)), fcr)
        two_ri = fr.fr_add(p, ri, ri)
        new_zi = fr.fr_add(p, fr.fr_normalize(p, two_ri), fci)
        zr, zi = new_zr, new_zi
        # float64 reference
        mag = zr64 * zr64 + zi64 * zi64
        esc_f64 = np.where((esc_f64 == 99) & (mag >= 4.0), it, esc_f64)
        zr64, zi64 = zr64 * zr64 - zi64 * zi64 + cr, 2 * zr64 * zi64 + ci
    # escape iterations agree except at numerical boundaries
    agree = np.mean(esc_rns == esc_f64)
    assert agree > 0.9, (esc_rns, esc_f64)
