"""SSM recurrences (mamba, rwkv6) + MoE dispatch invariants."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, init_moe, moe_ffn
from repro.models.ssm import (
    SSMConfig,
    init_mamba,
    init_rwkv6,
    mamba_seq,
    rwkv6_channelmix,
    rwkv6_timemix,
)


def test_mamba_seq_equals_stepwise():
    cfg = SSMConfig(kind="mamba", d_state=4, d_conv=4, expand=2, chunk=8)
    d = 16
    p, _ = init_mamba(jax.random.PRNGKey(0), d, cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 21, d)), jnp.float32)
    y_seq, (h_last, tail) = mamba_seq(p, x, cfg)
    # step one token at a time
    state = None
    outs = []
    for t in range(x.shape[1]):
        y, state = mamba_seq(p, x[:, t:t + 1], cfg,
                             h0=None if state is None else state[0],
                             conv0=None if state is None else state[1])
        outs.append(y)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_step),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(state[0]),
                               atol=2e-5)


def test_mamba_state_continuation():
    cfg = SSMConfig(kind="mamba", d_state=4, d_conv=4, expand=2, chunk=4)
    d = 8
    p, _ = init_mamba(jax.random.PRNGKey(1), d, cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 24, d)), jnp.float32)
    y_full, _ = mamba_seq(p, x, cfg)
    y1, st = mamba_seq(p, x[:, :11], cfg)
    y2, _ = mamba_seq(p, x[:, 11:], cfg, h0=st[0], conv0=st[1])
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full),
        atol=2e-5)


def test_rwkv_seq_equals_stepwise():
    cfg = SSMConfig(kind="rwkv6", head_dim=8, chunk=8)
    d = 16
    p, _ = init_rwkv6(jax.random.PRNGKey(0), d, cfg, d_ff=32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((2, 19, d)), jnp.float32)
    y_seq, (S_last, x_last) = rwkv6_timemix(p, x, cfg)
    state = None
    outs = []
    for t in range(x.shape[1]):
        y, state = rwkv6_timemix(p, x[:, t:t + 1], cfg, state=state)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_seq), atol=3e-4)
    np.testing.assert_allclose(np.asarray(S_last), np.asarray(state[0]),
                               atol=3e-4)


def test_rwkv_channelmix_stepwise():
    cfg = SSMConfig(kind="rwkv6", head_dim=8)
    d = 16
    p, _ = init_rwkv6(jax.random.PRNGKey(0), d, cfg, d_ff=32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 9, d)), jnp.float32)
    y_seq, _ = rwkv6_channelmix(p, x)
    state, outs = None, []
    for t in range(x.shape[1]):
        y, state = rwkv6_channelmix(p, x[:, t:t + 1], state=state)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_seq), atol=2e-5)


def test_moe_dropless_equals_dense_expert_loop():
    """With capacity >= S*k/E guaranteed, dispatch must equal the explicit
    per-token expert loop (the semantics oracle)."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=32, n_shared=0,
                    capacity_factor=8.0, aux_loss_weight=0.0)
    d = 16
    p, _ = init_moe(jax.random.PRNGKey(0), d, cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, 12, d)), jnp.float32)
    y, aux = moe_ffn(p, x, cfg)

    # oracle
    logits = np.asarray(x @ p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_p, top_i = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    want = np.zeros_like(np.asarray(x))
    for b in range(2):
        for s in range(12):
            acc = 0
            for j in range(2):
                e = int(top_i[b, s, j])
                h_in = np.asarray(x[b, s]) @ np.asarray(p["wi"][e])
                h_g = np.asarray(x[b, s]) @ np.asarray(p["wg"][e])
                h = np.asarray(jax.nn.silu(jnp.asarray(h_g))) * h_in
                acc = acc + float(top_p[b, s, j]) * (h @ np.asarray(p["wo"][e]))
            want[b, s] = acc
    np.testing.assert_allclose(np.asarray(y), want, atol=2e-4)


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=2, top_k=1, d_ff_expert=8, capacity_factor=0.5,
                    aux_loss_weight=0.0)
    d = 4
    p, _ = init_moe(jax.random.PRNGKey(1), d, cfg)
    x = jnp.ones((1, 16, d), jnp.float32)  # all tokens route identically
    y, _ = moe_ffn(p, x, cfg)
    # capacity = 16*1/2*0.5 = 4 slots -> at most 8 of 16 token outputs nonzero
    nonzero = int(jnp.sum(jnp.any(jnp.abs(y) > 1e-9, axis=-1)))
    assert nonzero <= 8
