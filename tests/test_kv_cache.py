"""Paged KV cache: allocator, gather/scatter, bit-for-bit vs dense."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.models.attention import decode_attention
from repro.serve.kv_cache import (
    TRASH_PAGE,
    PageAllocator,
    PagedCacheConfig,
    gather_pages,
    write_prompt_pages,
    write_token,
)


def test_allocator_alloc_free_utilization():
    a = PageAllocator(9)            # 8 usable, page 0 reserved
    assert a.n_free == 8 and a.utilization == 0.0
    got = a.alloc(3)
    assert len(got) == 3 and TRASH_PAGE not in got
    assert a.utilization == pytest.approx(3 / 8)
    assert a.alloc(6) is None       # not enough: no partial allocation
    assert a.n_free == 5
    a.free(got)
    assert a.n_free == 8
    with pytest.raises(ValueError):
        a.free([TRASH_PAGE])        # trash page is never allocatable
    with pytest.raises(ValueError):
        a.free([a.n_pages])         # out of range is a real bug: raises


def test_allocator_free_is_idempotent():
    """Preempt-then-complete may release the same pages twice in one
    engine step; the free list must not grow duplicates (a duplicate
    would hand one physical page to two sequences)."""
    a = PageAllocator(9)
    got = a.alloc(3)
    a.free(got)
    a.free(got)                     # second release: silent no-op
    assert a.n_free == 8
    assert sorted(a._free) == list(range(1, 9))   # no duplicates
    # a page re-allocated after the double release is handed out once
    again = a.alloc(8)
    assert sorted(again) == list(range(1, 9))
    assert a.alloc(1) is None


def test_paged_config_validates():
    with pytest.raises(ValueError):
        PagedCacheConfig(page_size=4, n_pages=3, max_seqs=1, max_blocks=4)


def _paged_from_dense(dense, bs, rng):
    """Scatter a dense [R, S, ...] cache into a shuffled page pool."""
    R, S = dense.shape[:2]
    nb = S // bs
    perm = rng.permutation(np.arange(1, 1 + R * nb))
    bt = perm.reshape(R, nb).astype(np.int32)
    pages = np.zeros((1 + R * nb, bs) + dense.shape[2:], dense.dtype)
    for r in range(R):
        for b in range(nb):
            pages[bt[r, b]] = dense[r, b * bs:(b + 1) * bs]
    return jnp.asarray(pages), jnp.asarray(bt)


def test_gather_pages_equals_dense_bitwise():
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((3, 32, 2, 4)).astype(np.float32)
    pages, bt = _paged_from_dense(dense, bs=8, rng=rng)
    out = np.asarray(gather_pages(pages, bt))
    assert out.shape == dense.shape
    assert (out == dense).all()     # bit-for-bit


def test_paged_attention_read_equals_dense_bitwise():
    """The acceptance gate: block-table gather feeding decode attention
    produces bit-identical output to the dense-cache read."""
    rng = np.random.default_rng(1)
    R, S, Hk, D, G = 4, 64, 2, 16, 3
    dense_k = rng.standard_normal((R, S, Hk, D)).astype(np.float32)
    dense_v = rng.standard_normal((R, S, Hk, D)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((R, 1, Hk * G, D)), jnp.float32)
    lengths = jnp.asarray([7, 33, 60, 1], jnp.int32)
    kp, bt = _paged_from_dense(dense_k, bs=16, rng=rng)
    # v pages must share k's block table: scatter v along the same mapping
    btn = np.asarray(bt)
    vpages = np.zeros((1 + R * (S // 16), 16, Hk, D), np.float32)
    for r in range(R):
        for b in range(S // 16):
            vpages[btn[r, b]] = dense_v[r, b * 16:(b + 1) * 16]
    vp = jnp.asarray(vpages)
    out_d, lse_d = decode_attention(q, jnp.asarray(dense_k),
                                    jnp.asarray(dense_v), lengths)
    out_p, lse_p = decode_attention(q, gather_pages(kp, bt),
                                    gather_pages(vp, bt), lengths)
    assert (np.asarray(out_d) == np.asarray(out_p)).all()
    assert (np.asarray(lse_d) == np.asarray(lse_p)).all()


def test_write_token_lands_at_length():
    rng = np.random.default_rng(2)
    R, nb, bs = 3, 2, 4
    pages = jnp.zeros((1 + R * nb, bs, 2), jnp.float32)
    bt = jnp.asarray(np.arange(1, 1 + R * nb).reshape(R, nb), jnp.int32)
    lengths = jnp.asarray([0, 3, 5], jnp.int32)     # row 2 in block 1
    vals = jnp.asarray(rng.standard_normal((R, 2)), jnp.float32)
    pages = write_token(pages, bt, lengths, vals)
    dense = np.asarray(gather_pages(pages, bt))     # [R, nb*bs, 2]
    for r, t in enumerate([0, 3, 5]):
        assert (dense[r, t] == np.asarray(vals)[r]).all()
        mask = np.ones(nb * bs, bool)
        mask[t] = False
        assert (dense[r, mask] == 0).all()          # nothing else touched


def test_write_prompt_pages_blits_and_trash_pads():
    rng = np.random.default_rng(3)
    npr, P, bs, d = 2, 6, 4, 3
    pages = jnp.zeros((npr, P, bs, d), jnp.float32)
    planes = jnp.asarray(rng.standard_normal((npr, 1, 8, d)), jnp.float32)
    block_row = jnp.asarray([2, 5], jnp.int32)
    pages = write_prompt_pages(pages, block_row, planes)
    got = np.asarray(pages)
    want = np.asarray(planes).reshape(npr, 2, bs, d)
    assert (got[:, 2] == want[:, 0]).all()
    assert (got[:, 5] == want[:, 1]).all()
    assert (got[:, 1] == 0).all() and (got[:, 3] == 0).all()
    # unused logical blocks redirect to trash: in-bounds, harmless
    trash_row = jnp.asarray([1, TRASH_PAGE], jnp.int32)
    pages2 = write_prompt_pages(pages, trash_row, planes)
    assert (np.asarray(pages2)[:, 1] == want[:, 0]).all()
