"""Continuous-batching engine: exactness, compile stability, policy.

The load-bearing claims, executed:
  * mixed-length traffic decodes through ONE jitted step (zero
    per-length recompiles) and yields tokens identical to per-request
    solo runs through the bucketed engine;
  * eviction + readmission (recompute preemption) preserves per-row
    results;
  * finished rows free their pages the same step;
  * the RNS execution policy threads through (per-step structural op
    counts);
  * the eos_id sentinel is validated.
"""

import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, Engine, ServeConfig


def _params(cfg, seed=0):
    return M.init_model(jax.random.PRNGKey(seed), cfg)[0]


def _solo(params, cfg, prompt, max_new, max_cache):
    eng = Engine(params, cfg, ServeConfig(max_cache=max_cache,
                                          max_new_tokens=max_new))
    return eng.generate(prompt[None])[0].tolist()


@pytest.fixture(scope="module")
def smollm():
    cfg = get_config("smollm-135m", smoke=True)
    return cfg, _params(cfg)


def test_mixed_lengths_match_solo_one_compile(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (7, 33, 120)]
    max_new, S = 8, 160
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=4))
    res, stats = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, max_new, S), i
    # zero per-length recompiles: one decode cell, one prefill cell
    assert eng._decode._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    assert stats["n_preemptions"] == 0
    assert stats["total_new_tokens"] == 3 * max_new


def test_mla_paged_matches_solo():
    cfg = dataclasses.replace(get_config("deepseek-v2-236b", smoke=True),
                              mlp_types=("dense",) * 4, moe=None)
    params = _params(cfg, seed=1)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 21)]
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=64, max_new_tokens=6, page_size=8, max_seqs=2))
    res, _ = eng.run(prompts)
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, 6, 64), i


def test_eviction_readmission_preserves_rows(smollm):
    """Tiny pool: growth forces LIFO preemption; recompute-from-prompt
    re-decode is token-identical to an uninterrupted solo run."""
    cfg, params = smollm
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (30, 28, 25, 20)]
    max_new = 20
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=64, max_new_tokens=max_new, page_size=16, max_seqs=4,
        n_pages=10))                        # 9 usable pages for 4 rows
    res, stats = eng.run(prompts)
    assert stats["n_preemptions"] > 0       # the pool really was too small
    for i, p in enumerate(prompts):
        assert res[i].tolist() == _solo(params, cfg, p, max_new, 64), i


def test_slot_compaction_frees_pages(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, (8,)).astype(np.int32),
               rng.integers(1, cfg.vocab, (8,)).astype(np.int32)]
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=3, page_size=16, max_seqs=2))
    eng.submit(prompts[0], max_new=1)       # finishes at its prefill
    eng.submit(prompts[1], max_new=3)
    s1 = eng.step()
    assert 0 in s1["finished"]              # max_new=1: done without decode
    assert eng.sched.alloc.utilization < 0.5   # its pages came back
    while eng.sched.has_work:
        eng.step()
    assert eng.sched.alloc.utilization == 0.0  # everything freed at drain
    assert len(eng.results[1]) == 3


def test_queueing_beyond_slots(smollm):
    """More requests than slots: later arrivals wait, all complete."""
    cfg, params = smollm
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab, (5 + i,)).astype(np.int32)
               for i in range(5)]
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=4, page_size=16, max_seqs=2))
    res, stats = eng.run(prompts)
    assert sorted(res) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in res.values())
    assert max(s["active"] for s in stats["steps"]) <= 2


def test_rns_ragged_prefill_and_decode_token_identical_to_solo():
    """The per-sequence quantization grids (core/quantize.token_mask)
    make the RNS path token-identical to solo runs under padding AND
    under batched decode — the caveat PR 2 documented, removed."""
    from repro.core.rns_matmul import RnsDotConfig

    base = dataclasses.replace(get_config("smollm-135m", smoke=True),
                               rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                               rns_targets="mlp")
    params = _params(base)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, base.vocab, (L,)).astype(np.int32)
               for L in (7, 33, 120)]
    max_new, S = 8, 160
    for defer in (False, True):
        eng = ContinuousEngine(params, base, ServeConfig(
            max_cache=S, max_new_tokens=max_new, page_size=16, max_seqs=4,
            rns_defer=defer))
        res, _ = eng.run(prompts)
        for i, p in enumerate(prompts):
            cfg_i = (base if not defer
                     else dataclasses.replace(
                         base, rns=dataclasses.replace(base.rns, defer=True)))
            assert res[i].tolist() == _solo(params, cfg_i, p, max_new, S), (
                defer, i)


def test_preempt_same_step_as_finish_no_double_free():
    """Regression: a sequence preempted in the same step it finishes.

    Growth (which can preempt) runs before the finished check, so the
    engine can hold a stale SeqState whose pages were already released
    by the preemption; completing it must be a no-op — not a second
    free of the pages and slot (which used to raise, and without the
    raise would hand the same page/slot to two sequences).
    """
    from repro.serve.kv_cache import PagedCacheConfig
    from repro.serve.scheduler import Request, Scheduler

    pcfg = PagedCacheConfig(page_size=4, n_pages=6, max_seqs=2,
                            max_blocks=4)
    sched = Scheduler(pcfg)
    sched.submit(Request(rid=0, tokens=np.ones(4, np.int32), max_new=8))
    sched.submit(Request(rid=1, tokens=np.ones(4, np.int32), max_new=8))
    plan = sched.schedule()
    assert len(plan.admitted) == 2
    old, young = plan.admitted
    # the older row grows until the pool is dry: the youngest is evicted
    # (needs 12 // 4 + 1 = 4 blocks; the pool holds 5, the pair owns 4)
    old.length = 12
    old.emitted = [3, 3, 3, 3]
    plan2 = sched.schedule()
    assert plan2.preempted == [young.rid]
    assert young.pages == []            # stale state defused at eviction
    n_free = sched.alloc.n_free
    # engine's finished check now completes the stale state: no-op
    sched.complete(young)
    assert sched.alloc.n_free == n_free
    assert sorted(sched._free_slots) == [young.slot]   # freed ONCE
    assert young.rid not in {s.rid for s in sched.running.values()}
    # the old row is untouched and the victim can be re-admitted cleanly
    assert sched.running[old.slot] is old
    sched.complete(old)
    plan3 = sched.schedule()
    assert [s.rid for s in plan3.admitted] == [young.rid]
    # completing the SAME state twice is also a no-op
    sched.complete(old)
    assert len(sched._free_slots) + len(sched.running) == pcfg.max_seqs


def test_window_evict_and_preempt_same_step_readmits_cleanly():
    """Regression: a row window-evicted AND LIFO-preempted in one step.

    Window eviction leaves TRASH_PAGE placeholders in ``seq.pages``;
    the preemption path (and a later stale ``complete``) must release
    only the real pages — freeing the trash page raises, and a double
    free of a cycled page would hand it to two rows.  The victim must
    readmit cleanly (fresh pages, no dangling prefill state) and the
    free list must balance page-for-page throughout.
    """
    from repro.serve.kv_cache import TRASH_PAGE, PagedCacheConfig
    from repro.serve.scheduler import Request, Scheduler

    pcfg = PagedCacheConfig(page_size=4, n_pages=6, max_seqs=2,
                            max_blocks=6, resident_blocks=3)
    sched = Scheduler(pcfg, window_tokens=6)
    sched.submit(Request(rid=0, tokens=np.ones(4, np.int32), max_new=20))
    sched.submit(Request(rid=1, tokens=np.ones(4, np.int32), max_new=20))
    plan = sched.schedule()
    assert len(plan.admitted) == 2
    old, young = plan.admitted
    # both rows decode to length 12: the 6-token window makes block 0 of
    # each row dead (keep_from = 7), and growth then wants 2 more pages
    # per row -- more than eviction freed, so the youngest is preempted
    # WHILE its page list still carries a trash placeholder
    for s in (old, young):
        s.length = 12
        s.emitted = [1] * 8
    plan2 = sched.schedule()
    assert sched.window_evictions == 2
    assert old.pages[0] == TRASH_PAGE       # eviction really cycled pages
    assert plan2.preempted == [young.rid]   # ...and did not raise on free
    assert young.pages == [] and young.todo is None
    # admission ran after the preemption in the SAME step: the victim is
    # already back, as a FRESH state on real pages
    assert [s.rid for s in plan2.admitted] == [young.rid]
    fresh = plan2.admitted[0]
    assert fresh is not young
    assert fresh.pages and all(pg != TRASH_PAGE for pg in fresh.pages)
    # page-for-page conservation across evict + preempt + readmit
    live = sum(1 for s in sched.running.values()
               for pg in s.pages if pg != TRASH_PAGE)
    assert sched.alloc.n_free + live == pcfg.n_pages - 1
    # the engine may still hold the stale victim: complete() is a no-op
    # (the slot's registered occupant is the fresh state, not it)
    n_free = sched.alloc.n_free
    sched.complete(young)
    assert sched.alloc.n_free == n_free
    assert sched.running[fresh.slot] is fresh
    # drain: both rows release every page exactly once
    sched.complete(old)
    sched.complete(fresh)
    assert sched.alloc.n_free == pcfg.n_pages - 1
    assert len(sched._free_slots) == pcfg.max_seqs


def test_rns_policy_and_per_step_op_counts():
    from repro.core.rns_matmul import RnsDotConfig

    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    params = _params(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
               for L in (5, 12)]
    per_step = {}
    for defer in (False, True):
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=32, max_new_tokens=3, page_size=16, max_seqs=2,
            rns_defer=defer))
        assert eng.cfg.rns.defer is defer   # the policy override landed
        _, stats = eng.run(prompts)
        first, last = stats["steps"][0], stats["steps"][-1]
        # admission step counts prefill + decode; later steps decode only
        assert first["rns_ops"].matmuls > last["rns_ops"].matmuls > 0
        per_step[defer] = last["rns_ops"]
    # deferred MLP: fewer slow normalizations for the same matmuls
    assert per_step[True].matmuls == per_step[False].matmuls
    assert per_step[True].normalizes < per_step[False].normalizes


def test_eos_id_validation_and_sentinel(smollm):
    cfg, params = smollm
    with pytest.raises(ValueError, match="eos_id"):
        ServeConfig(eos_id=-5)
    # -1 sentinel: never stops early -> exactly max_new tokens
    rng = np.random.default_rng(6)
    p = rng.integers(1, cfg.vocab, (9,)).astype(np.int32)
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=5, page_size=16, max_seqs=1, eos_id=-1))
    res, _ = eng.run([p])
    assert len(res[0]) == 5


def test_eos_id_stops_row(smollm):
    cfg, params = smollm
    rng = np.random.default_rng(7)
    p = rng.integers(1, cfg.vocab, (9,)).astype(np.int32)
    base = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=8, page_size=16, max_seqs=1))
    full, _ = base.run([p])
    eos = int(full[0][2])                   # aim for the 3rd token
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=8, page_size=16, max_seqs=1,
        eos_id=eos))
    res, _ = eng.run([p])
    toks = full[0].tolist()
    want = toks[: toks.index(eos) + 1]      # up to the FIRST eos occurrence
    assert res[0].tolist() == want


def test_unsupported_archs_rejected():
    scfg = ServeConfig(max_cache=32)
    rwkv = get_config("rwkv6-7b", smoke=True)
    with pytest.raises(NotImplementedError, match="attn/mla"):
        ContinuousEngine({}, rwkv, scfg)
    whisper = get_config("whisper-medium", smoke=True)
    with pytest.raises(NotImplementedError, match="decoder-only"):
        ContinuousEngine({}, whisper, scfg)


def test_oversized_requests_rejected(smollm):
    cfg, params = smollm
    eng = ContinuousEngine(params, cfg, ServeConfig(
        max_cache=32, max_new_tokens=8, page_size=16, max_seqs=2))
    with pytest.raises(ValueError, match="prompt"):
        eng.submit(np.ones((33,), np.int32))        # > prompt_pad
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(np.ones((30,), np.int32), max_new=10)  # 40 > 32 tokens
