"""End-to-end behaviour of the paper's system.

The headline claims, executed:
  1. wide-precision product summation is EXACT through the digit-sliced
     datapath (8-bit words only) — beyond what f32 accumulation achieves;
  2. deferred normalization: ONE slow op per output regardless of n;
  3. the datapath drops into a real LM and trains;
  4. precision scales by adding digit slices (linear), binary partial
     products scale quadratically.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rns
from repro.core.moduli import get_profile, required_digits
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_matmul_res


def test_exact_wide_dot_beats_f32_accumulation():
    p = get_profile("rns9")
    rng = np.random.default_rng(0)
    D = 65536
    a = rng.integers(-32767, 32768, (1, D)).astype(np.int64)
    b = rng.integers(-32767, 32768, (D, 1)).astype(np.int64)
    want = int((a.astype(object) @ b.astype(object))[0, 0])
    rc = rns_matmul_res("rns9", rns.encode_int32(p, a.astype(np.int32)),
                        rns.encode_int32(p, b.astype(np.int32)))
    got = int(rns.decode_exact(p, np.asarray(rc))[0, 0])
    assert got == want                                 # RNS: bit exact
    f32 = int(float((a.astype(np.float32) @ b.astype(np.float32))[0, 0]))
    assert f32 != want                                 # f32: rounded


def test_deferred_normalization_op_count():
    """PAC MACs + one normalization, vs one normalization per MAC."""
    from repro.core import fractional as fr

    p = get_profile("rns9")
    n = 32
    # the deferred path calls scale_signed exactly once: count via trace
    calls = {"n": 0}
    orig = fr.mrc.scale_signed

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    fr.mrc.scale_signed, token = counting, None
    try:
        xs = jnp.stack([fr.fr_encode(p, np.full(4, 0.5, np.float32))] * n)
        fr.fr_dot_deferred(p, xs, xs)
        deferred_calls = calls["n"]
        calls["n"] = 0
        acc = None
        for i in range(n):
            prod = fr.fr_mul(p, xs[i], xs[i])  # normalize EVERY multiply
            acc = prod if acc is None else fr.fr_add(p, acc, prod)
        naive_calls = calls["n"]
    finally:
        fr.mrc.scale_signed = orig
    assert deferred_calls == 1
    assert naive_calls == n


def test_rns_lm_training_loss_drops():
    import dataclasses

    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import model as M
    from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state

    cfg = dataclasses.replace(
        get_config("smollm-135m", smoke=True),
        rns=RnsDotConfig(profile="rns9", qx=14, qw=14), rns_targets="mlp")
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=8e-3, warmup_steps=2, total_steps=30,
                       weight_decay=0.0)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, branch=4, noise=0.05))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: M.loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt, _ = adamw_update(ocfg, g, opt, params)
        return params, opt, loss

    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_linear_vs_quadratic_precision_scaling():
    """Paper claim (6): slices grow ~linearly in bits; binary partial
    products grow quadratically."""
    digits = [required_digits(4096, q, q) for q in (8, 16, 24, 32)]
    # linear fit quality: second differences are ~0 for linear growth
    diffs = np.diff(digits)
    assert max(diffs) - min(diffs) <= 2
    # binary 8x8 partial products for a qxq multiply: (q/8)**2
    binary = [(q // 8) ** 2 for q in (8, 16, 24, 32)]
    assert np.all(np.diff(np.diff(binary)) > 0)  # strictly convex
    # at 32 bits RNS uses ~digits[-1] 8-bit mults/MAC vs binary 16
    assert digits[-1] < binary[-1]
