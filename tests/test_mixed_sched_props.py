"""Hypothesis properties for the packed mixed-phase scheduler.

The chunked scheduler (``Scheduler(chunked=True)`` + ``plan_mixed``) is
pure host-side policy, so its invariants are checked here with no jax at
all — a fake greedy "model" advances sequence state exactly the way the
engine would:

  * the packed token budget is never exceeded, step by step;
  * while any row is prefilling, decode rows are capped so chunks get
    their reserved lanes (bounded TTFT) yet at least one decode row
    always advances (liveness);
  * each row's chunk positions stream out strictly in order, front-
    first, and every admission episode is a prefix of the full
    position list — so a mid-chunk preemption readmits into a clean
    restart (the recompute that makes regenerated tokens identical);
  * the prefix-preference admission never starves the queue head past
    ``starvation_limit`` waiting steps;
  * every submitted request finishes (termination under preemption).

Property tests skip cleanly when hypothesis is absent (CI installs it;
see _hypothesis_stub).
"""

import numpy as np
from _hypothesis_stub import given, st

from repro.serve.kv_cache import PagedCacheConfig, PrefixCache  # noqa: F401
from repro.serve.scheduler import Request, Scheduler

BS = 4            # page size for every property run
MAX_BLOCKS = 4    # 16 tokens per sequence
MAX_NEW = 2


def _sched(*, max_seqs, n_pages, budget, chunk_size, reserve, window,
           prefix=False, starvation_limit=8):
    pcfg = PagedCacheConfig(page_size=BS, n_pages=n_pages,
                            max_seqs=max_seqs, max_blocks=MAX_BLOCKS)
    return Scheduler(pcfg, prefix_cache=prefix, lookahead=window,
                     starvation_limit=starvation_limit, chunked=True,
                     token_budget=budget, chunk_size=chunk_size,
                     prefill_reserve=reserve)


def _drive(sched, reqs, window=1, max_steps=400):
    """Run the scheduler loop with a fake greedy model.

    Chunks consume ``todo`` via plan_mixed; a ``last`` chunk emits the
    first token; decode segments emit one token and advance length (the
    scheduler only sees counters, never logits).  Returns per-rid lists
    of admission episodes (each a list of chunk positions, in emission
    order) and the set of finished rids.
    """
    for r in reqs:
        sched.submit(r)
    episodes: dict[int, list[list[int]]] = {r.rid: [] for r in reqs}
    finished: set[int] = set()
    steps = 0
    while sched.has_work:
        steps += 1
        assert steps <= max_steps, "scheduler loop did not terminate"
        plan = sched.schedule()
        for s in plan.admitted:
            episodes[s.rid].append([])
        prefilling = any(s.prefilling for s in sched.running.values())
        segs = sched.plan_mixed(window)
        assert sum(s.n for s in segs) <= sched.token_budget, \
            "token budget exceeded"
        decode_lanes = sum(s.n for s in segs if s.kind == "decode")
        if prefilling:
            cap = max(1, (sched.token_budget - sched.prefill_reserve)
                      // window)
            assert decode_lanes <= cap * window, \
                "prefill reserve not honoured"
        for s in segs:
            seq = s.seq
            if s.kind == "chunk":
                episodes[seq.rid][-1].extend(int(p) for p in s.positions)
                sched.register_chunks(seq)
                if s.last:
                    seq.emitted = [1]
                    seq.last_token = 1
            else:
                seq.emitted.append(1)
                seq.length += 1
        for seq in list(sched.running.values()):
            if seq.emitted and len(seq.emitted) >= seq.req.max_new:
                finished.add(seq.rid)
                sched.complete(seq)
    return episodes, finished


def _reqs(lens):
    rng = np.random.default_rng(7)
    return [Request(rid=i, tokens=rng.integers(1, 99, (t,)).astype(np.int32),
                    max_new=MAX_NEW) for i, t in enumerate(lens)]


@given(st.lists(st.integers(1, 14), min_size=1, max_size=6),
       st.integers(1, 3),
       st.integers(1, 12),
       st.sampled_from([1, 3]),
       st.integers(0, 11))
def test_budget_reserve_order_and_termination(lens, max_seqs, budget_raw,
                                              window, reserve_raw):
    budget = max(window, budget_raw)          # a decode row must fit
    reserve = min(reserve_raw, budget - 1)
    sched = _sched(max_seqs=max_seqs, n_pages=1 + max_seqs * MAX_BLOCKS,
                   budget=budget, chunk_size=BS, reserve=reserve,
                   window=window)
    episodes, finished = _drive(sched, _reqs(lens), window=window)
    assert finished == set(range(len(lens)))
    for rid, t in enumerate(lens):
        eps = episodes[rid]
        assert eps, "row never admitted"
        full = list(range(t))                 # no prefix cache: every pos
        for ep in eps[:-1]:                   # preempted episodes: clean
            assert ep == full[: len(ep)]      # front-first prefixes
        assert eps[-1] == full                # final episode completes


@given(st.lists(st.integers(1, 14), min_size=2, max_size=5),
       st.sampled_from([1, 3]))
def test_preempt_mid_chunk_readmits_cleanly(lens, window):
    """A pool too small for all rows forces mid-prefill eviction; every
    readmission must restart its chunk stream from scratch (the todo
    deque is rebuilt at admission, never resumed from a stale state) —
    the precondition for recompute token-identity."""
    sched = _sched(max_seqs=2, n_pages=1 + MAX_BLOCKS + 1, budget=6,
                   chunk_size=BS, reserve=3, window=window)
    episodes, finished = _drive(sched, _reqs(lens), window=window)
    assert finished == set(range(len(lens)))
    for rid, t in enumerate(lens):
        full = list(range(t))
        for ep in episodes[rid][:-1]:
            assert ep == full[: len(ep)]
        assert episodes[rid][-1] == full


@given(st.integers(2, 5), st.integers(6, 12))
def test_head_never_starves_past_limit(n_cached, t_head):
    """Prefix-preference admission vs the FCFS guard: once the queue
    head has waited ``starvation_limit`` scheduler steps, the next
    admission must be the head, no matter how long the cached
    competitors' prefixes are."""
    limit = 3
    sched = _sched(max_seqs=1, n_pages=1 + MAX_BLOCKS, budget=6,
                   chunk_size=BS, reserve=3, window=1, prefix=True,
                   starvation_limit=limit)
    rng = np.random.default_rng(3)
    donor = rng.integers(1, 99, (8,)).astype(np.int32)
    # a completed donor seeds the prefix index
    _drive(sched, [Request(rid=100, tokens=donor, max_new=MAX_NEW)])
    head = Request(rid=0,
                   tokens=rng.integers(1, 99, (t_head,)).astype(np.int32),
                   max_new=MAX_NEW)
    sched.submit(head)
    for i in range(n_cached):                  # cached competitors behind
        sched.submit(Request(rid=1 + i, tokens=donor.copy(),
                             max_new=MAX_NEW))
    violations = []
    for _ in range(200):
        if not sched.has_work:
            break
        head_waiting = any(r.rid == 0 for r in sched.waiting)
        overdue = head_waiting and head.wait_steps >= limit
        plan = sched.schedule()
        if overdue and plan.admitted and plan.admitted[0].rid != 0:
            violations.append(plan.admitted[0].rid)
        for s in sched.plan_mixed(1):
            seq = s.seq
            if s.kind == "chunk":
                if s.last:
                    seq.emitted = [1]
                    seq.last_token = 1
            else:
                seq.emitted.append(1)
                seq.length += 1
        for seq in list(sched.running.values()):
            if seq.emitted and len(seq.emitted) >= seq.req.max_new:
                sched.complete(seq)
    assert not violations, f"head starved past limit by {violations}"
    assert 0 in sched.running or not sched.has_work
