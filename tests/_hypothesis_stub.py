"""Optional-hypothesis shim: property tests skip cleanly when absent.

Test modules do ``from _hypothesis_stub import given, st`` instead of
importing hypothesis directly.  With hypothesis installed this re-exports
the real API; without it, ``@given(...)`` marks the test skipped and the
``st`` namespace returns inert placeholder strategies (they are only ever
built at decoration time, never drawn from).
"""

try:
    from hypothesis import given, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import pytest

    def given(*_args, **_kwargs):
        def deco(f):
            return pytest.mark.skip(reason="hypothesis not installed")(f)

        return deco

    class _StrategyStub:
        """Any ``st.xyz(...)`` call chain returns another inert stub."""

        def __call__(self, *args, **kwargs):
            return _StrategyStub()

        def __getattr__(self, name):
            return _StrategyStub()

    st = _StrategyStub()
