"""Sharding rule resolution (pure) + a small-mesh dry-run in a subprocess."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestSpecResolution:
    def _mesh(self, shape=(2, 4), axes=("data", "model")):
        # AbstractMesh: rule resolution only needs axis names + sizes
        from jax.sharding import AbstractMesh

        try:
            return AbstractMesh(shape, axes)            # jax >= 0.5
        except TypeError:
            return AbstractMesh(tuple(zip(axes, shape)))  # jax 0.4.x

    def test_basic_rules(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import spec_for_axes

        mesh = self._mesh((1, 1))
        # all divisible by 1: axes assigned
        assert spec_for_axes(("embed", "mlp"), (64, 256), mesh) == P("data", "model")

    def test_conflict_falls_back(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import spec_for_axes

        mesh = self._mesh((2, 4))
        # lora ranks are NEVER sharded (contraction dims; §Perf deepseek
        # iter 4) — heads still takes model
        assert spec_for_axes(("lora", "heads"), (64, 64), mesh) == P(None, "model")
        assert spec_for_axes(("heads", "lora"), (64, 64), mesh) == P("model", None)
        # same mesh axis is never used twice within one param
        assert spec_for_axes(("mlp", "heads"), (64, 64), mesh) == P("model", None)

    def test_indivisible_replicates(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import spec_for_axes

        mesh = self._mesh((2, 4))
        # 49155 % 4 != 0 -> vocab falls through model, lands on data? 49155 % 2
        # != 0 too -> replicated
        assert spec_for_axes(("vocab",), (49155,), mesh) == P(None)
        assert spec_for_axes(("vocab",), (49152,), mesh) == P("model")

    def test_first_valid_spec(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import first_valid_spec

        mesh = self._mesh((2, 4))
        cands = [P("data", "model"), P("data", None), P(None, None)]
        assert first_valid_spec((4, 8), cands, mesh) == P("data", "model")
        assert first_valid_spec((4, 9), cands, mesh) == P("data", None)
        assert first_valid_spec((3, 9), cands, mesh) == P(None, None)


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["JAX_PLATFORMS"] = "cpu"
import json, warnings
warnings.filterwarnings("ignore")
import jax
from repro.configs.base import get_config, ShapeConfig
from repro.launch import specs as SP
from repro.launch.dryrun import lower_cell
from repro.launch.hlo_cost import analyze_hlo

out = {}
for mesh_shape, axes, tag in [((2, 4), ("data", "model"), "single"),
                              ((2, 2, 4), ("pod", "data", "model"), "multi")]:
    mesh = jax.make_mesh(mesh_shape, axes)
    cfg = SP.with_shape_overrides(get_config("smollm-135m"))
    rec = {}
    for shape in [ShapeConfig("train", 256, 8, "train"),
                  ShapeConfig("prefill", 512, 4, "prefill"),
                  ShapeConfig("decode", 512, 8, "decode"),
                  ShapeConfig("long", 1024, 1, "decode")]:
        lowered, compiled, meta = lower_cell(cfg, shape, mesh)
        r = analyze_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        rec[shape.name] = {"flops": r["flops"], "wire": r["total_wire_bytes"],
                           "temp": mem.temp_size_in_bytes}
    out[tag] = rec
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_small_mesh_dryrun_subprocess():
    """End-to-end proof: lower+compile on single- AND multi-pod meshes."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    for mesh in ("single", "multi"):
        for shape in ("train", "prefill", "decode", "long"):
            assert out[mesh][shape]["flops"] > 0, (mesh, shape)
    # multi-pod (8 chips) shards the batch further than single (4 chips
    # of DP x 2 model... ) — just require both compiled with collectives
    assert out["single"]["train"]["wire"] > 0
    assert out["multi"]["train"]["wire"] > 0
