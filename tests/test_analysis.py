"""Static analysis: the exactness auditor and the repo-invariant linter.

The auditor must agree with the runtime magnitude ledger op for op (they
share ``core.tensor.ledger_limit_bits`` / ``dot_out_bits``), prove every
shipped ServeConfig feature combination exact without running the model,
and reject a deliberately overflowing configuration while naming the
failing layer and op.  The linter must hold ``src/`` at zero unsuppressed
violations (the CI ``static-analysis`` gate).
"""

import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.ledger_audit import (
    audit_fn,
    audit_serve,
    validate_resident,
)
from repro.analysis.lint import lint_source, run_lint
from repro.configs.base import get_config
from repro.core import dispatch
from repro.core.moduli import get_profile
from repro.core.rns_matmul import RnsDotConfig
from repro.core.tensor import (
    dot_out_bits,
    ledger_limit_bits,
    matmul_out_bits,
    needs_renormalize,
    rt_decode,
    rt_encode,
    rt_encode_int,
    rt_matmul,
)
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, ServeConfig


@pytest.fixture(scope="module")
def smoke():
    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    return cfg, M.init_model(jax.random.PRNGKey(0), cfg)[0]


# ----------------------------------------------- shared bound helpers -----
class TestBoundHelpers:
    """The three former per-call-site bound formulas now share one home;
    the runtime ledger and the static auditor must read identical numbers
    at the rns6/rns9 boundaries."""

    @pytest.mark.parametrize("profile", ["rns6", "rns9"])
    def test_limit_is_signed_bits_minus_safety(self, profile):
        p = get_profile(profile)
        assert ledger_limit_bits(profile) == p.signed_bits - 1.0
        assert ledger_limit_bits(p) == ledger_limit_bits(profile)

    @pytest.mark.parametrize("profile", ["rns6", "rns9"])
    def test_headroom_matches_limit(self, profile):
        rt = rt_encode(jnp.ones((2, 4)), profile, bits=8)
        assert rt.headroom_bits() == ledger_limit_bits(profile) - rt.mag_bits

    @pytest.mark.parametrize("profile", ["rns6", "rns9"])
    def test_matmul_out_bits_is_dot_out_bits(self, profile):
        a = rt_encode(jnp.ones((2, 8)), profile, bits=8)
        w = rt_encode(jnp.ones((8, 2)), profile, bits=8)
        assert matmul_out_bits(a, w, 8) == dot_out_bits(
            a.mag_bits, w.mag_bits, 8)
        assert dot_out_bits(7.0, 7.0, 8) == 7.0 + 7.0 + 3.0

    @pytest.mark.parametrize("profile", ["rns6", "rns9"])
    def test_needs_renormalize_boundary_agreement(self, profile):
        """Exactly at the limit is fine; any epsilon over trips — and the
        trip point is THE shared limit, on both profiles."""
        lim = ledger_limit_bits(profile)
        rt = rt_encode(jnp.ones((2, 4)), profile, bits=8)
        at = lim - rt.mag_bits
        assert not needs_renormalize(rt, at)
        assert needs_renormalize(rt, at + 1e-6)


# ------------------------------------------------------- rt_encode_int ----
class TestEncodeIntLedger:
    """The old hardcoded ``mag_bits=31.0`` default lied for small values
    and silently passed unrepresentable ones; the bound is now derived."""

    def test_concrete_value_derives_actual_bound(self):
        rt = rt_encode_int(jnp.asarray([3, -12345], jnp.int32), "rns9")
        assert rt.mag_bits == pytest.approx(math.log2(12345))

    def test_tiny_values_floor_at_zero(self):
        assert rt_encode_int(jnp.asarray([0, 1], jnp.int32),
                             "rns9").mag_bits == 0.0

    def test_explicit_mag_bits_wins(self):
        rt = rt_encode_int(jnp.asarray([3], jnp.int32), "rns9",
                           mag_bits=20.0)
        assert rt.mag_bits == 20.0

    def test_unrepresentable_concrete_value_raises(self):
        with pytest.raises(ValueError, match="wider profile"):
            rt_encode_int(np.asarray([2**40], np.int64), "rns5")

    def test_traced_value_clamps_to_profile(self):
        seen = {}

        def f(v):
            rt = rt_encode_int(v, "rns9")
            seen["mag"] = rt.mag_bits
            return rt.digits

        jax.eval_shape(f, jax.ShapeDtypeStruct((4,), jnp.int32))
        assert seen["mag"] == 31.0  # int32 payload < rns9 signed range


# ------------------------------------------------------------ OpCounts ----
class TestOpCounts:
    def test_add_merges_and_scales(self):
        a = dispatch.OpCounts(converts=1, matmuls=2, normalizes=1,
                              fallbacks=1,
                              fallback_sites={("s1", "r1"): 1})
        b = dispatch.OpCounts(converts=2, matmuls=1, fused=1, fallbacks=2,
                              weight_converts=1,
                              fallback_sites={("s1", "r1"): 1,
                                              ("s2", "r2"): 1})
        out = a.add(b, times=3)
        assert (out.converts, out.matmuls, out.normalizes, out.fused,
                out.fallbacks, out.weight_converts) == (7, 5, 1, 3, 7, 3)
        assert out.fallback_sites == {("s1", "r1"): 4, ("s2", "r2"): 3}
        # inputs untouched
        assert a.fallback_sites == {("s1", "r1"): 1}

    def test_fallbacks_tally_per_site(self):
        with dispatch.count_ops() as c:
            dispatch._tally_fallback("unit-test reason")
            dispatch._tally_fallback("unit-test reason")
        assert c.fallbacks == 2
        ((site, reason), n), = c.fallback_sites.items()
        assert reason == "unit-test reason" and n == 2
        # callers outside the repo get the explicit out-of-tree marker;
        # in-tree sites are named (see the audit fallback tests)
        assert site == "<external>"


# --------------------------------------------------------------- audit ----
class TestAuditFn:
    def test_proves_simple_matmul_chain(self):
        def f(x, w):
            a = rt_encode(x, "rns9", bits=8)
            b = rt_encode(w, "rns9", bits=8, weight=True)
            return rt_decode(rt_matmul(a, b))

        rep = audit_fn(f, jnp.ones((4, 16)), jnp.ones((16, 4)))
        assert rep.ok
        (ph,) = rep.phases
        assert ph.counts["matmuls"] == 1 and ph.counts["normalizes"] == 1
        assert ph.counts_match                   # graph == traced OpCounts
        assert ph.min_headroom == pytest.approx(
            ledger_limit_bits("rns9") - dot_out_bits(7.0, 7.0, 16))
        assert ph.critical_path                  # names the tight chain

    def test_smoke_arch_prefill_proved(self, smoke):
        cfg, params = smoke
        rep = audit_fn(
            lambda p, t: M.prefill(p, cfg, {"tokens": t}, S_max=16),
            params, jnp.zeros((1, 8), jnp.int32), name="prefill")
        assert rep.ok and rep.min_headroom > 0
        (ph,) = rep.phases
        assert ph.counts["matmuls"] > 0 and ph.counts_match


class TestOverflowRejection:
    def test_overflowing_config_names_layer_and_op(self, smoke):
        cfg, params = smoke
        # rns5 holds ~33.8 exact bits: a 16x16-bit dot over the smoke
        # model's MLP contraction provably cannot fit
        bad = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile="rns5", qx=16, qw=16))
        rep = audit_serve(params, bad, ServeConfig(
            max_cache=24, page_size=8, max_seqs=2))
        assert not rep.ok
        failed = [p for p in rep.phases if not p.ok]
        assert failed
        ph = failed[0]
        assert ph.error and "wider profile" in ph.error
        assert ph.error_site["layer"].startswith("models/")
        assert ph.error_site["op"].startswith(("core/", "kernels/"))
        assert "FAILED" in rep.summary()


class TestServeConfigAudit:
    def test_audit_true_builds_and_attaches_report(self, smoke):
        cfg, params = smoke
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=24, page_size=8, max_seqs=2, audit=True))
        assert eng.audit_report is not None and eng.audit_report.ok
        assert eng.audit_report.min_headroom > 0

    def test_audit_true_refuses_unprovable_config(self, smoke):
        cfg, params = smoke
        bad = dataclasses.replace(
            cfg, rns=RnsDotConfig(profile="rns5", qx=16, qw=16))
        with pytest.raises(ValueError, match="exactness audit"):
            ContinuousEngine(params, bad, ServeConfig(
                max_cache=24, page_size=8, max_seqs=2, audit=True))

    def test_audit_skips_float_configs(self, smoke):
        cfg, params = smoke
        float_cfg = dataclasses.replace(cfg, rns=None)
        eng = ContinuousEngine(params, float_cfg, ServeConfig(
            max_cache=24, page_size=8, max_seqs=2, audit=True))
        assert eng.audit_report is None

    def test_all_feature_combos_proved(self, smoke):
        """resident x defer x chunked x spec x prefix — every shipped
        combination must be provably exact at build time."""
        cfg, params = smoke
        n = 0
        for resident in (False, True):
            for defer in (False, True):
                for chunked in (False, True):
                    for spec in (False, True):
                        for prefix in (False, True):
                            scfg = ServeConfig(
                                max_cache=24, page_size=8, max_seqs=2,
                                rns_defer=defer, resident_weights=resident,
                                per_layer_profiles=resident,
                                chunked_prefill=chunked,
                                spec_decode=spec, spec_k=3,
                                token_budget=16, prefix_cache=prefix,
                                audit=True)
                            eng = ContinuousEngine(params, cfg, scfg)
                            assert eng.audit_report.ok, vars(scfg)
                            n += 1
        assert n == 32


# ---------------------------------------------------- resident re-proof ---
class TestResidentValidation:
    def test_resident_entries_reproved_from_masters(self, smoke):
        from repro.models.resident import encode_resident

        cfg, params = smoke
        res = encode_resident(params, cfg, per_layer_profiles=True)
        entries = validate_resident(res, cfg.rns)
        assert entries and all(e["ok"] for e in entries)

    def test_tampered_ledger_bound_is_caught(self, smoke):
        from repro.models import resident as R

        cfg, params = smoke
        res = jax.tree.map(lambda x: x,            # fresh containers
                           R.encode_resident(params, cfg))

        def tamper(mlp, path):
            for name in R._MLP_WEIGHTS:
                if isinstance(mlp.get(name), dict) and "w_res" in mlp[name]:
                    w = mlp[name]["w_res"]
                    mlp[name]["w_res"] = dataclasses.replace(
                        w, mag_bits=w.mag_bits - 4.0)
                    return mlp
            return mlp

        R._walk_mlps(res, tamper)
        entries = validate_resident(res, cfg.rns)
        assert any(not e["ok"] and "under-approximates" in e["detail"]
                   for e in entries)


# ---------------------------------------------------------------- lint ----
class TestLintRules:
    def test_pallas_call_outside_kernels(self):
        src = "import jax.experimental.pallas as pl\npl.pallas_call(k)\n"
        (v,) = lint_source(src, "models/layers.py")
        assert v.rule == "pallas-call" and v.line == 2
        assert not lint_source(src, "kernels/rns_matmul/kernel.py")

    def test_raw_digits_arithmetic(self):
        src = "y = rt.digits + 1\n"
        (v,) = lint_source(src, "serve/engine.py")
        assert v.rule == "raw-digits"
        assert not lint_source(src, "core/tensor.py")
        # arithmetic-shaped calls count too; layout moves don't
        assert lint_source("jnp.sum(rt.digits)\n", "serve/engine.py")
        assert not lint_source("jnp.moveaxis(rt.digits, 0, -1)\n",
                               "serve/engine.py")

    def test_backend_flag_bypass(self):
        src = "f(x, interpret=True)\n"
        (v,) = lint_source(src, "serve/engine.py")
        assert v.rule == "backend-flag"
        assert not lint_source(src, "kernels/rns_fused/ops.py")
        assert lint_source("g(use_pallas=True)\n", "models/layers.py")
        assert not lint_source("g(use_pallas=True)\n", "core/rns_matmul.py")

    def test_host_in_jit(self):
        src = "import time\nt = time.perf_counter()\n"
        (v,) = lint_source(src, "models/layers.py")
        assert v.rule == "host-in-jit"
        assert not lint_source(src, "serve/engine.py")  # host code is fine
        assert lint_source("x = np.random.uniform(0, 1)\n", "core/rns.py")

    def test_line_suppression_covers_line_and_next(self):
        src = ("# lint-ok: raw-digits (unit test)\n"
               "y = rt.digits + 1\n"
               "z = rt.digits + 2\n")
        (v,) = lint_source(src, "serve/engine.py")
        assert v.line == 3                       # line 2 was covered

    def test_file_suppression_and_multi_rule(self):
        src = ("# lint-ok-file: raw-digits\n"
               "y = rt.digits + 1\n"
               "t = time.sleep(1)  # lint-ok: host-in-jit, backend-flag\n")
        assert not lint_source(src, "models/layers.py")

    def test_repo_is_clean(self):
        violations = run_lint()
        assert violations == [], "\n".join(str(v) for v in violations)
