"""Kernel legality/VMEM auditor + jit compile-churn prover.

The load-bearing claims, executed:
  * every shipped block config (autotune DEFAULTS, every CANDIDATE,
    persisted cache rows) is statically proven Mosaic-legal and within
    the VMEM budget, for every kernel family x shape bucket;
  * an intentionally-illegal block is caught and NAMED at every layer:
    the closed-form checker, the wrapper guard (ValueError with kernel,
    blocks, computed VMEM bytes), the autotune cache load (self-heal to
    DEFAULTS with a logged reason), and the ``ServeConfig(audit=True)``
    engine build gate;
  * the trace auditor proves the continuous engine's phases keep ONE
    jit signature across a traffic family — and that static proof
    agrees with the runtime ``_cache_size() == 1`` pins;
  * a fabricated traffic-dependent phase is caught with the drifting
    leaf named.
"""

import dataclasses
import json
import logging

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis.kernel_audit import (
    BUDGET_BYTES,
    KernelAuditReport,
    audit_all,
    audit_config,
    capture_launches,
    check_launch,
    check_wrapper_blocks,
    launch_vmem_bytes,
    sublane,
    validate_blocks,
    vmem_bytes,
)
from repro.analysis.trace_audit import (
    arg_signature,
    audit_traces,
    describe_signature,
    traffic_family,
)
from repro.configs.base import get_config
from repro.core.moduli import get_profile
from repro.core.rns import encode_int32
from repro.core.rns_matmul import RnsDotConfig
from repro.kernels import autotune
from repro.models import model as M
from repro.serve.engine import ContinuousEngine, ServeConfig

_MATMUL_KINDS = ("rns_matmul", "rns_fused_encode_matmul",
                 "rns_fused_matmul_normalize", "rns_fused_dot")


@pytest.fixture(scope="module")
def smoke():
    cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                              rns=RnsDotConfig(profile="rns9", qx=8, qw=8),
                              rns_targets="mlp")
    return cfg, M.init_model(jax.random.PRNGKey(0), cfg)[0]


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache()
    yield path
    autotune.clear_cache()


# ------------------------------------------------ closed-form contract ----
class TestTileContract:
    def test_shipped_defaults_legal_for_every_kind(self):
        for kind, blocks in autotune.DEFAULTS.items():
            assert validate_blocks(kind, blocks, n_digits=9) == [], kind

    def test_lane_violation_named(self):
        v = validate_blocks("rns_matmul",
                            {"bm": 128, "bn": 100, "bk": 512}, n_digits=9)
        assert v and all(s.startswith("rns_matmul") for s in v)
        assert any("lane" in s for s in v)

    def test_whole_dim_exempts_lane_rule(self):
        # bn == N: the block spans the array dim, so 100 lanes is fine
        v = validate_blocks("rns_matmul", {"bm": 8, "bn": 100, "bk": 512},
                            n_digits=9,
                            dims={"M": 8, "D": 512, "N": 100})
        assert v == []

    def test_int8_profiles_tighten_the_sublane_rule(self):
        assert sublane(1) == 32 and sublane(2) == 16 and sublane(4) == 8
        # bm=8 is a legal f32 sublane but NOT a legal int8 one
        ok = validate_blocks("rns_matmul", {"bm": 8, "bn": 128, "bk": 512},
                             n_digits=6, res_bytes=4)
        bad = validate_blocks("rns_matmul", {"bm": 8, "bn": 128, "bk": 512},
                              n_digits=6, res_bytes=1)
        assert ok == []
        assert any("sublane" in s and "32" in s for s in bad)

    def test_vmem_formula_is_double_buffered_streams_plus_scratch(self):
        # rns_normalize, K=9, bt=1024: res (9,1024)x4B + out (1024,)x4B
        # streamed, no scratch -> 2 * (36864 + 4096)
        assert vmem_bytes("rns_normalize", {"bt": 1024},
                          n_digits=9) == 2 * (9 * 1024 * 4 + 1024 * 4)
        # rns_matmul defaults, K=9: moduli + a + b + out tiles double-
        # buffered, plus the (bm, bn) f32 accumulator scratch once
        streamed = (1 * 1 + 128 * 512 + 512 * 128 + 128 * 128) * 4
        assert vmem_bytes("rns_matmul", {"bm": 128, "bn": 128, "bk": 512},
                          n_digits=9) == 2 * streamed + 128 * 128 * 4

    def test_budget_violation_named(self):
        v = validate_blocks("rns_fused_matmul_normalize",
                            {"bm": 1024, "bn": 1024, "bk": 1024},
                            n_digits=9)
        assert any("budget" in s and str(BUDGET_BYTES) in s for s in v)

    def test_junk_is_named_not_raised(self):
        assert validate_blocks("no_such_kernel", {"bm": 128}) \
            == ["unknown kernel kind 'no_such_kernel'"]
        v = validate_blocks("rns_matmul",
                            {"bm": "big", "bn": 128, "bk": 512})
        assert v and "'bm'" in v[0] and "positive int" in v[0]
        assert "not a dict" in validate_blocks("rns_convert", [1024])[0]
        assert "positive int" in \
            validate_blocks("rns_convert", {"bt": True})[0]

    def test_wrapper_gate_names_kernel_blocks_and_vmem(self):
        blocks = {"bm": 128, "bn": 100, "bk": 512}
        with pytest.raises(ValueError) as e:
            check_wrapper_blocks("rns_matmul", blocks, dims={}, n_digits=9)
        msg = str(e.value)
        assert "rns_matmul" in msg and "'bn': 100" in msg
        assert "VMEM working set" in msg and str(BUDGET_BYTES) in msg


# ------------------------------------------------------ wrapper guards ----
class TestWrapperGuards:
    def test_rns_matmul_refuses_illegal_bn(self):
        from repro.kernels.rns_matmul.ops import rns_matmul

        p = get_profile("rns9")
        rng = np.random.default_rng(0)
        ra = jnp.asarray(encode_int32(
            p, rng.integers(-2**10, 2**10, (8, 256)).astype(np.int32)))
        rb = jnp.asarray(encode_int32(
            p, rng.integers(-2**10, 2**10, (256, 256)).astype(np.int32)))
        with pytest.raises(ValueError,
                           match="rns_matmul: illegal block config"):
            rns_matmul("rns9", ra, rb, bn=100)

    def test_rns_convert_refuses_illegal_bt(self):
        from repro.kernels.rns_convert.ops import rns_convert

        with pytest.raises(ValueError,
                           match="rns_convert: illegal block config"):
            rns_convert("rns9", jnp.ones(512, jnp.float32),
                        jnp.float32(4.0), bt=100)


# ------------------------------------------------------- capture layer ----
class TestCaptureLayer:
    def test_capture_records_the_real_launch(self):
        from repro.kernels.rns_matmul.ops import rns_matmul

        launches = capture_launches(
            lambda a, b: rns_matmul("rns9", a, b),
            jax.ShapeDtypeStruct((9, 8, 512), jnp.int32),
            jax.ShapeDtypeStruct((9, 512, 512), jnp.int32))
        assert len(launches) == 1
        ln = launches[0]
        assert ln.kind == "rns_matmul" and ln.grid[0] == 9
        assert check_launch(ln) == []
        # the closed-form model must be conservative vs the real launch
        assert launch_vmem_bytes(ln) <= vmem_bytes(
            "rns_matmul", autotune.DEFAULTS["rns_matmul"], n_digits=9)

    def test_capture_drops_its_poisoned_traces(self):
        from repro.kernels.rns_matmul.kernel import rns_matmul_tiles
        from repro.kernels.rns_matmul.ops import rns_matmul

        capture_launches(
            lambda a, b: rns_matmul("rns9", a, b),
            jax.ShapeDtypeStruct((9, 8, 512), jnp.int32),
            jax.ShapeDtypeStruct((9, 512, 512), jnp.int32))
        # the zeros-returning shim trace must never serve a real call
        assert rns_matmul_tiles._cache_size() == 0


# ------------------------------------------------------- report layer -----
class TestAuditSweep:
    def test_every_shipped_config_proved(self):
        report = audit_all()
        assert report.ok, report.summary()
        kinds = {e["kind"] for e in report.entries}
        assert kinds == set(autotune.DEFAULTS)
        sources = {e["source"].split("[")[0] for e in report.entries}
        assert {"defaults", "candidate"} <= sources
        # flash has no RNS profile: audited once under its dtype tag
        assert {e["profile"] for e in report.entries
                if e["kind"] == "flash_attention"} == {"float32"}
        assert report.summary().startswith("kernel audit: PROVED")

    def test_injected_illegal_config_failed_and_named(self):
        entry = audit_config("rns_matmul", "rns9", (8, 512, 512),
                             {"bm": 128, "bn": 100, "bk": 512},
                             source="injected")
        assert not entry["ok"]
        joined = " ".join(entry["violations"])
        assert "rns_matmul" in joined and "lane" in joined
        report = KernelAuditReport(ok=False, entries=[entry])
        assert "FAILED" in report.summary()
        assert "injected" in report.summary()
        assert json.loads(report.to_json())["ok"] is False


# --------------------------------------------------- engine build gate ----
class TestEngineGate:
    @pytest.mark.parametrize("backend", ["pallas_interpret",
                                         "pallas_fused_interpret"])
    def test_illegal_tuned_block_refuses_build(self, smoke, tmp_cache,
                                               monkeypatch, backend):
        """A bad tile that reaches the wrappers (here: forced through
        DEFAULTS) must refuse the audited engine build, naming the
        kernel, the block, and the violated constraint."""
        cfg, params = smoke
        for kind in _MATMUL_KINDS:
            monkeypatch.setitem(autotune.DEFAULTS, kind,
                                dict(autotune.DEFAULTS[kind], bn=100))
        with pytest.raises(ValueError, match="kernel audit failed") as e:
            ContinuousEngine(params, cfg, ServeConfig(
                max_cache=24, page_size=8, max_seqs=2, audit=True,
                rns_backend=backend))
        msg = str(e.value)
        assert "'bn': 100" in msg and "illegal block config" in msg

    def test_legal_build_attaches_kernel_and_trace_reports(self, smoke):
        cfg, params = smoke
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=24, page_size=8, max_seqs=2, audit=True,
            rns_backend="pallas_interpret"))
        assert eng.kernel_audit_report.ok
        assert {e["kind"] for e in eng.kernel_audit_report.entries} \
            == {"engine.decode", "engine.prefill"}
        assert all(e["n_launches"] > 0
                   for e in eng.kernel_audit_report.entries)
        assert eng.trace_audit_report.ok
        assert eng.audit_report.ok          # the exactness proof rides along


# --------------------------------------------- autotune cache self-heal ---
class TestCacheSelfHeal:
    def test_illegal_row_dropped_with_logged_reason(self, tmp_cache,
                                                    caplog):
        bad_key = "rns_matmul|rns9|128x512x128|cpu"
        tmp_cache.write_text(json.dumps({"version": 1, "entries": {
            bad_key: {"blocks": {"bm": 128, "bn": 100, "bk": 512},
                      "us": 1.0},
            "rns_normalize|rns9|512|cpu": {"blocks": {"bt": 512},
                                           "us": 1.0},
        }}))
        autotune.clear_cache()
        with caplog.at_level(logging.WARNING,
                             logger="repro.kernels.autotune"):
            blk = autotune.get_blocks("rns_matmul", "rns9",
                                      (128, 512, 128), "cpu")
        assert blk == autotune.DEFAULTS["rns_matmul"]    # healed
        assert "self-healing to DEFAULTS" in caplog.text
        assert bad_key in caplog.text and "'bn': 100" in caplog.text
        # the legal row in the same file survives the heal
        assert autotune.get_blocks("rns_normalize", "rns9",
                                   (512,), "cpu") == {"bt": 512}

    def test_tune_skips_illegal_candidates(self, tmp_cache, monkeypatch,
                                           caplog):
        monkeypatch.setitem(autotune.CANDIDATES, "rns_normalize",
                            [{"bt": 100}, {"bt": 512}])
        measured = []

        def bench(blocks):
            measured.append(dict(blocks))
            return 0.001

        with caplog.at_level(logging.WARNING,
                             logger="repro.kernels.autotune"):
            got = autotune.tune("rns_normalize", "rns9", (512,), "cpu",
                                bench_fn=bench, repeats=1)
        assert measured == [{"bt": 512}]    # the illegal tile never ran
        assert got == {"bt": 512}
        assert "skipping illegal candidate" in caplog.text

    def test_tune_with_no_legal_candidates_keeps_defaults(
            self, tmp_cache, monkeypatch, caplog):
        monkeypatch.setitem(autotune.CANDIDATES, "rns_normalize",
                            [{"bt": 100}])

        def boom(blocks):
            raise AssertionError("illegal candidate was measured")

        with caplog.at_level(logging.WARNING,
                             logger="repro.kernels.autotune"):
            got = autotune.tune("rns_normalize", "rns9", (512,), "cpu",
                                bench_fn=boom, repeats=1)
        assert got == autotune.DEFAULTS["rns_normalize"]
        assert "no legal candidates" in caplog.text
        assert not tmp_cache.exists()       # nothing bogus persisted


# ------------------------------------------------------- trace auditor ----
class _DriftingEngine:
    """Fake engine whose step signature depends on traffic — the exact
    bug class the auditor exists to catch."""

    prompt_pad = 8

    def _trace_specs(self, traffic=None):
        L = int((traffic or {}).get("length", 1))
        return {"step": (lambda t: t, (jnp.zeros((1, L), jnp.int32),))}


class _FlakyPhaseEngine:
    prompt_pad = 8

    def _trace_specs(self, traffic=None):
        specs = {"decode": (lambda t: t, (jnp.zeros((1, 1), jnp.int32),))}
        if int((traffic or {}).get("length", 1)) == 8:
            specs["prefill"] = (lambda t: t,
                                (jnp.zeros((1, 8), jnp.int32),))
        return specs


class TestTraceAudit:
    def test_arg_signature_sees_weak_types(self):
        sig = arg_signature((1.0, jnp.zeros((2, 8), jnp.int32)))
        (s0, _d0, weak0), (s1, d1, weak1) = sig[1]
        assert s0 == () and weak0          # python scalar: weak, retraces
        assert s1 == (2, 8) and d1 == "int32" and not weak1
        txt = describe_signature(sig)
        assert "~" in txt and "2x8:int32" in txt

    def test_family_spans_the_prompt_pad(self, smoke):
        cfg, params = smoke
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=32, max_new_tokens=4, page_size=8, max_seqs=2))
        fam = traffic_family(eng)
        assert {t["length"] for t in fam} \
            == {1, 2, eng.prompt_pad // 2, eng.prompt_pad - 1,
                eng.prompt_pad}

    def test_static_proof_agrees_with_runtime_cache_pins(self, smoke):
        cfg, params = smoke
        eng = ContinuousEngine(params, cfg, ServeConfig(
            max_cache=32, max_new_tokens=4, page_size=8, max_seqs=2))
        report = audit_traces(eng)
        assert report.ok, report.summary()
        assert {p.phase for p in report.phases} == {"decode", "prefill"}
        assert report.n_variants == len(traffic_family(eng))
        assert "PROVED" in report.summary()
        # the runtime fact the proof predicts: mixed lengths, one trace
        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
                   for L in (3, 7)]
        eng.run(prompts)
        assert eng._decode._cache_size() == 1
        assert eng._prefill._cache_size() == 1

    def test_drifting_phase_caught_with_leaf_named(self):
        report = audit_traces(_DriftingEngine())
        assert not report.ok
        bad = report.failed[0]
        assert bad.phase == "step"
        assert any("leaf 0" in d for d in bad.drift)
        assert "FAILED" in report.summary() and "step" in report.summary()

    def test_traffic_dependent_phase_set_caught(self):
        report = audit_traces(_FlakyPhaseEngine())
        assert not report.ok
        drift = [d for p in report.failed for d in p.drift]
        assert any("traffic variants" in d for d in drift)
