import os
import tempfile

# Tests run on the single real CPU device; the 512-device farm is ONLY for
# the dry-run process (launch/dryrun.py sets its own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# The suite's compile-count pins assume the wrappers' DEFAULT tile sizes;
# a developer's tuned cache (~/.cache/repro_rns/autotune.json) must not
# leak in.  Point the autotuner at a throwaway per-run path (the
# autotune tests repoint it again via monkeypatch).
os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro_autotune_test_"), "autotune.json")

# hypothesis is an optional extra (pyproject [test]); in a minimal env the
# suite must still collect — property tests skip via tests/_hypothesis_stub.
try:
    from hypothesis import HealthCheck, settings
except ModuleNotFoundError:
    pass
else:
    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
    )
    settings.load_profile("ci")
