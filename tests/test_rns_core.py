"""Core RNS arithmetic vs python-int oracles (exact, property-based)."""

import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_stub import given, st

from repro.core import mrc, rns
from repro.core.moduli import PROFILES, get_profile, required_digits

P9 = get_profile("rns9")
HALF = P9.M // 2


class TestModuli:
    def test_profiles_coprime_and_sized(self):
        import math

        for name, p in PROFILES.items():
            ms = p.moduli
            for i in range(len(ms)):
                for j in range(i + 1, len(ms)):
                    assert math.gcd(ms[i], ms[j]) == 1
            assert p.M == int(np.prod([int(m) for m in ms], dtype=object))
            if p.int8_safe:
                assert p.max_digit <= 128

    def test_capacity(self):
        # rns9 must hold an exact 16x16-bit dot of >= 2**29 terms
        assert P9.dot_capacity(16, 16) >= 2**29

    def test_required_digits_monotone(self):
        ds = [required_digits(n, 16, 16) for n in (16, 4096, 10**6)]
        assert ds == sorted(ds)
        assert required_digits(4096, 8, 8) < required_digits(4096, 24, 24)

    # construction-time validation: a bad basis must fail loudly, not
    # silently corrupt MRC reconstructions downstream
    def test_rejects_empty_moduli(self):
        from repro.core.moduli import RnsProfile

        with pytest.raises(ValueError, match="empty moduli"):
            RnsProfile("bad_empty", (), 0)

    def test_rejects_modulus_below_two(self):
        from repro.core.moduli import RnsProfile

        with pytest.raises(ValueError, match="contributes no range"):
            RnsProfile("bad_one", (1, 127), 0)

    def test_rejects_duplicate_modulus(self):
        from repro.core.moduli import RnsProfile

        with pytest.raises(ValueError, match="duplicated"):
            RnsProfile("bad_dup", (127, 127), 0)

    def test_rejects_non_coprime_pair(self):
        from repro.core.moduli import RnsProfile

        with pytest.raises(ValueError, match="not coprime"):
            RnsProfile("bad_gcd", (6, 9), 0)

    def test_narrowest_profile_selection(self):
        from repro.core.moduli import narrowest_profile

        # tiny need -> smallest registered int8-safe profile
        small = narrowest_profile(10.0, cap="rns9")
        assert small.signed_bits >= 10.0
        assert small.range_bits <= get_profile("rns9").range_bits
        # need just over a narrow profile's range climbs to the next one
        for name in ("rns5", "rns6", "rns7", "rns8"):
            p = get_profile(name)
            chosen = narrowest_profile(p.signed_bits + 0.5, cap="rns9")
            assert chosen.signed_bits >= p.signed_bits + 0.5
            assert chosen.range_bits > p.range_bits
        # impossible need falls back to the cap itself
        assert narrowest_profile(10_000.0, cap="rns9").name == "rns9"


@given(st.lists(st.integers(-HALF + 1, HALF - 1), min_size=1, max_size=16))
def test_exact_roundtrip(vals):
    res = rns.encode_exact(P9, np.asarray(vals, dtype=object))
    back = rns.decode_exact(P9, res)
    assert [int(b) for b in back] == vals


@given(st.lists(st.integers(-(2**30), 2**30 - 1), min_size=1, max_size=32))
def test_decode_int32_exact(vals):
    r = rns.encode_int32(P9, np.asarray(vals, np.int32))
    out = np.asarray(mrc.decode_int32(P9, r))
    assert out.tolist() == vals


@given(
    st.lists(st.integers(-(2**25), 2**25), min_size=1, max_size=8),
    st.lists(st.integers(-(2**25), 2**25), min_size=1, max_size=8),
)
def test_pac_ops_match_oracle(a, b):
    n = min(len(a), len(b))
    a, b = a[:n], b[:n]
    ra = rns.encode_int32(P9, np.asarray(a, np.int32))
    rb = rns.encode_int32(P9, np.asarray(b, np.int32))
    add = rns.decode_exact(P9, np.asarray(rns.rns_add(P9, ra, rb)))
    sub = rns.decode_exact(P9, np.asarray(rns.rns_sub(P9, ra, rb)))
    mul = rns.decode_exact(P9, np.asarray(rns.rns_mul(P9, ra, rb)))
    for i in range(n):
        assert int(add[i]) == a[i] + b[i]
        assert int(sub[i]) == a[i] - b[i]
        assert int(mul[i]) == a[i] * b[i]


@given(st.lists(st.integers(-(2**60), 2**60), min_size=1, max_size=16))
def test_sign_detection(vals):
    r = jnp.asarray(rns.encode_exact(P9, np.asarray(vals, dtype=object)))
    s = np.asarray(mrc.rns_sign(P9, r))
    assert s.tolist() == [int(np.sign(v)) for v in vals]


@given(st.lists(st.integers(-(2**55), 2**55), min_size=1, max_size=16))
def test_scale_signed_is_round_div(vals):
    from fractions import Fraction

    r = jnp.asarray(rns.encode_exact(P9, np.asarray(vals, dtype=object)))
    sc = mrc.scale_signed(P9, r)
    got = rns.decode_exact(P9, np.asarray(sc))
    for g, v in zip(got, vals):
        assert int(g) == round(Fraction(v, P9.M_f))


@given(st.lists(st.integers(-(2**40), 2**40), min_size=1, max_size=16),
       st.integers(-(2**40), 2**40))
def test_compare_ge_const(vals, c):
    r = jnp.asarray(rns.encode_exact(P9, np.asarray(vals, dtype=object)))
    got = np.asarray(mrc.compare_ge_const(P9, r, c))
    assert got.tolist() == [v >= c for v in vals]


def test_decode_float_precision():
    rng = np.random.default_rng(0)
    vals = rng.integers(-(2**50), 2**50, 64).astype(object)
    r = jnp.asarray(rns.encode_exact(P9, vals))
    out = np.asarray(mrc.decode_float(P9, r, inv_scale=2.0**-20))
    want = np.asarray([float(v) * 2.0**-20 for v in vals])
    np.testing.assert_allclose(out, want, rtol=2e-6)


def test_base_extend_consistent():
    rng = np.random.default_rng(1)
    f = P9.frac_digits
    small = rng.integers(0, P9.M_f, 32).astype(object)
    r = jnp.asarray(rns.encode_exact(P9, small))
    digits = mrc.mrc_digits(P9, r)
    ext = mrc.base_extend(P9, digits, f)
    assert np.array_equal(np.asarray(ext), np.asarray(r))
