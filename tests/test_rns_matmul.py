"""Digit-sliced matmul: exactness, gradients, capacity guard."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rns
from repro.core.moduli import get_profile
from repro.core.rns_matmul import RnsDotConfig, rns_dot, rns_matmul_res


@pytest.mark.parametrize("profile", ["rns5", "rns9", "rns12", "rns8_u8"])
@pytest.mark.parametrize("shape", [(1, 8, 1), (4, 64, 8), (17, 333, 5)])
def test_matmul_exact_vs_python_ints(profile, shape):
    p = get_profile(profile)
    M, D, N = shape
    qmax = min(2 ** 12, int((p.M // 2 // D) ** 0.5))
    rng = np.random.default_rng(hash((profile, shape)) % 2**32)
    A = rng.integers(-qmax, qmax + 1, (M, D)).astype(np.int32)
    B = rng.integers(-qmax, qmax + 1, (D, N)).astype(np.int32)
    rc = rns_matmul_res(profile, rns.encode_int32(p, A), rns.encode_int32(p, B))
    got = rns.decode_exact(p, np.asarray(rc))
    want = A.astype(object) @ B.astype(object)
    assert np.array_equal(got, want)


def test_wide_dot_exact_where_f32_fails():
    """The paper's motivation: exact wide accumulation, 8-bit hardware."""
    p = get_profile("rns9")
    rng = np.random.default_rng(0)
    D = 8192
    A = rng.integers(-32767, 32768, (1, D)).astype(np.int64)
    B = rng.integers(-32767, 32768, (D, 1)).astype(np.int64)
    rc = rns_matmul_res("rns9", rns.encode_int32(p, A.astype(np.int32)),
                        rns.encode_int32(p, B.astype(np.int32)))
    got = int(rns.decode_exact(p, np.asarray(rc))[0, 0])
    want = int((A.astype(object) @ B.astype(object))[0, 0])
    assert got == want
    f32 = float((A.astype(np.float32) @ B.astype(np.float32))[0, 0])
    # f32 accumulation in this magnitude regime is NOT exact
    assert abs(want) > 2**33  # f32 ulp here is > 2**9
    assert int(f32) != want


def test_rns_dot_close_and_grads():
    rng = np.random.default_rng(3)
    cfg = RnsDotConfig(profile="rns9", qx=14, qw=14)
    x = jnp.asarray(rng.standard_normal((6, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    y = rns_dot(x, w, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=0,
                               atol=3e-3 * float(jnp.abs(x @ w).max()))
    g = jax.grad(lambda x, w: jnp.sum(rns_dot(x, w, cfg) ** 2), argnums=(0, 1))(x, w)
    gref = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2), argnums=(0, 1))(x, w)
    for a, b in zip(g, gref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2
                                   * float(jnp.abs(b).max()))


def test_capacity_guard_raises():
    cfg = RnsDotConfig(profile="rns5", qx=16, qw=16)
    x = jnp.zeros((2, 4096), jnp.float32)
    w = jnp.zeros((4096, 2), jnp.float32)
    with pytest.raises(ValueError, match="cannot hold an exact"):
        rns_dot(x, w, cfg)


def test_chunked_lazy_reduction_path():
    """D > lazy_chunk exercises the chunked modular accumulation."""
    p = get_profile("rns9")
    D = p.lazy_chunk + 1000
    rng = np.random.default_rng(5)
    A = rng.integers(-3, 4, (1, D)).astype(np.int32)
    B = rng.integers(-3, 4, (D, 1)).astype(np.int32)
    rc = rns_matmul_res("rns9", rns.encode_int32(p, A), rns.encode_int32(p, B))
    got = int(rns.decode_exact(p, np.asarray(rc))[0, 0])
    want = int((A.astype(object) @ B.astype(object))[0, 0])
    assert got == want
