"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, st

from repro.core.rns import encode_exact, encode_int32, tables
from repro.core.rns_matmul import RnsDotConfig, rns_dot
from repro.kernels.rns_convert.ops import rns_convert
from repro.kernels.rns_convert.ref import rns_convert_ref
from repro.kernels.rns_matmul.ops import rns_matmul
from repro.kernels.rns_matmul.ref import rns_matmul_ref
from repro.kernels.rns_normalize.ops import rns_normalize
from repro.kernels.rns_normalize.ref import rns_normalize_ref

PROFILES = ["rns5", "rns9"]


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
@pytest.mark.parametrize(
    "shape", [(4, 32, 8), (128, 512, 128), (17, 100, 9), (130, 700, 150),
              (1, 1, 1)])
def test_matmul_kernel_matches_ref(profile, dtype, shape):
    t = tables(profile)
    M, D, N = shape
    rng = np.random.default_rng(hash((profile, shape)) % 2**32)
    A = rng.integers(-2**11, 2**11, (M, D)).astype(np.int32)
    B = rng.integers(-2**11, 2**11, (D, N)).astype(np.int32)
    ra = encode_int32(profile, A).astype(dtype)
    rb = encode_int32(profile, B).astype(dtype)
    got = np.asarray(rns_matmul(profile, ra, rb))
    want = np.asarray(rns_matmul_ref(np.asarray(t.moduli), ra, rb))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("batch", [(), (3,), (2, 3)])
def test_matmul_kernel_batched(batch):
    profile = "rns9"
    t = tables(profile)
    rng = np.random.default_rng(0)
    A = rng.integers(-500, 500, batch + (5, 64)).astype(np.int32)
    B = rng.integers(-500, 500, (64, 7)).astype(np.int32)
    ra = encode_int32(profile, A).astype(jnp.int8)
    rb = encode_int32(profile, B).astype(jnp.int8)
    got = np.asarray(rns_matmul(profile, ra, rb))
    K = ra.shape[0]
    want = np.asarray(rns_matmul_ref(
        np.asarray(t.moduli), ra.reshape(K, -1, 64), rb)).reshape(got.shape)
    assert np.array_equal(got, want)


@given(st.lists(st.integers(-(2**55), 2**55), min_size=1, max_size=40),
       st.sampled_from(PROFILES))
def test_normalize_kernel_matches_ref(vals, profile):
    rv = jnp.asarray(encode_exact(profile, np.asarray(vals, dtype=object)))
    got = np.asarray(rns_normalize(profile, rv))
    want = np.asarray(rns_normalize_ref(rv, profile=profile))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("bits", [8, 12, 16])
@pytest.mark.parametrize("shape", [(7,), (3, 55), (1, 1)])
def test_convert_kernel_matches_ref(profile, bits, shape):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(shape).astype(np.float32) * 10
    s = np.float32(37.5)
    got = np.asarray(rns_convert(profile, jnp.asarray(x), s, bits=bits))
    want = np.asarray(
        rns_convert_ref(x.reshape(-1), s, profile=profile, bits=bits))
    assert np.array_equal(got.reshape(got.shape[0], -1), want)


def test_end_to_end_pallas_equals_jnp_backend():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((6, 200)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    y_j = rns_dot(x, w, RnsDotConfig(profile="rns9", qx=14, qw=14))
    y_p = rns_dot(x, w, RnsDotConfig(profile="rns9", qx=14, qw=14,
                                     use_pallas=True))
    assert np.array_equal(np.asarray(y_j), np.asarray(y_p))


# --------------------------------------------- property tests (tails) -----
@given(st.integers(1, 1200), st.sampled_from(PROFILES),
       st.sampled_from([8, 16]))
def test_convert_property_ragged(T, profile, bits):
    rng = np.random.default_rng(T * 31 + bits)
    x = rng.standard_normal(T).astype(np.float32) * 10
    s = np.float32(rng.uniform(0.5, 50.0))
    got = np.asarray(rns_convert(profile, jnp.asarray(x), s, bits=bits))
    want = np.asarray(rns_convert_ref(x, s, profile=profile, bits=bits))
    assert np.array_equal(got, want)


@given(st.lists(st.integers(-(2**55), 2**55), min_size=1, max_size=60),
       st.sampled_from(PROFILES))
def test_normalize_property_ragged(vals, profile):
    rv = jnp.asarray(encode_exact(profile, np.asarray(vals, dtype=object)))
    got = np.asarray(rns_normalize(profile, rv))
    want = np.asarray(rns_normalize_ref(rv, profile=profile))
    assert np.array_equal(got, want)   # same kernel math: bitwise, not close


@given(st.integers(1, 40), st.integers(1, 80), st.integers(1, 30),
       st.sampled_from(PROFILES))
def test_matmul_property_tails(M, D, N, profile):
    t = tables(profile)
    rng = np.random.default_rng(M * 7919 + D * 131 + N)
    A = rng.integers(-2**11, 2**11, (M, D)).astype(np.int32)
    B = rng.integers(-2**11, 2**11, (D, N)).astype(np.int32)
    ra = encode_int32(profile, A).astype(jnp.int8)
    rb = encode_int32(profile, B).astype(jnp.int8)
    got = np.asarray(rns_matmul(profile, ra, rb))
    want = np.asarray(rns_matmul_ref(np.asarray(t.moduli), ra, rb))
    assert np.array_equal(got, want)


@given(st.integers(1, 6), st.integers(1, 9), st.integers(1, 33))
def test_convert_property_per_sequence_scales(B, T, d):
    """Per-row grids through the kernel == the reference broadcast rule."""
    from repro.core.quantize import quantize_with_scale

    rng = np.random.default_rng(B * 100 + T * 10 + d)
    x = jnp.asarray(rng.standard_normal((B, T, d)), jnp.float32)
    s = jnp.asarray(rng.uniform(0.5, 40.0, (B, 1, 1)), jnp.float32)
    got = rns_convert("rns9", x, s, bits=12)
    want = encode_int32("rns9", quantize_with_scale(x, s, 12))
    assert np.array_equal(np.asarray(got, np.int32), np.asarray(want))


# -------------------------------------- zero-per-length-recompile pins ----
def test_normalize_wrapper_single_compile_across_ragged_lengths():
    """Satellite: fixed bt tile + padding — ONE kernel for every length
    in a padded-size bucket (was: one whole-array compile per length)."""
    from repro.kernels.rns_normalize.kernel import rns_normalize_tiles

    rng = np.random.default_rng(7)
    before = rns_normalize_tiles._cache_size()
    for L in (3, 17, 100, 555, 1000, 1024):
        res = jnp.asarray(encode_int32(
            "rns9", rng.integers(-2**20, 2**20, L).astype(np.int32)))
        rns_normalize("rns9", res)
    assert rns_normalize_tiles._cache_size() - before <= 1


def test_convert_wrapper_single_compile_across_ragged_lengths():
    from repro.kernels.rns_convert.kernel import rns_convert_tiles

    rng = np.random.default_rng(8)
    before = rns_convert_tiles._cache_size()
    for L in (3, 17, 100, 555, 1000, 1024):
        rns_convert("rns9", jnp.asarray(
            rng.standard_normal(L), jnp.float32), np.float32(11.0))
    assert rns_convert_tiles._cache_size() - before <= 1


def test_matmul_wrapper_m_bucketing_single_compile():
    """Satellite: bm is a multiple of 8 and M is pow2-bucketed — mixed
    row counts in one bucket share ONE compile (was: a Mosaic-illegal
    non-multiple-of-8 tile and a recompile per distinct M)."""
    from repro.kernels.rns_matmul.kernel import rns_matmul_tiles
    from repro.kernels.rns_matmul.ops import _pow2_at_least

    assert all(_pow2_at_least(m) % 8 == 0 for m in range(1, 300))
    rng = np.random.default_rng(9)
    B = rng.integers(-500, 500, (64, 16)).astype(np.int32)
    rb = encode_int32("rns9", B).astype(jnp.int8)
    before = rns_matmul_tiles._cache_size()
    for M in (65, 80, 100, 128):       # one power-of-two bucket: (64, 128]
        A = rng.integers(-500, 500, (M, 64)).astype(np.int32)
        ra = encode_int32("rns9", A).astype(jnp.int8)
        rns_matmul("rns9", ra, rb)
    # <= 1: an earlier test may already have compiled this bucket's cell;
    # the broken wrapper would have added one cell PER distinct M
    assert rns_matmul_tiles._cache_size() - before <= 1
