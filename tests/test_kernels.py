"""Pallas kernels (interpret mode) vs pure-jnp oracles: shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, st

from repro.core.rns import encode_exact, encode_int32, tables
from repro.core.rns_matmul import RnsDotConfig, rns_dot
from repro.kernels.rns_convert.ops import rns_convert
from repro.kernels.rns_convert.ref import rns_convert_ref
from repro.kernels.rns_matmul.ops import rns_matmul
from repro.kernels.rns_matmul.ref import rns_matmul_ref
from repro.kernels.rns_normalize.ops import rns_normalize
from repro.kernels.rns_normalize.ref import rns_normalize_ref

PROFILES = ["rns5", "rns9"]


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
@pytest.mark.parametrize(
    "shape", [(4, 32, 8), (128, 512, 128), (17, 100, 9), (130, 700, 150),
              (1, 1, 1)])
def test_matmul_kernel_matches_ref(profile, dtype, shape):
    t = tables(profile)
    M, D, N = shape
    rng = np.random.default_rng(hash((profile, shape)) % 2**32)
    A = rng.integers(-2**11, 2**11, (M, D)).astype(np.int32)
    B = rng.integers(-2**11, 2**11, (D, N)).astype(np.int32)
    ra = encode_int32(profile, A).astype(dtype)
    rb = encode_int32(profile, B).astype(dtype)
    got = np.asarray(rns_matmul(profile, ra, rb))
    want = np.asarray(rns_matmul_ref(np.asarray(t.moduli), ra, rb))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("batch", [(), (3,), (2, 3)])
def test_matmul_kernel_batched(batch):
    profile = "rns9"
    t = tables(profile)
    rng = np.random.default_rng(0)
    A = rng.integers(-500, 500, batch + (5, 64)).astype(np.int32)
    B = rng.integers(-500, 500, (64, 7)).astype(np.int32)
    ra = encode_int32(profile, A).astype(jnp.int8)
    rb = encode_int32(profile, B).astype(jnp.int8)
    got = np.asarray(rns_matmul(profile, ra, rb))
    K = ra.shape[0]
    want = np.asarray(rns_matmul_ref(
        np.asarray(t.moduli), ra.reshape(K, -1, 64), rb)).reshape(got.shape)
    assert np.array_equal(got, want)


@given(st.lists(st.integers(-(2**55), 2**55), min_size=1, max_size=40),
       st.sampled_from(PROFILES))
def test_normalize_kernel_matches_ref(vals, profile):
    rv = jnp.asarray(encode_exact(profile, np.asarray(vals, dtype=object)))
    got = np.asarray(rns_normalize(profile, rv))
    want = np.asarray(rns_normalize_ref(rv, profile=profile))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("profile", PROFILES)
@pytest.mark.parametrize("bits", [8, 12, 16])
@pytest.mark.parametrize("shape", [(7,), (3, 55), (1, 1)])
def test_convert_kernel_matches_ref(profile, bits, shape):
    rng = np.random.default_rng(1)
    x = rng.standard_normal(shape).astype(np.float32) * 10
    s = np.float32(37.5)
    got = np.asarray(rns_convert(profile, jnp.asarray(x), s, bits=bits))
    want = np.asarray(
        rns_convert_ref(x.reshape(-1), s, profile=profile, bits=bits))
    assert np.array_equal(got.reshape(got.shape[0], -1), want)


def test_end_to_end_pallas_equals_jnp_backend():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((6, 200)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((200, 12)), jnp.float32)
    y_j = rns_dot(x, w, RnsDotConfig(profile="rns9", qx=14, qw=14))
    y_p = rns_dot(x, w, RnsDotConfig(profile="rns9", qx=14, qw=14,
                                     use_pallas=True))
    assert np.array_equal(np.asarray(y_j), np.asarray(y_p))
