"""The trip-count-aware HLO cost model (roofline measurement tool)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo


def _flops(f, *args):
    txt = jax.jit(f).lower(*args).compile().as_text()
    return analyze_hlo(txt)


def test_scan_flops_equal_unrolled():
    x = jnp.zeros((256, 256), jnp.float32)

    def body(c, _):
        return c @ c, None

    def f_scan(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def f_unroll(x):
        for _ in range(10):
            x = x @ x
        return x

    expect = 2 * 256**3 * 10
    assert _flops(f_scan, x)["flops"] == expect
    assert _flops(f_unroll, x)["flops"] == expect


def test_nested_scan_multiplies():
    x = jnp.zeros((128, 128), jnp.float32)

    def f(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None

            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    assert _flops(f, x)["flops"] == 2 * 128**3 * 15


def test_remat_increases_flops():
    x = jnp.zeros((128, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)

    def loss(w):
        h = x
        for _ in range(4):
            h = jnp.tanh(h @ w)
        return jnp.sum(h)

    plain = _flops(jax.grad(loss), w)["flops"]
    rematted = _flops(jax.grad(jax.checkpoint(loss)), w)["flops"]
    assert rematted >= plain  # recompute adds forward flops


def test_synthetic_collectives_parse():
    txt = """
HloModule m, entry_computation_layout={()->f32[]}

%region_2.3 (a: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %a = (s32[], f32[64,64]{1,0}) parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%x), replica_groups={}, dimensions={0}
  %ar = f32[64,64]{1,0} all-reduce-start(%ag), to_apply=%add
  %ard = f32[64,64]{1,0} all-reduce-done(%ar)
}

%region_3.4 (a2: (s32[], f32[64,64])) -> pred[] {
  %a2 = (s32[], f32[64,64]{1,0}) parameter(0)
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (p: f32[64,64]) -> f32[] {
  %w = (s32[], f32[64,64]{1,0}) while(%t), condition=%region_3.4, body=%region_2.3
}
"""
    r = analyze_hlo(txt)
    n = 64 * 64 * 4
    assert r["collectives"]["all-gather"]["wire_bytes"] == 7 * n
    assert r["collectives"]["all-reduce"]["wire_bytes"] == 7 * 2 * n
    assert r["collectives"]["all-gather"]["count"] == 7
