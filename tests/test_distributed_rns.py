"""Residue-channel (digit-axis) sharding over a multi-device mesh.

The paper's digit-independence claim, executed as a distribution
strategy on 8 virtual CPU devices (a subprocess, because the suite's
own jax is pinned to 1 device):

  * a digit-sharded 3-linear chain decodes BIT-IDENTICALLY to the
    single-device reference;
  * the compiled residue segment (convert + matmuls + deferred
    elementwise mul) contains ZERO cross-device collectives — digits
    never exchange carries; the full chain's HLO contains the one
    normalize-time digit gather (which also proves the sharded trace
    actually engaged: jax's trace cache is keyed on function identity,
    so the two paths use distinct function defs);
  * DP x digit composition: `make_dp_train_step` on a (2, 4) mesh
    produces losses matching the single-device step to float tolerance;
  * the continuous serving engine with `ServeConfig.mesh` set decodes
    token-identically to the unsharded engine.

Pure-layout unit checks (DigitSharding rules) run in-process.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestDigitShardingRules:
    def _mesh(self, shape=(1, 8), axes=("data", "model")):
        from jax.sharding import AbstractMesh

        try:
            return AbstractMesh(shape, axes)
        except TypeError:
            return AbstractMesh(tuple(zip(axes, shape)))

    def test_shards_requires_divisibility(self):
        from repro.distributed.sharding import DigitSharding

        ds = DigitSharding(self._mesh((1, 8)))
        assert ds.n_shards == 8
        assert ds.shards(16) and ds.shards(8)
        assert not ds.shards(9)        # rns9 does not divide 8 devices
        assert ds.auto_axes() == {"data"}

    def test_digit_spec_shape(self):
        from jax.sharding import PartitionSpec as P

        from repro.distributed.sharding import DigitSharding

        ds = DigitSharding(self._mesh((2, 4)))
        assert ds.digit_spec(3) == P("model", None, None)

    def test_context_install_and_noop(self):
        from repro.distributed.sharding import (
            digit_sharding,
            use_digit_sharding,
        )

        assert digit_sharding() is None
        with use_digit_sharding(None):            # no-op form
            assert digit_sharding() is None
        mesh = self._mesh((1, 4))
        with use_digit_sharding(mesh) as ds:
            assert digit_sharding() is ds and ds.axis == "model"
        assert digit_sharding() is None

    def test_rt_device_put_places_digit_layout(self):
        # concrete 1x1 mesh (the suite's jax is pinned to 1 CPU device):
        # placement is a no-op partition but the layout contract holds
        import jax.numpy as jnp
        import numpy as np

        from jax.sharding import PartitionSpec as P

        from repro.core.tensor import (
            rt_device_put,
            rt_digit_sharding,
            rt_encode,
        )
        from repro.distributed.sharding import use_digit_sharding
        from repro.launch.mesh import make_digit_mesh

        x = jnp.asarray(np.arange(8, dtype=np.float32).reshape(2, 4))
        rt = rt_encode(x, "rns16", bits=8)
        assert rt_digit_sharding(rt) is None          # no context: no-op
        assert rt_device_put(rt) is rt
        with use_digit_sharding(make_digit_mesh()):
            sh = rt_digit_sharding(rt)
            assert sh is not None
            assert sh.spec == P("model", None, None)
            placed = rt_device_put(rt)
            assert placed.digits.sharding == sh
            assert np.array_equal(np.asarray(placed.digits),
                                  np.asarray(rt.digits))


SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import dataclasses, json, warnings
warnings.filterwarnings("ignore")
import numpy as np
import jax, jax.numpy as jnp

from repro.launch.mesh import make_digit_mesh
from repro.distributed.sharding import use_digit_sharding
from repro.core.tensor import rt_encode, rt_matmul, rt_mul, rt_decode

out = {"n_devices": jax.device_count()}
mesh = make_digit_mesh(8)                 # (1, 8) data x model
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
ws = [jnp.asarray(rng.standard_normal((64, 64)) / 8, jnp.float32)
      for _ in range(3)]

# NOTE distinct function defs for the sharded/unsharded variants: jax's
# trace cache is keyed on function identity and would otherwise reuse
# the first trace, silently ignoring the digit context.
def chain_ref(x, ws):
    ht = rt_encode(x, "rns16", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns16", bits=8))
    return rt_decode(ht)

def chain_sharded(x, ws):
    ht = rt_encode(x, "rns16", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns16", bits=8))
    return rt_decode(ht)

def residue_segment(x, ws):
    # encode + matmul chain + deferred elementwise mul; residues out, NO
    # normalize -> its HLO must be collective-free
    ht = rt_encode(x, "rns16", bits=8)
    for w in ws:
        ht = rt_matmul(ht, rt_encode(w, "rns16", bits=8))
    ht = rt_mul(ht, rt_encode(x, "rns16", bits=8))
    return ht.digits

COLL = ("all-reduce", "all-to-all", "collective-permute", "all-gather",
        "reduce-scatter")
def n_coll(hlo):
    return sum(1 for l in hlo.splitlines()
               if "=" in l and any(c in l for c in COLL))

y_ref = jax.jit(chain_ref)(x, ws)
with use_digit_sharding(mesh):
    y_sh = jax.jit(chain_sharded)(x, ws)
    seg_hlo = jax.jit(residue_segment).lower(x, ws).compile().as_text()
    full_hlo = jax.jit(chain_sharded).lower(x, ws).compile().as_text()
out["chain_bitexact"] = bool(jnp.all(y_ref == y_sh))
out["residue_segment_collectives"] = n_coll(seg_hlo)
out["full_chain_collectives"] = n_coll(full_hlo)
out["digits_sharded"] = "s32[2,4,64]" in seg_hlo  # 16 digits / 8 devices

# ---- DP x digit train step -----------------------------------------------
from repro.configs.base import get_config
from repro.core.rns_matmul import RnsDotConfig
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import (
    init_train_state, make_train_step, make_dp_train_step)

cfg = dataclasses.replace(get_config("smollm-135m", smoke=True),
                          rns=RnsDotConfig(profile="rns8", qx=8, qw=8),
                          rns_targets="mlp")
mesh24 = make_digit_mesh(4, n_data=2)
opt = AdamWConfig(lr=1e-3)
state_a, _ = init_train_state(jax.random.PRNGKey(0), cfg)
state_b = jax.tree.map(jnp.copy, state_a)
batch = {"tokens": jnp.asarray(rng.integers(1, cfg.vocab, (4, 16)),
                               jnp.int32)}
step_1 = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
step_dp = make_dp_train_step(cfg, opt, mesh24)
l1, ldp = [], []
for _ in range(2):
    state_a, m1 = step_1(state_a, batch)
    state_b, m2 = step_dp(state_b, batch)
    l1.append(float(m1["loss"])); ldp.append(float(m2["loss"]))
out["single_losses"], out["dp_losses"] = l1, ldp
out["dp_loss_close"] = bool(np.allclose(l1, ldp, rtol=1e-5, atol=1e-5))

# ---- sharded continuous serving ------------------------------------------
from repro.serve.engine import ContinuousEngine, ServeConfig

params, _ = M.init_model(jax.random.PRNGKey(1), cfg)
prompts = [rng.integers(1, cfg.vocab, (L,)).astype(np.int32)
           for L in (7, 20)]
res_u, _ = ContinuousEngine(params, cfg, ServeConfig(
    max_cache=48, max_new_tokens=5, page_size=16, max_seqs=2)).run(prompts)
res_s, stats = ContinuousEngine(params, cfg, ServeConfig(
    max_cache=48, max_new_tokens=5, page_size=16, max_seqs=2,
    mesh=mesh)).run(prompts)
out["serve_sharded_identical"] = all(
    res_u[i].tolist() == res_s[i].tolist() for i in range(len(prompts)))
out["serve_tokens"] = {str(i): res_s[i].tolist() for i in res_s}
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_digit_sharded_execution_8_devices():
    """End-to-end: exactness, collective-free residues, DP x digit, serve."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["n_devices"] == 8
    # digit-sharded chain decodes bit-identically to single-device
    assert out["chain_bitexact"]
    # the residue segment's HLO has ZERO cross-device collectives ...
    assert out["residue_segment_collectives"] == 0
    assert out["digits_sharded"]        # 2-of-16 digit planes per device
    # ... and the full chain has (only) the normalize-time digit gather,
    # which also proves the sharded trace engaged at all
    assert out["full_chain_collectives"] > 0
    # DP-sharded train_step losses match single-device to float tolerance
    assert out["dp_loss_close"], (out["single_losses"], out["dp_losses"])
    # sharded continuous decode is token-identical to unsharded
    assert out["serve_sharded_identical"], out["serve_tokens"]
